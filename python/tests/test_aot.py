"""AOT pipeline: artifact plan coverage, manifest/weights integrity."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, model
from compile.configs import MODEL, BUCKETS, WEIGHT_SEED


@pytest.fixture(scope="module")
def plan():
    return aot.build_artifact_plan()


def test_plan_covers_every_bucket(plan):
    names = {a["name"] for a in plan}
    for t in BUCKETS.prefill_t:
        assert f"attn_prefill_t{t}" in names
    for b in BUCKETS.decode_b:
        assert f"attn_decode_b{b}" in names
    for b in BUCKETS.expert_b:
        assert f"expert_b{b}" in names
    for b in BUCKETS.router_b(MODEL):
        assert f"router_b{b}" in names
    for b in BUCKETS.lm_head_b:
        assert f"lm_head_b{b}" in names
    assert len(names) == len(plan), "duplicate artifact names"


def test_plan_io_specs_match_fn_signature(plan):
    """Lowering each entry with its in_specs must produce outputs whose
    shapes match the declared output specs (the Rust-side ABI)."""
    for art in plan:
        out = jax.eval_shape(art["fn"], *art["in_specs"])
        flat = out if isinstance(out, tuple) else (out,)
        assert len(flat) == len(art["outputs"]), art["name"]
        for got, want in zip(flat, art["outputs"]):
            assert list(got.shape) == want["shape"], (art["name"], want["name"])


def test_hlo_text_emission(tmp_path):
    """Lower one small artifact end-to-end and sanity-check the HLO text."""
    art = [a for a in aot.build_artifact_plan() if a["name"] == "router_b1"][0]
    lowered = jax.jit(art["fn"]).lower(*art["in_specs"])
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "ROOT" in text
    # return_tuple=True: the root must be a tuple so the Rust side can
    # uniformly unwrap outputs.
    assert "tuple(" in text or "(f32[" in text


def test_write_weights_roundtrip(tmp_path):
    w = model.generate_weights(WEIGHT_SEED)
    meta = aot.write_weights(str(tmp_path), w)
    blob = np.fromfile(tmp_path / "weights.bin", dtype=np.float32)
    assert meta["total_bytes"] == blob.nbytes
    # Reconstruct a few tensors from the offset table and compare.
    table = {t["name"]: t for t in meta["tensors"]}
    for name in ("embed", "layer0.wq", "layer1.expert3.w2", "lm_head"):
        t = table[name]
        start = t["offset"] // 4
        n = int(np.prod(t["shape"]))
        np.testing.assert_array_equal(
            blob[start:start + n].reshape(t["shape"]), w[name])


def test_weight_table_is_dense_and_ordered(tmp_path):
    w = model.generate_weights(WEIGHT_SEED)
    meta = aot.write_weights(str(tmp_path), w)
    offset = 0
    for t in meta["tensors"]:
        assert t["offset"] == offset, "weight blob must be densely packed"
        assert t["nbytes"] == int(np.prod(t["shape"])) * 4
        offset += t["nbytes"]


def test_artifacts_dir_manifest_consistent():
    """If `make artifacts` has run, the manifest must describe every file."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art_dir, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.load(open(mpath))
    assert manifest["model"]["hidden"] == MODEL.hidden
    assert manifest["model"]["layers"] == MODEL.layers
    for art in manifest["artifacts"]:
        path = os.path.join(art_dir, art["file"])
        assert os.path.exists(path), art["file"]
        assert os.path.getsize(path) > 0
    wpath = os.path.join(art_dir, manifest["weights"]["file"])
    assert os.path.getsize(wpath) == manifest["weights"]["total_bytes"]
