import os
import sys

import numpy as np
import pytest

# Tests are run from python/ (see Makefile); make the package importable
# regardless of the invocation directory.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import model  # noqa: E402
from compile.configs import WEIGHT_SEED  # noqa: E402


@pytest.fixture(scope="session")
def weights():
    return model.generate_weights(WEIGHT_SEED)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
