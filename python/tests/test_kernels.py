"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps the shape space (batch sizes, sequence lengths, head
configurations, block sizes) and asserts allclose against kernels/ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import decode_attention, prefill_attention
from compile.kernels.expert_ffn import swiglu_ffn, pick_block

TOL = dict(rtol=2e-5, atol=2e-5)


def _arr(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# SwiGLU expert FFN
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3, 4, 8, 16, 64, 256]),
    h=st.sampled_from([32, 128]),
    f=st.sampled_from([64, 256]),
    block_m=st.sampled_from([1, 8, 64]),
    block_f=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_swiglu_matches_ref(b, h, f, block_m, block_f, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, b, h)
    w1 = _arr(rng, h, f, scale=h ** -0.5)
    w3 = _arr(rng, h, f, scale=h ** -0.5)
    w2 = _arr(rng, f, h, scale=f ** -0.5)
    got = swiglu_ffn(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w3),
                     jnp.asarray(w2), block_m=block_m, block_f=block_f)
    want = ref.swiglu_ffn_ref(jnp.asarray(x), jnp.asarray(w1),
                              jnp.asarray(w3), jnp.asarray(w2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_swiglu_extreme_values():
    """Gate saturation must not produce NaN/Inf."""
    rng = np.random.default_rng(0)
    x = _arr(rng, 4, 32, scale=50.0)  # drives silu into both tails
    w1 = _arr(rng, 32, 64)
    w3 = _arr(rng, 32, 64)
    w2 = _arr(rng, 64, 32)
    got = np.asarray(swiglu_ffn(*map(jnp.asarray, (x, w1, w3, w2))))
    want = np.asarray(ref.swiglu_ffn_ref(*map(jnp.asarray, (x, w1, w3, w2))))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_pick_block_divides():
    for dim in (1, 2, 4, 96, 128, 160, 256):
        for pref in (1, 32, 64, 128):
            b = pick_block(dim, pref)
            assert dim % b == 0 and 1 <= b <= min(dim, pref)


# ---------------------------------------------------------------------------
# Decode attention (flash-decoding vs dense oracle)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    heads_kv=st.sampled_from([(4, 1), (4, 2), (2, 2), (8, 1)]),
    s=st.sampled_from([32, 96, 160]),
    block_s=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, heads_kv, s, block_s, seed):
    heads, kv = heads_kv
    d = 16
    rng = np.random.default_rng(seed)
    q = _arr(rng, b, heads, d)
    kc = _arr(rng, b, s, kv, d)
    vc = _arr(rng, b, s, kv, d)
    kn = _arr(rng, b, kv, d)
    vn = _arr(rng, b, kv, d)
    pos = rng.integers(0, s + 1, size=(b,)).astype(np.int32)
    args = tuple(map(jnp.asarray, (q, kc, vc, kn, vn, pos)))
    got = decode_attention(*args, block_s=block_s)
    want = ref.decode_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_decode_attention_empty_cache():
    """pos=0: output must equal v_new exactly (only the current token)."""
    rng = np.random.default_rng(7)
    b, heads, kv, d, s = 2, 4, 1, 16, 32
    q = _arr(rng, b, heads, d)
    kc = np.zeros((b, s, kv, d), np.float32)
    vc = np.zeros((b, s, kv, d), np.float32)
    kn = _arr(rng, b, kv, d)
    vn = _arr(rng, b, kv, d)
    pos = np.zeros(b, np.int32)
    got = np.asarray(decode_attention(*map(jnp.asarray, (q, kc, vc, kn, vn, pos))))
    want = np.repeat(vn, heads // kv, axis=1)
    np.testing.assert_allclose(got, want, **TOL)


def test_decode_attention_ignores_garbage_beyond_pos():
    """Cache contents past pos must not affect the result."""
    rng = np.random.default_rng(8)
    b, heads, kv, d, s = 2, 4, 1, 16, 64
    q = _arr(rng, b, heads, d)
    kc = _arr(rng, b, s, kv, d)
    vc = _arr(rng, b, s, kv, d)
    kn = _arr(rng, b, kv, d)
    vn = _arr(rng, b, kv, d)
    pos = np.array([5, 40], np.int32)
    base = np.asarray(decode_attention(*map(jnp.asarray, (q, kc, vc, kn, vn, pos))))
    kc2, vc2 = kc.copy(), vc.copy()
    for i, p in enumerate(pos):
        kc2[i, p:] = 1e6
        vc2[i, p:] = -1e6
    poisoned = np.asarray(
        decode_attention(*map(jnp.asarray, (q, kc2, vc2, kn, vn, pos))))
    np.testing.assert_allclose(base, poisoned, **TOL)


# ---------------------------------------------------------------------------
# Prefill attention (causal flash vs dense oracle)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([4, 32, 96]),
    heads_kv=st.sampled_from([(4, 1), (4, 2), (2, 1)]),
    blocks=st.sampled_from([(8, 8), (32, 32), (16, 32)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_attention_matches_ref(t, heads_kv, blocks, seed):
    heads, kv = heads_kv
    d = 16
    bq, bk = blocks
    rng = np.random.default_rng(seed)
    q = _arr(rng, t, heads, d)
    k = _arr(rng, t, kv, d)
    v = _arr(rng, t, kv, d)
    got = prefill_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            block_q=bq, block_k=bk)
    want = ref.prefill_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                     jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_prefill_is_causal():
    """Future tokens must not influence earlier outputs."""
    rng = np.random.default_rng(9)
    t, heads, kv, d = 32, 4, 1, 16
    q = _arr(rng, t, heads, d)
    k = _arr(rng, t, kv, d)
    v = _arr(rng, t, kv, d)
    full = np.asarray(prefill_attention(*map(jnp.asarray, (q, k, v))))
    # Perturb the tail; the first half of the outputs must be unchanged.
    k2, v2 = k.copy(), v.copy()
    k2[t // 2:] += 100.0
    v2[t // 2:] -= 100.0
    pert = np.asarray(prefill_attention(*map(jnp.asarray, (q, k2, v2))))
    np.testing.assert_allclose(full[: t // 2], pert[: t // 2], **TOL)


def test_prefill_first_token_is_v0():
    rng = np.random.default_rng(10)
    t, heads, kv, d = 8, 2, 1, 16
    q = _arr(rng, t, heads, d)
    k = _arr(rng, t, kv, d)
    v = _arr(rng, t, kv, d)
    out = np.asarray(prefill_attention(*map(jnp.asarray, (q, k, v))))
    want = np.repeat(v[:1], heads // kv, axis=1)[0]
    np.testing.assert_allclose(out[0], want, **TOL)


# ---------------------------------------------------------------------------
# Decode == prefill consistency (the invariant the AW recovery path relies on)
# ---------------------------------------------------------------------------

def test_decode_step_extends_prefill():
    """Attention for token T computed via decode over a cache built by
    prefill must equal row T of a T+1-token prefill."""
    rng = np.random.default_rng(11)
    t, heads, kv, d = 16, 4, 1, 16
    q_all = _arr(rng, t + 1, heads, d)
    k_all = _arr(rng, t + 1, kv, d)
    v_all = _arr(rng, t + 1, kv, d)
    full = np.asarray(prefill_attention(
        jnp.asarray(q_all), jnp.asarray(k_all), jnp.asarray(v_all)))
    s = 32  # padded cache
    kc = np.zeros((1, s, kv, d), np.float32)
    vc = np.zeros((1, s, kv, d), np.float32)
    kc[0, :t] = k_all[:t]
    vc[0, :t] = v_all[:t]
    got = np.asarray(decode_attention(
        jnp.asarray(q_all[t:t + 1]), jnp.asarray(kc), jnp.asarray(vc),
        jnp.asarray(k_all[t:t + 1]), jnp.asarray(v_all[t:t + 1]),
        jnp.asarray(np.array([t], np.int32))))
    np.testing.assert_allclose(got[0], full[t], **TOL)
