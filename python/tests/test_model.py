"""L2 correctness: model entry points, weights, and the e2e oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.configs import MODEL, WEIGHT_SEED
from compile.kernels import ref


def _arr(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([1, 5, 32]))
def test_rms_norm_unit_scale(seed, n):
    rng = np.random.default_rng(seed)
    x = _arr(rng, n, MODEL.hidden, scale=3.0)
    out = np.asarray(model.rms_norm(jnp.asarray(x),
                                    jnp.ones(MODEL.hidden, np.float32)))
    # RMS of the output must be ~1 for gamma=1
    rms = np.sqrt(np.mean(out ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), pos=st.integers(0, 200))
def test_rope_preserves_norm(seed, pos):
    """Rotations are orthogonal: vector norms are invariant."""
    rng = np.random.default_rng(seed)
    x = _arr(rng, 3, MODEL.heads, MODEL.head_dim)
    p = jnp.asarray(np.full((3,), pos, np.int32))
    out = np.asarray(model.rope(jnp.asarray(x), p))
    np.testing.assert_allclose(
        np.linalg.norm(out, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-4)


def test_rope_position_zero_identity():
    rng = np.random.default_rng(3)
    x = _arr(rng, 2, MODEL.heads, MODEL.head_dim)
    out = np.asarray(model.rope(jnp.asarray(x),
                                jnp.zeros((2,), jnp.int32)))
    np.testing.assert_allclose(out, x, rtol=1e-6, atol=1e-6)


def test_rope_relative_phase():
    """RoPE dot-products depend only on relative position."""
    rng = np.random.default_rng(4)
    q = _arr(rng, 1, 1, MODEL.head_dim)
    k = _arr(rng, 1, 1, MODEL.head_dim)
    def dot(pq, pk):
        qq = model.rope(jnp.asarray(q), jnp.asarray(np.array([pq], np.int32)))
        kk = model.rope(jnp.asarray(k), jnp.asarray(np.array([pk], np.int32)))
        return float(jnp.sum(qq * kk))
    np.testing.assert_allclose(dot(7, 3), dot(14, 10), rtol=1e-4)
    np.testing.assert_allclose(dot(20, 20), dot(0, 0), rtol=1e-4)


def test_router_is_distribution(weights):
    rng = np.random.default_rng(5)
    g = _arr(rng, 16, MODEL.hidden)
    probs = np.asarray(model.router(
        jnp.asarray(g), jnp.asarray(weights["layer0.router"])))
    assert probs.shape == (16, MODEL.experts)
    assert (probs >= 0).all()
    np.testing.assert_allclose(probs.sum(axis=-1), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# Attention entry points: prefill/decode consistency at the layer level
# ---------------------------------------------------------------------------

def test_attn_decode_extends_prefill(weights):
    """Layer outputs for token T via the decode path must match running a
    T+1-token prefill — the invariant behind replay-based AW recovery."""
    m = MODEL
    rng = np.random.default_rng(6)
    t = 12
    x = _arr(rng, t + 1, m.hidden)
    lw = model.layer_weights(weights, 0)

    h_full, g_full, k_full, v_full = model.attn_prefill(jnp.asarray(x), *lw)

    # decode path for the last token against a padded cache of the first t
    s = m.max_seq
    kc = np.zeros((1, s, m.kv_heads, m.head_dim), np.float32)
    vc = np.zeros((1, s, m.kv_heads, m.head_dim), np.float32)
    kc[0, :t] = np.asarray(k_full)[:t]
    vc[0, :t] = np.asarray(v_full)[:t]
    h_dec, g_dec, k_new, v_new = model.attn_decode(
        jnp.asarray(x[t:t + 1]), jnp.asarray(kc), jnp.asarray(vc),
        jnp.asarray(np.array([t], np.int32)), *lw)

    np.testing.assert_allclose(np.asarray(h_dec)[0], np.asarray(h_full)[t],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g_dec)[0], np.asarray(g_full)[t],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(k_new)[0], np.asarray(k_full)[t],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v_new)[0], np.asarray(v_full)[t],
                               rtol=1e-4, atol=1e-5)


def test_attn_decode_batch_rows_independent(weights):
    """Batching decode requests must not change per-request results — the
    property that makes continuous batching and per-request restoration
    sound."""
    m = MODEL
    rng = np.random.default_rng(7)
    b, s = 4, m.max_seq
    x = _arr(rng, b, m.hidden)
    kc = _arr(rng, b, s, m.kv_heads, m.head_dim)
    vc = _arr(rng, b, s, m.kv_heads, m.head_dim)
    pos = np.array([3, 50, 7, 100], np.int32)
    lw = model.layer_weights(weights, 1)

    h_b, g_b, kn_b, vn_b = model.attn_decode(
        jnp.asarray(x), jnp.asarray(kc), jnp.asarray(vc),
        jnp.asarray(pos), *lw)
    for i in range(b):
        h1, g1, kn1, vn1 = model.attn_decode(
            jnp.asarray(x[i:i + 1]), jnp.asarray(kc[i:i + 1]),
            jnp.asarray(vc[i:i + 1]), jnp.asarray(pos[i:i + 1]), *lw)
        np.testing.assert_allclose(np.asarray(h_b)[i], np.asarray(h1)[0],
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(kn_b)[i], np.asarray(kn1)[0],
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Weights and the e2e oracle
# ---------------------------------------------------------------------------

def test_weights_deterministic():
    w1 = model.generate_weights(WEIGHT_SEED)
    w2 = model.generate_weights(WEIGHT_SEED)
    assert list(w1) == list(w2)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])


def test_weights_complete(weights):
    m = MODEL
    assert weights["embed"].shape == (m.vocab, m.hidden)
    assert weights["lm_head"].shape == (m.hidden, m.vocab)
    for layer in range(m.layers):
        assert weights[f"layer{layer}.router"].shape == (m.hidden, m.experts)
        for e in range(m.experts):
            assert weights[f"layer{layer}.expert{e}.w1"].shape == (m.hidden, m.ffn)
            assert weights[f"layer{layer}.expert{e}.w2"].shape == (m.ffn, m.hidden)


def test_moe_block_renormalizes(weights):
    """Top-k gate weights are renormalized to sum to 1 (Mixtral convention):
    scaling the router logits' temperature must not change which experts win
    nor blow up the output scale."""
    rng = np.random.default_rng(8)
    g = _arr(rng, 4, MODEL.hidden)
    out = np.asarray(model._moe_block(jnp.asarray(g), weights, 0))
    assert np.isfinite(out).all()
    # Output magnitude should be commensurate with a single expert's output.
    e0 = np.asarray(ref.swiglu_ffn_ref(
        jnp.asarray(g), jnp.asarray(weights["layer0.expert0.w1"]),
        jnp.asarray(weights["layer0.expert0.w3"]),
        jnp.asarray(weights["layer0.expert0.w2"])))
    assert np.linalg.norm(out) < 10 * np.linalg.norm(e0) + 1e3


def test_reference_generate_deterministic(weights):
    a = model.reference_generate([5, 6, 7], 4, weights)
    b = model.reference_generate([5, 6, 7], 4, weights)
    assert a == b
    assert len(a) == 4
    assert all(0 <= t < MODEL.vocab for t in a)


def test_reference_generate_prompt_sensitivity(weights):
    a = model.reference_generate([5, 6, 7], 4, weights)
    b = model.reference_generate([9, 10, 11], 4, weights)
    assert a != b  # distinct prompts should diverge with overwhelming prob.
