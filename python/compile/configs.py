"""Single source of truth for the model configuration and artifact buckets.

The Rust runtime consumes these values through ``artifacts/manifest.json``
emitted by ``aot.py``; nothing on the Rust side hard-codes dimensions.

The configuration is a scaled-down Mixtral-8x7B ("mixtral-tiny") preserving
the structural ratios the paper's arguments depend on (see DESIGN.md §1):
8 experts / top-2 routing, SwiGLU FFN, GQA with a 4:1 head ratio so the
KV-checkpoint-to-expert-traffic ratio matches Appendix C (12.5%).
"""

from dataclasses import dataclass, field, asdict
from typing import List


@dataclass(frozen=True)
class ModelConfig:
    """Architecture of the MoE transformer served by the cluster."""

    layers: int = 4
    hidden: int = 128
    heads: int = 4
    kv_heads: int = 1
    ffn: int = 256           # SwiGLU intermediate size
    experts: int = 8
    top_k: int = 2
    vocab: int = 512
    max_seq: int = 160       # prompt <= 96, decode <= 128 fit with headroom
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim


@dataclass(frozen=True)
class Buckets:
    """Static shape buckets each artifact is AOT-compiled for.

    HLO is static-shape; the Rust coordinator pads each call to the smallest
    bucket that fits and slices the result (see rust/src/runtime).
    """

    prefill_t: List[int] = field(default_factory=lambda: [32, 96])
    decode_b: List[int] = field(default_factory=lambda: [1, 2, 4, 8])
    # expert buckets double as the Fig. 13(b) latency-vs-batch sweep points
    expert_b: List[int] = field(
        default_factory=lambda: [1, 2, 4, 8, 16, 32, 64, 128, 256]
    )
    lm_head_b: List[int] = field(default_factory=lambda: [1, 2, 4, 8])

    def router_b(self, cfg: ModelConfig, prefill: List[int] = None) -> List[int]:
        """Router runs on decode batches and on whole prefill prompts."""
        pre = self.prefill_t if prefill is None else prefill
        return sorted(set(self.decode_b) | set(pre))


MODEL = ModelConfig()
BUCKETS = Buckets()

# Seed for deterministic weight generation; shared with python tests so the
# pytest oracle and the Rust runtime see identical parameters.
WEIGHT_SEED = 0x7A44A60  # "tarragon"


def model_dict() -> dict:
    d = asdict(MODEL)
    d["head_dim"] = MODEL.head_dim
    d["kv_dim"] = MODEL.kv_dim
    return d


def buckets_dict() -> dict:
    return {
        "prefill_t": BUCKETS.prefill_t,
        "decode_b": BUCKETS.decode_b,
        "expert_b": BUCKETS.expert_b,
        "router_b": BUCKETS.router_b(MODEL),
        "lm_head_b": BUCKETS.lm_head_b,
    }
