"""L2: the MoE transformer compute graph, decomposed into per-role entry
points that are each AOT-lowered to one HLO artifact (see aot.py).

The decomposition mirrors the paper's decoupled attention-expert deployment:

- ``attn_prefill`` / ``attn_decode`` run on Attention Workers. One call is
  one transformer layer's attention sub-block *including* RMSNorm, RoPE,
  residual add, and the post-attention norm (``g``), so the Rust AW makes a
  single artifact call per layer per step and never does tensor math beyond
  expert-output accumulation.
- ``router`` produces the gating distribution; top-k selection happens in
  the Rust coordinator (it is control flow, not compute, and the ERT lookup
  that follows is the paper's contribution).
- ``expert_ffn`` (the L1 Pallas kernel) runs on Expert Workers.
- ``lm_head`` maps the final hidden state to logits.

All functions take weights as *runtime arguments* so a single artifact
serves every layer / expert; the Rust runtime uploads the weight blobs once
per worker at init (part of T_w).

``reference_generate`` is the pure-jnp end-to-end oracle used by the pytest
suite and to produce the golden-token fixture the Rust integration tests
compare against.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .configs import MODEL
from .kernels.attention import decode_attention, prefill_attention
from .kernels.expert_ffn import swiglu_ffn
from .kernels import ref


# ---------------------------------------------------------------------------
# Shared building blocks (lowered inline into each artifact)
# ---------------------------------------------------------------------------

def rms_norm(x, gamma):
    return ref.rms_norm_ref(x, gamma, eps=MODEL.rms_eps)


def rope(x, positions):
    return ref.rope_ref(x, positions, theta=MODEL.rope_theta)


def _project_qkv(n, wq, wk, wv):
    """n: [N, H] -> q [N, heads, d], k/v [N, kv_heads, d]."""
    m = MODEL
    num = n.shape[0]
    q = (n @ wq).reshape(num, m.heads, m.head_dim)
    k = (n @ wk).reshape(num, m.kv_heads, m.head_dim)
    v = (n @ wv).reshape(num, m.kv_heads, m.head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Artifact entry points
# ---------------------------------------------------------------------------

def attn_prefill(x, wq, wk, wv, wo, ln1, ln2):
    """One layer's attention sub-block over a whole prompt.

    x: [T, H] token embeddings (or previous layer's hidden states).
    Returns (h, g, k, v):
      h [T, H]  hidden after residual add (input to next layer),
      g [T, H]  post-attention RMSNorm (router / expert input),
      k [T, kv, d], v [T, kv, d]  KV-cache entries for positions 0..T-1.
    """
    t = x.shape[0]
    n = rms_norm(x, ln1)
    q, k, v = _project_qkv(n, wq, wk, wv)
    positions = jnp.arange(t, dtype=jnp.int32)
    q = rope(q, positions)
    k = rope(k, positions)
    attn = prefill_attention(q, k, v)                    # L1 Pallas kernel
    h = x + attn.reshape(t, MODEL.hidden) @ wo
    g = rms_norm(h, ln2)
    return h, g, k, v


def attn_decode(x, k_cache, v_cache, pos, wq, wk, wv, wo, ln1, ln2):
    """One layer's attention sub-block for one decode step of a batch.

    x: [B, H]; k_cache/v_cache: [B, S, kv, d] (valid prefix length pos[b]);
    pos: [B] int32. Returns (h, g, k_new, v_new); the Rust AW writes
    k_new/v_new into its cache at index pos[b] after the call.
    """
    b = x.shape[0]
    n = rms_norm(x, ln1)
    q, k_new, v_new = _project_qkv(n, wq, wk, wv)
    q = rope(q, pos)
    k_new = rope(k_new, pos)
    attn = decode_attention(q, k_cache, v_cache, k_new, v_new, pos)  # L1
    h = x + attn.reshape(b, MODEL.hidden) @ wo
    g = rms_norm(h, ln2)
    return h, g, k_new, v_new


def router(g, wg):
    """Gating network: g [B, H], wg [H, E] -> probs [B, E] (softmax)."""
    return ref.router_ref(g, wg)


def expert_ffn(x, w1, w3, w2):
    """One expert's SwiGLU FFN over a token batch (the L1 Pallas kernel)."""
    return swiglu_ffn(x, w1, w3, w2)


def lm_head(h, ln_f, wlm):
    """Final norm + vocabulary projection. h: [B, H] -> logits [B, V]."""
    return rms_norm(h, ln_f) @ wlm


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

def generate_weights(seed: int) -> dict:
    """Deterministic synthetic weights; shared by pytest and `make artifacts`.

    Returns a dict name -> np.float32 array (insertion-ordered). The naming
    convention is consumed by the Rust manifest loader (modelcfg::weights).
    """
    m = MODEL
    rng = np.random.default_rng(seed)

    def mat(rows, cols, scale=None):
        s = scale if scale is not None else 1.0 / np.sqrt(rows)
        return (rng.standard_normal((rows, cols)) * s).astype(np.float32)

    w = {}
    w["embed"] = mat(m.vocab, m.hidden, scale=1.0)
    for layer in range(m.layers):
        p = f"layer{layer}."
        w[p + "wq"] = mat(m.hidden, m.hidden)
        w[p + "wk"] = mat(m.hidden, m.kv_dim)
        w[p + "wv"] = mat(m.hidden, m.kv_dim)
        w[p + "wo"] = mat(m.hidden, m.hidden)
        w[p + "ln1"] = np.ones(m.hidden, dtype=np.float32)
        w[p + "ln2"] = np.ones(m.hidden, dtype=np.float32)
        w[p + "router"] = mat(m.hidden, m.experts)
        for e in range(m.experts):
            q = f"{p}expert{e}."
            w[q + "w1"] = mat(m.hidden, m.ffn)
            w[q + "w3"] = mat(m.hidden, m.ffn)
            w[q + "w2"] = mat(m.ffn, m.hidden)
    w["ln_f"] = np.ones(m.hidden, dtype=np.float32)
    w["lm_head"] = mat(m.hidden, m.vocab)
    return w


def layer_weights(w: dict, layer: int):
    p = f"layer{layer}."
    return tuple(
        jnp.asarray(w[p + k]) for k in ("wq", "wk", "wv", "wo", "ln1", "ln2")
    )


# ---------------------------------------------------------------------------
# Pure-jnp end-to-end oracle (tests + golden fixture)
# ---------------------------------------------------------------------------

def _moe_block(g, w, layer):
    """Dense reference MoE: route each row to its top-k experts."""
    m = MODEL
    probs = router(g, jnp.asarray(w[f"layer{layer}.router"]))  # [N, E]
    topv, topi = jax.lax.top_k(probs, m.top_k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)        # renormalize
    out = jnp.zeros_like(g)
    for e in range(m.experts):
        pe = f"layer{layer}.expert{e}."
        y = ref.swiglu_ffn_ref(
            g, jnp.asarray(w[pe + "w1"]), jnp.asarray(w[pe + "w3"]),
            jnp.asarray(w[pe + "w2"]))
        weight = jnp.sum(jnp.where(topi == e, topv, 0.0), axis=-1)  # [N]
        out = out + weight[:, None] * y
    return out


def reference_generate(prompt_ids, n_decode: int, w: dict):
    """Greedy generation with the dense reference pipeline.

    prompt_ids: list[int]; returns list[int] of n_decode generated ids.
    Mirrors exactly what the Rust cluster computes (same top-k tie-break:
    jax.lax.top_k is stable by index, as is the Rust router).
    """
    m = MODEL
    embed = jnp.asarray(w["embed"])
    t = len(prompt_ids)
    x = embed[jnp.asarray(prompt_ids, dtype=jnp.int32)]        # [T, H]

    k_caches = []   # per layer, growing [cur_len, kv, d]
    v_caches = []
    for layer in range(m.layers):
        h, g, k, v = attn_prefill(x, *layer_weights(w, layer))
        moe = _moe_block(g, w, layer)
        x = h + moe
        k_caches.append(k)
        v_caches.append(v)

    out_ids = []
    last = x[-1:]                                               # [1, H]
    logits = lm_head(last, jnp.asarray(w["ln_f"]), jnp.asarray(w["lm_head"]))
    next_id = int(jnp.argmax(logits[0]))
    out_ids.append(next_id)

    for step in range(1, n_decode):
        pos = t + step - 1                                      # cache length
        x = embed[jnp.asarray([next_id], dtype=jnp.int32)]      # [1, H]
        for layer in range(m.layers):
            kc = k_caches[layer][None, ...]                     # [1, pos, kv, d]
            vc = v_caches[layer][None, ...]
            h, g, k_new, v_new = attn_decode(
                x, kc, vc, jnp.asarray([pos], dtype=jnp.int32),
                *layer_weights(w, layer))
            k_caches[layer] = jnp.concatenate([k_caches[layer], k_new], axis=0)
            v_caches[layer] = jnp.concatenate([v_caches[layer], v_new], axis=0)
            moe = _moe_block(g, w, layer)
            x = h + moe
        logits = lm_head(x, jnp.asarray(w["ln_f"]), jnp.asarray(w["lm_head"]))
        next_id = int(jnp.argmax(logits[0]))
        out_ids.append(next_id)
    return out_ids
