"""L1 Pallas kernels: flash-style attention (prefill + decode).

The paper's AW hot-spot is vLLM's paged-attention CUDA kernel. The TPU
rethink (DESIGN.md §7):

- *decode*: flash-decoding — the grid walks KV-cache blocks resident in
  HBM, staging one [block_s, kv, d] tile into VMEM per step and keeping an
  online-softmax state (running max / denominator / f32 accumulator) in
  scratch, so nothing of size S*S is ever materialized. The current token's
  K/V (not yet written to the cache) are folded into the online softmax in
  the final grid step — this is what lets the Rust AW run attention and
  cache-append as a single artifact call.
- *prefill*: classic flash attention with a causal mask, grid over
  (head, q-block, k-block).

Both kernels use `interpret=True` (CPU PJRT cannot run Mosaic custom-calls;
interpret-mode lowers to plain HLO the Rust runtime executes).

Masking uses -1e30 rather than -inf so fully-masked tiles stay NaN-free.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Decode: one query token per request against a padded KV cache
# ---------------------------------------------------------------------------

def _decode_kernel(q_ref, kc_ref, vc_ref, kn_ref, vn_ref, pos_ref,
                   o_ref, m_scr, l_scr, acc_scr, *, block_s, group, scale):
    s_idx = pl.program_id(1)
    n_s = pl.num_programs(1)

    @pl.when(s_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                     # [heads, d]
    k = kc_ref[0]                    # [block_s, kv, d]
    v = vc_ref[0]
    pos = pos_ref[0]                 # scalar: valid cache length for this row
    base = s_idx * block_s

    kx = jnp.repeat(k, group, axis=1)   # [block_s, heads, d]  (GQA broadcast)
    vx = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("hd,shd->hs", q, kx) * scale        # [heads, block_s]
    valid = (base + jax.lax.iota(jnp.int32, block_s)) < pos  # [block_s]
    scores = jnp.where(valid[None, :], scores, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.einsum("hs,shd->hd", p, vx)
    m_scr[...] = m_new

    @pl.when(s_idx == n_s - 1)
    def _finalize():
        # Fold in the current token's K/V (logically at cache index `pos`).
        k_cur = jnp.repeat(kn_ref[0], group, axis=0)   # [heads, d]
        v_cur = jnp.repeat(vn_ref[0], group, axis=0)
        s_cur = jnp.sum(q * k_cur, axis=-1) * scale    # [heads]
        m_fin = jnp.maximum(m_scr[...], s_cur)
        alpha2 = jnp.exp(m_scr[...] - m_fin)
        e_cur = jnp.exp(s_cur - m_fin)
        denom = l_scr[...] * alpha2 + e_cur
        out = acc_scr[...] * alpha2[:, None] + e_cur[:, None] * v_cur
        o_ref[0] = out / denom[:, None]


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention(q, k_cache, v_cache, k_new, v_new, pos, block_s: int = 32):
    """Flash-decoding. See kernels/ref.py::decode_attention_ref for shapes."""
    b, heads, d = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    group = heads // kv
    bs = min(block_s, s)
    while s % bs != 0:
        bs -= 1
    grid = (b, s // bs)
    kernel = functools.partial(
        _decode_kernel, block_s=bs, group=group, scale=1.0 / (d ** 0.5)
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, heads, d), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, bs, kv, d), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1, bs, kv, d), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1, kv, d), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, kv, d), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1,), lambda bi, si: (bi,)),
        ],
        out_specs=pl.BlockSpec((1, heads, d), lambda bi, si: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, heads, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((heads,), jnp.float32),
            pltpu.VMEM((heads,), jnp.float32),
            pltpu.VMEM((heads, d), jnp.float32),
        ],
        interpret=True,
    )(q, k_cache, v_cache, k_new, v_new, pos)


# ---------------------------------------------------------------------------
# Prefill: causal flash attention over the whole prompt
# ---------------------------------------------------------------------------

def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                    *, block_q, block_k, scale):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[:, 0, :]               # [block_q, d]
    k = k_ref[:, 0, :]               # [block_k, d]
    v = v_ref[:, 0, :]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
    causal = q_pos[:, None] >= k_pos[None, :]
    scores = jnp.where(causal, scores, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])
    p = jnp.where(causal, p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[:, 0, :] = acc_scr[...] / l_scr[...][:, None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def prefill_attention(q, k, v, block_q: int = 32, block_k: int = 32):
    """Causal flash attention. q: [T,heads,d], k/v: [T,kv,d] -> [T,heads,d]."""
    t, heads, d = q.shape
    kv = k.shape[1]
    group = heads // kv
    bq = min(block_q, t)
    while t % bq != 0:
        bq -= 1
    bk = min(block_k, t)
    while t % bk != 0:
        bk -= 1
    grid = (heads, t // bq, t // bk)
    kernel = functools.partial(
        _prefill_kernel, block_q=bq, block_k=bk, scale=1.0 / (d ** 0.5)
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 1, d), lambda h, iq, ik: (iq, h, 0)),
            pl.BlockSpec((bk, 1, d), lambda h, iq, ik: (ik, h // group, 0)),
            pl.BlockSpec((bk, 1, d), lambda h, iq, ik: (ik, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, d), lambda h, iq, ik: (iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((t, heads, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
