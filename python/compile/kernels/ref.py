"""Pure-jnp reference oracle for every Pallas kernel (L1 correctness).

These functions are the ground truth the pytest + hypothesis suites compare
the Pallas kernels against. They are deliberately written in the most
obvious dense form (materializing full score matrices etc.) so that any
cleverness lives only in the kernels.
"""

import jax.numpy as jnp


def silu(x):
    """SiLU / swish activation."""
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def swiglu_ffn_ref(x, w1, w3, w2):
    """SwiGLU expert FFN: (silu(x@w1) * (x@w3)) @ w2.

    x: [B, H], w1: [H, F], w3: [H, F], w2: [F, H] -> [B, H]
    """
    a = x @ w1
    g = x @ w3
    return (silu(a) * g) @ w2


def rms_norm_ref(x, gamma, eps=1e-5):
    """RMSNorm over the last axis. x: [..., H], gamma: [H]."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * gamma


def rope_ref(x, positions, theta=10000.0):
    """Rotary embedding (rotate-half convention).

    x: [..., n_heads, head_dim]; positions broadcastable to x.shape[:-2].
    """
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    cos = jnp.cos(ang)[..., None, :]  # [..., 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def prefill_attention_ref(q, k, v):
    """Causal multi-head attention over a full prompt (GQA: kv broadcast).

    q: [T, n_heads, d], k, v: [T, n_kv, d] -> [T, n_heads, d]
    """
    t, n_heads, d = q.shape
    n_kv = k.shape[1]
    group = n_heads // n_kv
    kx = jnp.repeat(k, group, axis=1)  # [T, n_heads, d]
    vx = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    scores = jnp.einsum("qhd,khd->hqk", q, kx) * scale  # [n_heads, T, T]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", probs, vx)


def decode_attention_ref(q, k_cache, v_cache, k_new, v_new, pos):
    """Single-token attention against a padded KV cache.

    q:       [B, n_heads, d]   query for the current token
    k_cache: [B, S, n_kv, d]   valid entries are [0, pos_b) per batch row
    v_cache: [B, S, n_kv, d]
    k_new:   [B, n_kv, d]      current token's projections (not yet in cache)
    v_new:   [B, n_kv, d]
    pos:     [B] int32         number of valid cache entries per row
    returns  [B, n_heads, d]
    """
    b, n_heads, d = q.shape
    s = k_cache.shape[1]
    n_kv = k_cache.shape[2]
    group = n_heads // n_kv
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    kx = jnp.repeat(k_cache, group, axis=2)               # [B, S, n_heads, d]
    vx = jnp.repeat(v_cache, group, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q, kx) * scale   # [B, n_heads, S]
    idx = jnp.arange(s)[None, :]                          # [1, S]
    valid = idx < pos[:, None]                            # [B, S]
    scores = jnp.where(valid[:, None, :], scores, -jnp.inf)
    k_cur = jnp.repeat(k_new, group, axis=1)              # [B, n_heads, d]
    v_cur = jnp.repeat(v_new, group, axis=1)
    s_cur = jnp.einsum("bhd,bhd->bh", q, k_cur) * scale   # [B, n_heads]
    m = jnp.maximum(jnp.max(scores, axis=-1), s_cur)      # [B, n_heads]
    e = jnp.exp(scores - m[..., None])
    e = jnp.where(valid[:, None, :], e, 0.0)
    e_cur = jnp.exp(s_cur - m)
    denom = jnp.sum(e, axis=-1) + e_cur
    out = jnp.einsum("bhs,bshd->bhd", e, vx) + e_cur[..., None] * v_cur
    return out / denom[..., None]


def router_ref(g, wg):
    """Gating network: softmax over expert logits. g: [B, H], wg: [H, E]."""
    logits = g @ wg
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
