"""L1 Pallas kernel: tiled SwiGLU expert FFN.

This is the EW-side compute hot-spot of the paper (libtorch CUDA FFN in the
original). Rethought for TPU rather than ported (DESIGN.md §7):

- the grid tiles (batch, ffn) so each step stages an x-tile plus one
  column-tile of w1/w3 (and the matching row-tile of w2) from HBM into
  VMEM via BlockSpec — the TPU analogue of the paper's threadblock tiling;
- both matmuls and the SwiGLU gate are fused in one kernel so the [bm, bf]
  activation tile never round-trips to HBM;
- the output tile is accumulated in f32 across the ffn grid axis
  (revisited output block), which is the canonical Pallas reduction.

``interpret=True`` is mandatory here: CPU PJRT cannot execute Mosaic
custom-calls, and interpret-mode lowers the kernel to plain HLO that the
Rust runtime's CPU client can run (see /opt/xla-example/README.md).

VMEM budget at full scale (H=4096, F=14336, bm=128, bf=512, bf16):
x-tile 1 MiB + w1/w3 tiles 4 MiB each + w2 tile 4 MiB + acc 1 MiB
≈ 14 MiB < 16 MiB VMEM with double-buffering of the weight streams
disabled, or bf=256 with it enabled. At mixtral-tiny scale the tiles are
chosen with the same divisibility rules so the structure is identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    """One (m, f) grid step: o[m] += swiglu(x[m] @ w1[:, f], x[m] @ w3[:, f]) @ w2[f, :]."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                       # [bm, H]
    a = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)  # [bm, bf]
    g = jnp.dot(x, w3_ref[...], preferred_element_type=jnp.float32)  # [bm, bf]
    h = (a * (1.0 / (1.0 + jnp.exp(-a)))) * g                        # SwiGLU gate
    o_ref[...] += jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)


def pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (power-of-2 dims)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_m", "block_f"))
def swiglu_ffn(x, w1, w3, w2, block_m: int = 64, block_f: int = 128):
    """SwiGLU FFN as a Pallas call. Shapes: x [B,H], w1/w3 [H,F], w2 [F,H]."""
    b, h = x.shape
    f = w1.shape[1]
    bm = pick_block(b, block_m)
    bf = pick_block(f, block_f)
    grid = (b // bm, f // bf)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, h), lambda m, fi: (m, 0)),   # x tile
            pl.BlockSpec((h, bf), lambda m, fi: (0, fi)),  # w1 column tile
            pl.BlockSpec((h, bf), lambda m, fi: (0, fi)),  # w3 column tile
            pl.BlockSpec((bf, h), lambda m, fi: (fi, 0)),  # w2 row tile
        ],
        out_specs=pl.BlockSpec((bm, h), lambda m, fi: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h), jnp.float32),
        interpret=True,
    )(x, w1, w3, w2)
