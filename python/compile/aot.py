"""AOT pipeline: lower every artifact to HLO text + emit weights/manifest.

Run once at build time (`make artifacts`); Python never runs on the request
path. Outputs under ``artifacts/``:

  manifest.json     model config, buckets, artifact specs, weight table
  weights.bin       all parameters as one little-endian f32 blob
  golden.json       reference generation fixture (prompt -> token ids),
                    produced by the pure-jnp oracle; the Rust integration
                    suite replays it through the full cluster
  *.hlo.txt         one HLO-text module per (artifact kind, shape bucket)

HLO *text* (not serialized HloModuleProto) is the interchange format: the
xla crate's xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids,
while the text parser reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .configs import MODEL, BUCKETS, WEIGHT_SEED, model_dict, buckets_dict

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    """jax lowering -> XLA HLO text via stablehlo (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io(name, shape, dtype=F32):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def build_artifact_plan():
    """Every artifact: (name, kind, bucket, jax fn, input specs, output specs).

    Input order here *is* the call ABI the Rust runtime uses.
    """
    m = MODEL
    h, kvh, d, s, e, f, v = (m.hidden, m.kv_heads, m.head_dim, m.max_seq,
                             m.experts, m.ffn, m.vocab)
    plan = []

    attn_w = [
        _io("wq", (h, h)), _io("wk", (h, kvh * d)), _io("wv", (h, kvh * d)),
        _io("wo", (h, h)), _io("ln1", (h,)), _io("ln2", (h,)),
    ]
    attn_w_specs = [spec((h, h)), spec((h, kvh * d)), spec((h, kvh * d)),
                    spec((h, h)), spec((h,)), spec((h,))]

    for t in BUCKETS.prefill_t:
        plan.append(dict(
            name=f"attn_prefill_t{t}", kind="attn_prefill", bucket=t,
            fn=model.attn_prefill,
            in_specs=[spec((t, h))] + attn_w_specs,
            inputs=[_io("x", (t, h))] + attn_w,
            outputs=[_io("h", (t, h)), _io("g", (t, h)),
                     _io("k", (t, kvh, d)), _io("v", (t, kvh, d))],
        ))

    for b in BUCKETS.decode_b:
        plan.append(dict(
            name=f"attn_decode_b{b}", kind="attn_decode", bucket=b,
            fn=model.attn_decode,
            in_specs=[spec((b, h)), spec((b, s, kvh, d)), spec((b, s, kvh, d)),
                      spec((b,), jnp.int32)] + attn_w_specs,
            inputs=[_io("x", (b, h)), _io("k_cache", (b, s, kvh, d)),
                    _io("v_cache", (b, s, kvh, d)), _io("pos", (b,), I32)]
                   + attn_w,
            outputs=[_io("h", (b, h)), _io("g", (b, h)),
                     _io("k_new", (b, kvh, d)), _io("v_new", (b, kvh, d))],
        ))

    for b in BUCKETS.router_b(MODEL):
        plan.append(dict(
            name=f"router_b{b}", kind="router", bucket=b,
            fn=model.router,
            in_specs=[spec((b, h)), spec((h, e))],
            inputs=[_io("g", (b, h)), _io("wg", (h, e))],
            outputs=[_io("probs", (b, e))],
        ))

    for b in BUCKETS.expert_b:
        plan.append(dict(
            name=f"expert_b{b}", kind="expert", bucket=b,
            fn=model.expert_ffn,
            in_specs=[spec((b, h)), spec((h, f)), spec((h, f)), spec((f, h))],
            inputs=[_io("x", (b, h)), _io("w1", (h, f)), _io("w3", (h, f)),
                    _io("w2", (f, h))],
            outputs=[_io("y", (b, h))],
        ))

    for b in BUCKETS.lm_head_b:
        plan.append(dict(
            name=f"lm_head_b{b}", kind="lm_head", bucket=b,
            fn=model.lm_head,
            in_specs=[spec((b, h)), spec((h,)), spec((h, v))],
            inputs=[_io("h", (b, h)), _io("ln_f", (h,)), _io("wlm", (h, v))],
            outputs=[_io("logits", (b, v))],
        ))
    return plan


def write_weights(out_dir: str, weights: dict) -> dict:
    """Concatenate all tensors into weights.bin; return the offset table."""
    table = []
    offset = 0
    blob_path = os.path.join(out_dir, "weights.bin")
    with open(blob_path, "wb") as fh:
        for name, arr in weights.items():
            data = np.ascontiguousarray(arr, dtype=np.float32).tobytes()
            fh.write(data)
            table.append({
                "name": name, "shape": list(arr.shape),
                "offset": offset, "nbytes": len(data), "dtype": F32,
            })
            offset += len(data)
    return {"file": "weights.bin", "total_bytes": offset, "tensors": table}


def write_golden(out_dir: str, weights: dict):
    """Golden generation fixture for the Rust integration tests."""
    cases = []
    for prompt, n_dec in [([1, 2, 3, 4, 5, 6, 7, 8], 12),
                          ([42, 17, 300, 9], 8)]:
        ids = model.reference_generate(prompt, n_dec, weights)
        cases.append({"prompt": prompt, "generated": ids})
    with open(os.path.join(out_dir, "golden.json"), "w") as fh:
        json.dump({"cases": cases}, fh, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (default: ../artifacts)")
    ap.add_argument("--skip-golden", action="store_true",
                    help="skip the (slow) golden-fixture generation")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    weights = model.generate_weights(WEIGHT_SEED)
    weight_meta = write_weights(out_dir, weights)
    print(f"weights.bin: {weight_meta['total_bytes']} bytes, "
          f"{len(weight_meta['tensors'])} tensors")

    artifacts_meta = []
    for art in build_artifact_plan():
        t0 = time.time()
        lowered = jax.jit(art["fn"]).lower(*art["in_specs"])
        text = to_hlo_text(lowered)
        fname = art["name"] + ".hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        artifacts_meta.append({
            "name": art["name"], "kind": art["kind"], "bucket": art["bucket"],
            "file": fname, "inputs": art["inputs"], "outputs": art["outputs"],
        })
        print(f"  {art['name']:<20} {len(text):>9} chars  "
              f"({time.time() - t0:.2f}s)")

    manifest = {
        "version": 1,
        "model": model_dict(),
        "buckets": buckets_dict(),
        "weight_seed": WEIGHT_SEED,
        "artifacts": artifacts_meta,
        "weights": weight_meta,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"manifest.json: {len(artifacts_meta)} artifacts")

    if not args.skip_golden:
        t0 = time.time()
        write_golden(out_dir, weights)
        print(f"golden.json ({time.time() - t0:.1f}s)")


if __name__ == "__main__":
    main()
