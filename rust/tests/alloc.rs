//! Allocation contract of the decode hot path (DESIGN.md §10), pinned
//! with a counting `#[global_allocator]`:
//!
//! 1. a steady-state decode step — embed → RMSNorm/QKV/RoPE → paged
//!    attention → router → top-k select → dispatch build (row views) →
//!    EW bucket staging → expert FFN → return views → slot-ordered
//!    accumulation → LM head — performs **zero** heap allocations once
//!    arenas and capacities are warm, under **both** kernel backends
//!    (`reference` and `simd`; DESIGN.md §12);
//! 2. checkpoint segment emit and request restore stay **bounded**
//!    (O(1) allocations per segment / per page, never per float).
//!
//! The harness drives the same public kernels and data structures the
//! cluster hot path uses (`runtime::xla::kern`, `PagedKv` reads of the
//! `KvPool`, `proto::DispatchEntry` row views, `tensor` scratch arena),
//! single-threaded so the process-global counters are attributable.
//!
//! **Scope.** The hard zero covers the decode *data path* — everything
//! whose cost scales with hidden dim, context, or batch floats — AND
//! the per-step page-table gather: `gather_paged` recycles its view
//! storage (`Arc::get_mut` reclamation), so the steady-state step
//! drives the real assembler at zero allocations too. The threaded
//! coordinator still adds bounded control metadata on top
//! (`DispatchEntry` shells, channel nodes): O(batch x experts) words
//! per layer, independent of tensor sizes — measured as allocs/token
//! by `benches/decode.rs`. See DESIGN.md §10.
//!
//! Everything lives in ONE #[test]: a second parallel test would
//! pollute the global allocation counters.

use std::sync::Arc;
use tarragon::kvcache::{BatchAssembler, KvPool, PoolConfig, RequestKv};
use tarragon::metrics::trace::{SpanKind, Tracer};
use tarragon::modelcfg::ModelSpec;
use tarragon::proto::DispatchEntry;
use tarragon::runtime::xla::kern;
use tarragon::runtime::xla::kern::KernelBackend;
use tarragon::tensor::{ops, scratch, Tensor};
use tarragon::testing::alloccount::{allocations_during, CountingAlloc};
use tarragon::util::rng::Pcg;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

const RMS_EPS: f32 = 1e-5;
const ROPE_THETA: f32 = 10000.0;

// Tiny decode cluster: batch 2, 2 layers, GQA 2:1, 4 experts / top-2.
const B: usize = 2;
const LAYERS: usize = 2;
const H: usize = 32;
const HEADS: usize = 2;
const KV: usize = 1;
const D: usize = 16;
const KVD: usize = KV * D;
const F: usize = 64;
const E: usize = 4;
const VOCAB: usize = 64;
const S_MAX: usize = 64;
const PAGE_TOKENS: usize = 16;
const EXPERT_BUCKET: usize = 4;
const INIT_LEN: usize = 8;
const MAX_STEPS: usize = 24;

fn mspec() -> ModelSpec {
    ModelSpec {
        layers: LAYERS,
        hidden: H,
        heads: HEADS,
        kv_heads: KV,
        head_dim: D,
        ffn: F,
        experts: E,
        top_k: 2,
        vocab: VOCAB,
        max_seq: S_MAX,
    }
}

fn rand_vec(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() - 0.5) * 0.4).collect()
}

/// A weight and its precomputed transpose (the executor memoizes this
/// per resident buffer; the harness holds it directly).
struct Wt {
    t: Vec<f32>,
}

fn wt(rng: &mut Pcg, k: usize, m: usize) -> Wt {
    let w = rand_vec(rng, k * m);
    Wt { t: kern::transpose(&w, k, m) }
}

struct Harness {
    /// Kernel backend driving every FLOP of the step (the zero-alloc
    /// contract must hold whichever backend a device selects).
    bk: &'static dyn kern::KernelBackend,
    // weights (transposed where matmul'd)
    embed: Vec<f32>,
    wq: Vec<Wt>,
    wk: Vec<Wt>,
    wv: Vec<Wt>,
    wo: Vec<Wt>,
    ln1: Vec<Vec<f32>>,
    ln2: Vec<Vec<f32>>,
    wg: Vec<Wt>,
    w1: Vec<Vec<Wt>>, // [layer][expert]
    w3: Vec<Vec<Wt>>,
    w2: Vec<Vec<Wt>>,
    ln_f: Vec<f32>,
    lm: Wt,
    freqs: Vec<f32>,
    // KV state (pages reserved up front: steady state never allocates)
    pool: Arc<KvPool>,
    kvs: Vec<RequestKv>,
    /// The real per-step gather: recycled view storage, zero allocs warm.
    asm: BatchAssembler,
    gpos: Vec<i32>, // gather_paged's reusable position scratch
    pos: Vec<i32>,
    len: usize,
    next_tok: Vec<u32>,
    // reusable per-step buffers (capacities retained across steps)
    groups: Vec<Vec<(usize, f32)>>, // [expert] -> (row, gate)
    slot_info: Vec<(usize, f32)>,
    slot_out: Vec<Option<Tensor>>,
    dispatch: Vec<DispatchEntry>,
    ret: Vec<DispatchEntry>,
}

impl Harness {
    fn new(bk: &'static dyn kern::KernelBackend) -> Harness {
        let m = mspec();
        let mut rng = Pcg::seeded(0xA110C);
        let pool = KvPool::new(PoolConfig { page_tokens: PAGE_TOKENS, seg: KVD });
        let mut kvs: Vec<RequestKv> = (0..B).map(|_| RequestKv::new(&m, &pool)).collect();
        for r in kvs.iter_mut() {
            // Reserve every page the run will touch, then fill the
            // initial context — decode steps only write into slots.
            r.reserve(INIT_LEN + MAX_STEPS + 1);
            for layer in 0..LAYERS {
                for t in 0..INIT_LEN {
                    let k = rand_vec(&mut rng, KVD);
                    let v = rand_vec(&mut rng, KVD);
                    r.write(layer, t, &k, &v);
                }
            }
            r.set_len(INIT_LEN);
        }
        let per_layer = |rng: &mut Pcg, k: usize, mm: usize| -> Vec<Wt> {
            (0..LAYERS).map(|_| wt(rng, k, mm)).collect()
        };
        let per_expert = |rng: &mut Pcg, k: usize, mm: usize| -> Vec<Vec<Wt>> {
            (0..LAYERS).map(|_| (0..E).map(|_| wt(rng, k, mm)).collect()).collect()
        };
        Harness {
            bk,
            embed: rand_vec(&mut rng, VOCAB * H),
            wq: per_layer(&mut rng, H, H),
            wk: per_layer(&mut rng, H, KVD),
            wv: per_layer(&mut rng, H, KVD),
            wo: per_layer(&mut rng, H, H),
            ln1: (0..LAYERS).map(|_| vec![1.0; H]).collect(),
            ln2: (0..LAYERS).map(|_| vec![1.0; H]).collect(),
            wg: per_layer(&mut rng, H, E),
            w1: per_expert(&mut rng, H, F),
            w3: per_expert(&mut rng, H, F),
            w2: per_expert(&mut rng, F, H),
            ln_f: vec![1.0; H],
            lm: wt(&mut rng, H, VOCAB),
            freqs: kern::rope_freqs(D, ROPE_THETA),
            pool,
            kvs,
            asm: BatchAssembler::new(&m),
            gpos: Vec::with_capacity(B),
            pos: vec![INIT_LEN as i32; B],
            len: INIT_LEN,
            next_tok: vec![3; B],
            // Worst-case capacities up front: an expert can receive every
            // row, and routing varies step to step — capacity growth mid-
            // measurement would count as an allocation.
            groups: (0..E).map(|_| Vec::with_capacity(B)).collect(),
            slot_info: Vec::with_capacity(B * 2),
            slot_out: Vec::with_capacity(B * 2),
            dispatch: (0..E)
                .map(|e| DispatchEntry {
                    expert: e as u16,
                    rows: Vec::with_capacity(B),
                    slots: Vec::with_capacity(B),
                })
                .collect(),
            ret: (0..E)
                .map(|e| DispatchEntry {
                    expert: e as u16,
                    rows: Vec::with_capacity(B),
                    slots: Vec::with_capacity(B),
                })
                .collect(),
        }
    }

    /// One full decode step over the AW→REFE→EW→REFE→AW data path.
    fn step(&mut self) {
        assert!(self.len < INIT_LEN + MAX_STEPS, "harness exceeded reserved pages");
        // ---- AW: embed previous tokens --------------------------------
        let mut x = Tensor::uninit([B, H]);
        {
            let xd = x.data_mut();
            for i in 0..B {
                let tok = self.next_tok[i] as usize;
                xd[i * H..(i + 1) * H].copy_from_slice(&self.embed[tok * H..(tok + 1) * H]);
            }
        }
        for layer in 0..LAYERS {
            // ---- attention (paged reads, blocked matmuls) -------------
            let bk = self.bk;
            let mut n_t = Tensor::uninit([B, H]);
            bk.rms_norm_into(x.data(), &self.ln1[layer], B, H, RMS_EPS, n_t.data_mut());
            let mut q = Tensor::uninit([B, H]);
            bk.matmul_wt_into(n_t.data(), &self.wq[layer].t, B, H, H, q.data_mut());
            let mut k_new = Tensor::uninit([B, KVD]);
            bk.matmul_wt_into(n_t.data(), &self.wk[layer].t, B, H, KVD, k_new.data_mut());
            let mut v_new = Tensor::uninit([B, KVD]);
            bk.matmul_wt_into(n_t.data(), &self.wv[layer].t, B, H, KVD, v_new.data_mut());
            let pos = &self.pos;
            bk.rope_with_freqs(q.data_mut(), B, HEADS, D, &self.freqs, &|i: usize| pos[i] as f32);
            bk.rope_with_freqs(k_new.data_mut(), B, KV, D, &self.freqs, &|i: usize| pos[i] as f32);
            let mut attn = Tensor::zeros([B, H]);
            let mut scores = Tensor::uninit([S_MAX]);
            {
                // Per-step page-table gather through the real assembler —
                // the view recycles its storage, so this is part of the
                // zero-allocation contract. Dropped at block end so the
                // next layer's gather can reclaim the buffer in place.
                let view = {
                    let refs: [&RequestKv; B] = [&self.kvs[0], &self.kvs[1]];
                    self.asm.gather_paged(&self.pool, &refs, layer, B, &mut self.gpos)
                };
                debug_assert_eq!(self.gpos, self.pos);
                let read = self.pool.read();
                let src = kern::PagedKv {
                    read: &read,
                    tables: view.tables.as_slice(),
                    d: D,
                };
                bk.attn_decode_into(
                    q.data(),
                    k_new.data(),
                    v_new.data(),
                    &self.pos,
                    &src,
                    B,
                    HEADS,
                    KV,
                    D,
                    S_MAX,
                    scores.data_mut(),
                    attn.data_mut(),
                );
            }
            // Append this step's KV (read lock released above).
            for i in 0..B {
                self.kvs[i].write(layer, self.len, k_new.row(i), v_new.row(i));
            }
            let mut proj = Tensor::uninit([B, H]);
            bk.matmul_wt_into(attn.data(), &self.wo[layer].t, B, H, H, proj.data_mut());
            let mut h_out = Tensor::uninit([B, H]);
            for ((o, a), p) in h_out.data_mut().iter_mut().zip(x.data()).zip(proj.data()) {
                *o = a + p;
            }
            let mut g = Tensor::uninit([B, H]);
            bk.rms_norm_into(h_out.data(), &self.ln2[layer], B, H, RMS_EPS, g.data_mut());
            // ---- router + top-2 select (reusable buffers) -------------
            let mut logits = Tensor::uninit([B, E]);
            bk.matmul_wt_into(g.data(), &self.wg[layer].t, B, H, E, logits.data_mut());
            bk.softmax_rows(logits.data_mut(), B, E);
            for ge in self.groups.iter_mut() {
                ge.clear();
            }
            for i in 0..B {
                let row = logits.row(i);
                let mut b0 = 0usize;
                for (j, &p) in row.iter().enumerate() {
                    if p > row[b0] {
                        b0 = j;
                    }
                }
                let mut b1 = usize::MAX;
                for (j, &p) in row.iter().enumerate() {
                    if j != b0 && (b1 == usize::MAX || p > row[b1]) {
                        b1 = j;
                    }
                }
                let (p0, p1) = (row[b0], row[b1]);
                let sum = p0 + p1;
                self.groups[b0].push((i, p0 / sum));
                self.groups[b1].push((i, p1 / sum));
            }
            // ---- REFE dispatch build: row views, no copies ------------
            self.slot_info.clear();
            for e in 0..E {
                let entry = &mut self.dispatch[e];
                entry.rows.clear();
                entry.slots.clear();
                for &(row, w) in &self.groups[e] {
                    entry.slots.push(self.slot_info.len() as u32);
                    self.slot_info.push((row, w));
                    entry.rows.push(g.row_tensor(row));
                }
                assert!(
                    entry.rows.iter().all(|r| r.shares_storage(&g)),
                    "dispatch rows must view the activation tensor"
                );
            }
            self.slot_out.clear();
            self.slot_out.resize_with(self.slot_info.len(), || None);
            // ---- EW: bucket staging + expert FFN + return views -------
            for e in 0..E {
                let n = self.dispatch[e].slots.len();
                if n == 0 {
                    continue;
                }
                let mut xe = Tensor::zeros([EXPERT_BUCKET, H]);
                {
                    let xd = xe.data_mut();
                    for (j, r) in self.dispatch[e].rows.iter().enumerate() {
                        xd[j * H..(j + 1) * H].copy_from_slice(r.data());
                    }
                }
                let (w1t, w3t, w2t) =
                    (&self.w1[layer][e].t, &self.w3[layer][e].t, &self.w2[layer][e].t);
                let mut a = Tensor::uninit([EXPERT_BUCKET, F]);
                bk.matmul_wt_into(xe.data(), w1t, EXPERT_BUCKET, H, F, a.data_mut());
                let mut gate = Tensor::uninit([EXPERT_BUCKET, F]);
                bk.matmul_wt_into(xe.data(), w3t, EXPERT_BUCKET, H, F, gate.data_mut());
                bk.silu_mul(a.data_mut(), gate.data());
                let mut y = Tensor::uninit([EXPERT_BUCKET, H]);
                bk.matmul_wt_into(a.data(), w2t, EXPERT_BUCKET, F, H, y.data_mut());
                let ret = &mut self.ret[e];
                ret.rows.clear();
                ret.slots.clear();
                for j in 0..n {
                    ret.rows.push(y.row_tensor(j));
                }
                ret.slots.extend(self.dispatch[e].slots.iter().copied());
                assert!(
                    ret.rows.iter().all(|r| r.shares_storage(&y)),
                    "return rows must view the kernel output"
                );
                // ---- REFE gather: buffer views per slot ---------------
                for (j, &s) in ret.slots.iter().enumerate() {
                    self.slot_out[s as usize] = Some(ret.rows[j].clone());
                }
            }
            // ---- canonical slot-ordered accumulation ------------------
            for s in 0..self.slot_info.len() {
                if let Some(out) = &self.slot_out[s] {
                    let (row, w) = self.slot_info[s];
                    ops::axpy_row(h_out.row_mut(row), w, out.data());
                }
            }
            x = h_out;
        }
        // ---- LM head ---------------------------------------------------
        let bk = self.bk;
        let mut normed = Tensor::uninit([B, H]);
        bk.rms_norm_into(x.data(), &self.ln_f, B, H, RMS_EPS, normed.data_mut());
        let mut logits = Tensor::uninit([B, VOCAB]);
        bk.matmul_wt_into(normed.data(), &self.lm.t, B, H, VOCAB, logits.data_mut());
        for i in 0..B {
            self.next_tok[i] = ops::argmax(logits.row(i)) as u32;
        }
        self.len += 1;
        for i in 0..B {
            self.kvs[i].set_len(self.len);
            self.pos[i] = self.len as i32;
        }
    }
}

/// Park `count` blocks of exactly `len` floats in the shared arena, so a
/// measured step never sees a cold size class even when routing shifts
/// how many buffers of a class are live at once.
fn prewarm_class(len: usize, count: usize) {
    let held: Vec<Tensor> = (0..count).map(|_| Tensor::zeros([len])).collect();
    drop(held);
}

#[test]
fn hot_path_allocation_contract() {
    scratch::warm();
    // Every buffer size the step touches (S_MAX and B*H share class 64;
    // EXPERT_BUCKET*H and B*VOCAB share class 128), with headroom for
    // the worst simultaneous-live count.
    prewarm_class(B * H, 16);
    prewarm_class(B * KVD, 8);
    prewarm_class(B * E, 4);
    prewarm_class(EXPERT_BUCKET * H, 16);
    prewarm_class(EXPERT_BUCKET * F, 8);
    // 1. Steady state: zero heap allocations per decode step across the
    //    whole AW→REFE→EW→REFE→AW round trip — under BOTH kernel
    //    backends (warmup also covers one-time backend init such as the
    //    AVX2 feature probe and the rope-frequency memo) — WITH span
    //    tracing live: the ring is preallocated at handle registration,
    //    so recording a DecodeStep span per step is two clock reads
    //    plus a plain store.
    let tracer = Tracer::new(tarragon::util::clock::Clock::wall(), 64);
    let trace = tracer.handle(0);
    let steps = 8;
    let mut h = None;
    for kind in [kern::BackendKind::Reference, kern::BackendKind::Simd] {
        let bk = kern::backend(kind);
        let mut hb = Harness::new(bk);

        // Warmup: populate arena size classes and buffer capacities.
        for _ in 0..4 {
            hb.step();
        }

        let (allocs, _) = allocations_during(|| {
            for _ in 0..steps {
                let t0 = trace.start();
                hb.step();
                trace.record(SpanKind::DecodeStep, 0, B as u64, t0);
            }
        });
        assert_eq!(
            allocs,
            0,
            "steady-state decode must be allocation-free under the {} backend \
             ({allocs} allocations over {steps} steps, tracing enabled)",
            bk.name()
        );
        // The generation advanced and stayed in-vocab (the harness
        // computes real tokens, not dead code the optimizer could strip).
        assert!(hb.next_tok.iter().all(|&t| (t as usize) < VOCAB));
        assert_eq!(hb.len, INIT_LEN + 4 + steps);
        h = Some(hb);
    }
    let h = h.unwrap();
    // Every traced step landed in the preallocated ring (both backends).
    assert_eq!(tracer.snapshot().len(), 2 * steps);
    assert_eq!(tracer.dropped(), 0);

    // 2. Checkpoint emit: bounded — one payload Vec + one Arc control
    //    block per segment, nothing proportional to floats beyond the
    //    payload itself.
    let n_segs = (LAYERS * h.len) as u64;
    let (ckpt_allocs, segs) = allocations_during(|| {
        let mut v = Vec::with_capacity(LAYERS * h.len);
        for layer in 0..LAYERS {
            for t in 0..h.len {
                v.push((layer, t, h.kvs[0].segment_payload(layer, t)));
            }
        }
        v
    });
    assert!(
        ckpt_allocs <= 3 * n_segs + 8,
        "checkpoint emit must stay O(1) per segment: {ckpt_allocs} allocations \
         for {n_segs} segments"
    );

    // 3. Restore install: bounded by pages + layers, not by floats.
    let restore_len = h.len;
    let m = mspec();
    let (restore_allocs, restored) = allocations_during(|| {
        let mut r = RequestKv::new(&m, &h.pool);
        for (layer, t, seg) in &segs {
            r.write_segment(*layer, *t, seg.as_slice());
        }
        r.set_len(restore_len);
        r
    });
    let pages = restored.allocated_pages() as u64;
    assert_eq!(restored.len(), restore_len);
    assert!(
        restore_allocs <= 4 * pages + LAYERS as u64 + 16,
        "restore must stay O(1) per page: {restore_allocs} allocations for {pages} pages"
    );
    drop(restored);
}
