//! Control-plane failure scenarios (DESIGN.md §15) on the virtual clock:
//! replicated checkpoint stores, sharded gateways, and orchestrator
//! failover. Every test runs the full cluster under deterministic
//! virtual time and asserts the same guarantee the worker-failure matrix
//! does — the generated token streams are byte-identical to the
//! failure-free run — now with the control plane itself as the victim.

use std::time::Duration;
use tarragon::config::Config;
use tarragon::metrics::FailureClass;
use tarragon::testing::scenario::Scenario;
use tarragon::testing::synthetic;
use tarragon::util::chash;

const MAX_DETECT: Duration = Duration::from_millis(250);
const MAX_STALL: Duration = Duration::from_secs(2);

/// Scenario base: 2 AWs x 2 EWs at 1 ms wire latency, with the control
/// plane replicated — 2 checkpoint-store replicas, 2 gateway shards, and
/// a warm orchestrator standby.
fn control_cfg() -> Config {
    let mut cfg = Config::small_test();
    cfg.transport.latency = Duration::from_millis(1);
    cfg.transport.worker_extra_init = Duration::from_millis(200);
    cfg.cluster.num_stores = 2;
    cfg.cluster.num_gateways = 2;
    cfg.resilience.orch_standby = true;
    cfg
}

fn two_request_scenario(name: &str, cfg: Config) -> Scenario {
    Scenario::new(name, cfg)
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
}

fn assert_full_streams(faulty: &tarragon::testing::scenario::ScenarioOutcome, name: &str) {
    assert!(faulty.completed, "{name}: faulty run did not drain");
    for (id, toks) in &faulty.tokens {
        assert_eq!(toks.len(), 32, "{name}: req {id} truncated");
    }
}

// ---------------------------------------------------------------------------
// Replicated checkpoint store
// ---------------------------------------------------------------------------

#[test]
fn store_replica_kill_mid_run_keeps_streams_identical() {
    let (manifest, weights, _) = synthetic::ensure();
    // AWs fan every commit out to both replicas, so killing one mid-run
    // loses nothing durable; decode never even stalls.
    let s = two_request_scenario("store-kill", control_cfg()).fault("at 60ms kill store0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "store-kill");
    assert_eq!(faulty.tokens, clean.tokens, "store failover changed token streams");
    assert!(faulty.report.store_failovers >= 1, "store death went undetected");
    assert_eq!(faulty.report.aw_failures, 0);
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
    assert!(
        faulty.recovery.incidents.iter().any(|i| i.class == FailureClass::Store),
        "store kill must attribute as a store incident:\n{}",
        faulty.recovery.render()
    );
    assert!(clean.recovery.is_empty(), "failure-free run must have no incidents");
    assert_eq!(clean.report.store_replica_lag, 0, "healthy replicas must agree");
}

#[test]
fn restore_pull_survives_store_failover_during_aw_recovery() {
    let (manifest, weights, _) = synthetic::ensure();
    // aw0 dies mid-decode; while its requests are being adopted and
    // restored, the store replica the orchestrator queried dies too. The
    // restore pull was fanned out to every replica, so the survivor
    // serves it (a replica that was still catching up parks the pull and
    // replays it) — whichever interleaving the clock produces, the
    // streams must not move.
    let s = two_request_scenario("store-failover-restore", control_cfg())
        .fault("at 60ms kill aw0")
        .fault("at 130ms kill store0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "store-failover-restore");
    assert_eq!(faulty.tokens, clean.tokens, "restore across store failover changed streams");
    assert!(faulty.report.aw_failures >= 1);
    assert!(faulty.report.store_failovers >= 1);
    faulty.assert_recovery(2, MAX_DETECT, MAX_STALL);
    let classes: Vec<_> = faulty.recovery.incidents.iter().map(|i| i.class).collect();
    assert!(
        classes.contains(&FailureClass::Aw) && classes.contains(&FailureClass::Store),
        "expected one AW and one store incident:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn store_death_before_aw_death_fails_queries_over_to_the_survivor() {
    let (manifest, weights, _) = synthetic::ensure();
    // Reverse order: the replica dies first, then the AW. The active-set
    // query and the restore must both route to the survivor.
    let s = two_request_scenario("store-first", control_cfg())
        .fault("at 40ms kill store0")
        .fault("at 90ms kill aw0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "store-first");
    assert_eq!(faulty.tokens, clean.tokens, "survivor-served recovery changed streams");
    assert!(faulty.report.store_failovers >= 1);
    assert!(faulty.report.aw_failures >= 1);
    faulty.assert_recovery(2, MAX_DETECT, MAX_STALL);
}

#[test]
fn respawned_store_resyncs_and_serves_after_the_peer_dies() {
    let (manifest, weights, _) = synthetic::ensure();
    // The strongest replication chain: store0 dies, is rebuilt empty and
    // anti-entropy-syncs from store1; then store1 dies, leaving the
    // *resynced* replica as the only store; then aw0 dies and every
    // restore must be served from state store0 only has via the re-sync.
    let s = two_request_scenario("store-resync", control_cfg())
        .fault("at 50ms kill store0")
        .fault("at 300ms respawn store0")
        .fault("at 400ms kill store1")
        .fault("at 500ms kill aw0")
        .request(2, Duration::from_millis(450), vec![21, 22, 23], 32);
    let mut s = s;
    s.drain_timeout = Duration::from_secs(90);
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_eq!(faulty.tokens, clean.tokens, "resynced-replica recovery changed streams");
    assert!(faulty.report.store_failovers >= 2, "both replica deaths must be detected");
    assert!(faulty.report.aw_failures >= 1);
}

#[test]
fn corrupt_page_index_degrades_restores_without_changing_streams() {
    let (manifest, weights, _) = synthetic::ensure();
    // The page_refs_missed degradation: drop the sole store's sealed-page
    // content index, then kill an AW. Restores can no longer resolve
    // prefill page references and must fall back to recompute/resubmit —
    // slower, but byte-identical. Single-replica config: the corruption
    // cannot be masked by a healthy peer.
    let mut cfg = control_cfg();
    cfg.cluster.num_stores = 1;
    cfg.cluster.num_gateways = 1;
    cfg.resilience.orch_standby = false;
    // One-page shared prompts so the commits actually carry page refs.
    let prompt: Vec<u32> = (1..=16).collect();
    let s = Scenario::new("corrupt-index", cfg)
        .request(0, Duration::ZERO, prompt.clone(), 32)
        .request(1, Duration::from_millis(5), prompt, 32)
        .fault("at 55ms corrupt_index store0")
        .fault("at 60ms kill aw0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "corrupt-index");
    assert_eq!(faulty.tokens, clean.tokens, "degraded restore changed token streams");
    assert!(faulty.report.aw_failures >= 1);
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
}

// ---------------------------------------------------------------------------
// Sharded gateway
// ---------------------------------------------------------------------------

#[test]
fn gateway_shard_kill_readmits_through_survivors_with_identical_streams() {
    let (manifest, weights, _) = synthetic::ensure();
    // Kill the shard that owns request 0 (rendezvous hash over the two
    // shards), mid-decode: its in-flight admissions re-admit through the
    // survivor, AWs replay recorded token history to the new owner, and
    // the merged shared state must show full byte-identical streams.
    let victim = chash::owner(0, &[0, 1]).unwrap();
    let s = two_request_scenario("gateway-kill", control_cfg())
        .request(2, Duration::from_millis(10), vec![12, 13, 14], 32)
        .request(3, Duration::from_millis(15), vec![15, 16, 17], 32)
        .fault(&format!("at 60ms kill gateway{victim}"));
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "gateway-kill");
    assert_eq!(faulty.tokens, clean.tokens, "gateway failover changed token streams");
    assert!(faulty.report.gateway_failovers >= 1, "gateway death went undetected");
    assert_eq!(faulty.report.aw_failures, 0);
    assert_eq!(faulty.report.finished, 4, "every request must still finish");
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
    assert!(
        faulty.recovery.incidents.iter().any(|i| i.class == FailureClass::Gateway),
        "gateway kill must attribute as a gateway incident:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn gateway_kill_before_arrivals_moves_ownership_of_future_requests() {
    let (manifest, weights, _) = synthetic::ensure();
    // Kill a shard before most of the schedule has arrived: later
    // arrivals must be admitted by the survivor under the updated live
    // set (no request may be stranded waiting for its dead owner).
    let victim = chash::owner(2, &[0, 1]).unwrap();
    let s = Scenario::new("gateway-early-kill", control_cfg())
        .request(0, Duration::ZERO, vec![1, 2, 3, 4], 32)
        .request(1, Duration::from_millis(5), vec![5, 6, 7], 32)
        .request(2, Duration::from_millis(200), vec![8, 9, 10], 32)
        .request(3, Duration::from_millis(210), vec![11, 12, 13], 32)
        .fault(&format!("at 40ms kill gateway{victim}"));
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_eq!(faulty.tokens, clean.tokens, "post-failover arrivals changed streams");
    assert!(faulty.report.gateway_failovers >= 1);
    assert_eq!(faulty.report.finished, 4);
}

// ---------------------------------------------------------------------------
// Orchestrator failover
// ---------------------------------------------------------------------------

#[test]
fn orch_kill_promotes_the_standby_with_identical_streams() {
    let (manifest, weights, _) = synthetic::ensure();
    // The orchestrator is off the decode datapath: killing it must not
    // move a single token even before the standby takes over.
    let s = two_request_scenario("orch-kill", control_cfg()).fault("at 40ms kill orch");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "orch-kill");
    assert_eq!(faulty.tokens, clean.tokens, "orchestrator failover changed token streams");
    assert!(faulty.report.orch_promotions >= 1, "standby never promoted");
    assert!(
        faulty.event_log.contains("orch_promoted"),
        "event log missing the promotion:\n{}",
        faulty.event_log
    );
    assert!(
        faulty.recovery.incidents.iter().any(|i| i.class == FailureClass::Orch),
        "unplanned promotion must attribute an orch incident:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn promoted_standby_recovers_a_subsequent_aw_kill() {
    let (manifest, weights, _) = synthetic::ensure();
    // The real test of the takeover: the promoted standby must drive a
    // full AW recovery (query a store replica, adopt, rebind, restore)
    // exactly like the original orchestrator would have. Promotion takes
    // ~3 missed probes (~75ms); the AW dies well after.
    let s = two_request_scenario("orch-then-aw", control_cfg())
        .fault("at 40ms kill orch")
        .fault("at 200ms kill aw0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "orch-then-aw");
    assert_eq!(faulty.tokens, clean.tokens, "post-promotion AW recovery changed streams");
    assert!(faulty.report.orch_promotions >= 1);
    assert!(faulty.report.aw_failures >= 1, "the promoted standby must handle the AW kill");
    faulty.assert_recovery(2, MAX_DETECT, MAX_STALL);
    let classes: Vec<_> = faulty.recovery.incidents.iter().map(|i| i.class).collect();
    assert!(
        classes.contains(&FailureClass::Orch) && classes.contains(&FailureClass::Aw),
        "expected an orch and an AW incident:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn orch_kill_racing_an_aw_kill_still_recovers() {
    let (manifest, weights, _) = synthetic::ensure();
    // The nastiest window: the AW dies while the orchestrator is already
    // dead but the standby has not promoted yet. The promoted standby's
    // catch-up sweep plus the re-driven active-set queries must pick the
    // orphan up.
    let s = two_request_scenario("orch-race-aw", control_cfg())
        .fault("at 40ms kill orch")
        .fault("at 55ms kill aw0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "orch-race-aw");
    assert_eq!(faulty.tokens, clean.tokens, "takeover-window AW death changed streams");
    assert!(faulty.report.orch_promotions >= 1);
    assert!(faulty.report.aw_failures >= 1);
    // The AW stall includes the promotion latency; detection is measured
    // from the victim's last progress, so give it the promotion window
    // (3 probes) on top of the normal ladder.
    faulty.assert_recovery(2, Duration::from_millis(500), MAX_STALL);
}

#[test]
fn planned_orch_promotion_is_a_zero_incident_handover() {
    let (manifest, weights, _) = synthetic::ensure();
    // `promote orch` demotes the active first (acked handover): planned
    // mobility must report zero incidents and move zero tokens.
    let s = two_request_scenario("orch-promote", control_cfg()).fault("at 60ms promote orch");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "orch-promote");
    assert_eq!(faulty.tokens, clean.tokens, "planned handover changed token streams");
    assert_eq!(faulty.report.orch_promotions, 1, "exactly one planned promotion");
    assert_eq!(faulty.report.aw_failures, 0);
    assert_eq!(faulty.report.ew_failures, 0);
    assert!(faulty.event_log.contains("orch_promoted"));
    assert!(
        faulty.recovery.is_empty(),
        "planned handover must not register an incident:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn promoted_orch_after_planned_handover_still_recovers_failures() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = two_request_scenario("promote-then-kill", control_cfg())
        .fault("at 60ms promote orch")
        .fault("at 200ms kill ew0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_eq!(faulty.tokens, clean.tokens, "post-handover EW recovery changed streams");
    assert_eq!(faulty.report.orch_promotions, 1);
    assert!(faulty.report.ew_failures >= 1, "the promoted orchestrator must handle the kill");
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
}

// ---------------------------------------------------------------------------
// Planned mobility + determinism under the replicated control plane
// ---------------------------------------------------------------------------

#[test]
fn planned_drain_under_replicated_control_plane_reports_zero_incidents() {
    let (manifest, weights, _) = synthetic::ensure();
    // The §9 planned-mobility guarantee must survive §15: draining an AW
    // with replicated stores, sharded gateways and a live standby still
    // produces identical streams and zero incidents.
    let s = two_request_scenario("drain-replicated", control_cfg()).fault("at 60ms drain aw0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_full_streams(&faulty, "drain-replicated");
    assert_eq!(faulty.tokens, clean.tokens, "planned drain changed token streams");
    assert_eq!(faulty.report.aw_failures, 0, "a drain is not a failure");
    assert!(
        faulty.recovery.is_empty(),
        "planned mobility must report zero incidents:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn control_plane_failover_replays_byte_identical_event_logs() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = two_request_scenario("control-determinism", control_cfg())
        .fault("at 40ms kill store0")
        .fault("at 60ms kill gateway1")
        .seed(42);
    let a = s.run(manifest.clone(), weights.clone());
    let b = s.run(manifest, weights);
    assert!(a.completed && b.completed);
    assert!(!a.event_log.is_empty());
    assert_eq!(a.event_log, b.event_log, "same scenario + seed must replay identically");
    assert_eq!(a.tokens, b.tokens);
}
