//! Chaos/soak fuzz suite: seeded random schedules over the full scenario
//! verb set (kill / respawn / sever+heal / drain / migrate / scale_ew /
//! hotspot, plus the §15 control-plane verbs: kill/respawn store
//! replicas, kill gateway shards, kill or hand over the orchestrator)
//! against a full cluster on the virtual clock.
//!
//! Per seed, the generator composes a random workload plus a random fault
//! schedule that a small cluster model keeps *survivable* (every expert
//! keeps a reachable replica, at least one routable AW remains), then
//! asserts the paper's recovery guarantee end to end:
//!   - the workload drains within the virtual budget,
//!   - nothing is rejected,
//!   - the per-request token streams are byte-identical to the
//!     failure-free baseline (same workload + hotspot, no faults),
//!   - the KV page budget is never exceeded on any AW arena,
//!   - same-seed reruns produce byte-identical event logs.
//!
//! On failure the schedule is delta-minimized (drop one fault at a time
//! while the failure reproduces) and printed in DSL form, so the exact
//! repro is one `Scenario::fault(line)` per printed line.
//!
//! Knobs (for CI and local soaking):
//!   TARRAGON_CHAOS_SEEDS  comma-separated seed list (default 1..=8)
//!   TARRAGON_CHAOS_STEPS  fault-schedule length per seed (default 10)

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;
use tarragon::config::Config;
use tarragon::testing::scenario::{Fault, Scenario, ScenarioOutcome, ScheduledFault};
use tarragon::testing::synthetic;
use tarragon::transport::NodeId;
use tarragon::util::rng::Pcg;

const DEFAULT_SEEDS: [u64; 8] = [1, 2, 3, 4, 5, 6, 7, 8];
const DEFAULT_STEPS: usize = 10;
/// How many extra runs the minimizer may spend on a failing seed.
const MINIMIZE_BUDGET: usize = 24;
/// Worst end-to-end stall any victim may see across stacked faults.
const MAX_STALL_S: f64 = 3.0;

fn seeds() -> Vec<u64> {
    match std::env::var("TARRAGON_CHAOS_SEEDS") {
        Ok(s) => s
            .split(',')
            .filter_map(|t| t.trim().parse::<u64>().ok())
            .collect(),
        Err(_) => DEFAULT_SEEDS.to_vec(),
    }
}

fn steps() -> usize {
    std::env::var("TARRAGON_CHAOS_STEPS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .unwrap_or(DEFAULT_STEPS)
}

fn chaos_cfg() -> Config {
    let mut cfg = Config::small_test();
    cfg.transport.latency = Duration::from_millis(1);
    cfg.transport.worker_extra_init = Duration::from_millis(50);
    // The generator owns every respawn: background provisioning would
    // add replacement EWs the cluster model cannot track.
    cfg.resilience.provisioning = false;
    // Bounded arenas so the soak also exercises preemption/restore under
    // mobility; every generated request fits (<= 4 pages of 16).
    cfg.sched.kv_budget_pages = 16;
    // Replicated control plane (§15) so its failure verbs are legal:
    // two store replicas, two gateway shards, a warm orchestrator standby.
    cfg.cluster.num_stores = 2;
    cfg.cluster.num_gateways = 2;
    cfg.resilience.orch_standby = true;
    cfg
}

// ---------------------------------------------------------------------------
// Survivability model: mirrors just enough cluster state to only emit
// schedules the recovery machinery is *supposed* to mask.
// ---------------------------------------------------------------------------

struct Model {
    /// EW -> virtual time from which the router may count on it.
    ew_ready: BTreeMap<u32, Duration>,
    /// Scale-up EWs (shadow-everything tail candidates).
    universal: BTreeSet<u32>,
    ew_killed: BTreeSet<u32>,
    ew_retired: BTreeSet<u32>,
    aw_live: BTreeSet<u32>,
    aw_killed: BTreeSet<u32>,
    aw_draining: BTreeSet<u32>,
    /// AWs that ever drained: never respawned (drain state is sticky on
    /// the manual respawn path).
    aw_drained_ever: BTreeSet<u32>,
    /// An aw<->ew link is severed until this time (at most one at once;
    /// EW removals are forbidden while it is open).
    sever_until: Option<Duration>,
    ups: u32,
    hotspot_used: bool,
    /// §15 control plane: live store replicas / gateway shards (never
    /// drop the last of either — the cluster is only *replica*-tolerant).
    store_live: BTreeSet<u32>,
    store_killed: BTreeSet<u32>,
    gateway_live: BTreeSet<u32>,
    /// The orchestrator slot acts at most once per run (kill *or* planned
    /// promotion): there is exactly one standby to consume.
    orch_acted: bool,
    /// Control-plane faults are spaced out so each failover (probe
    /// detection + takeover) lands before the next one stacks on top.
    control_ready: Duration,
}

impl Model {
    fn new() -> Model {
        Model {
            ew_ready: [(0, Duration::ZERO), (1, Duration::ZERO)].into_iter().collect(),
            universal: BTreeSet::new(),
            ew_killed: BTreeSet::new(),
            ew_retired: BTreeSet::new(),
            aw_live: [0, 1].into_iter().collect(),
            aw_killed: BTreeSet::new(),
            aw_draining: BTreeSet::new(),
            aw_drained_ever: BTreeSet::new(),
            sever_until: None,
            ups: 0,
            hotspot_used: false,
            store_live: [0, 1].into_iter().collect(),
            store_killed: BTreeSet::new(),
            gateway_live: [0, 1].into_iter().collect(),
            orch_acted: false,
            control_ready: Duration::ZERO,
        }
    }

    fn ew_avail(&self, ew: u32, t: Duration, removed: Option<u32>) -> bool {
        Some(ew) != removed
            && !self.ew_killed.contains(&ew)
            && !self.ew_retired.contains(&ew)
            && self.ew_ready.get(&ew).map(|&r| r <= t).unwrap_or(false)
    }

    /// Every expert keeps a usable replica if `removed` goes away: the
    /// initial ring spans EWs {0, 1} for every expert, and universal
    /// scale-ups shadow everything.
    fn covered_without(&self, t: Duration, removed: u32) -> bool {
        [0u32, 1].iter().any(|&e| self.ew_avail(e, t, Some(removed)))
            || self.universal.iter().any(|&e| self.ew_avail(e, t, Some(removed)))
    }

    fn sever_active(&self, t: Duration) -> bool {
        self.sever_until.map(|until| t < until).unwrap_or(false)
    }

    fn routable_aws_without(&self, removed: Option<u32>) -> usize {
        self.aw_live
            .iter()
            .filter(|&&a| Some(a) != removed && !self.aw_draining.contains(&a))
            .count()
    }
}

/// One candidate generator action (pre-legality-checked).
#[derive(Clone)]
enum Act {
    KillEw(u32),
    RespawnEw(u32),
    ScaleUp,
    ScaleDown(u32),
    KillAw(u32),
    RespawnAw(u32),
    Drain(u32),
    Migrate(u32, u32),
    Sever(u32, u32),
    Hotspot(u32),
    KillStore(u32),
    RespawnStore(u32),
    KillGateway(u32),
    KillOrch,
    PromoteOrch,
}

/// Generate one survivable fault schedule; the model is advanced in time
/// order so each verb's legality is judged against the state it will
/// actually meet.
fn gen_faults(rng: &mut Pcg, steps: usize) -> Vec<ScheduledFault> {
    let mut m = Model::new();
    let mut out: Vec<ScheduledFault> = Vec::new();
    let mut t = Duration::from_millis(30);
    for _ in 0..steps {
        t += Duration::from_millis(rng.range(15, 50));

        // Enumerate the verbs that are legal right now.
        let mut acts: Vec<Act> = Vec::new();
        let sever_open = m.sever_active(t);
        if !sever_open {
            for &e in m.ew_ready.keys() {
                if m.ew_avail(e, t, None) && m.covered_without(t, e) {
                    acts.push(Act::KillEw(e));
                    acts.push(Act::ScaleDown(e));
                }
            }
        }
        for &e in &m.ew_killed {
            if e <= 1 {
                acts.push(Act::RespawnEw(e));
            }
        }
        if m.ups < 2 {
            acts.push(Act::ScaleUp);
        }
        for &a in &m.aw_live {
            if m.routable_aws_without(Some(a)) >= 1 {
                acts.push(Act::KillAw(a));
            }
        }
        for &a in &m.aw_killed {
            if !m.aw_drained_ever.contains(&a) {
                acts.push(Act::RespawnAw(a));
            }
        }
        if m.aw_draining.is_empty() {
            for &a in &m.aw_live {
                if m.routable_aws_without(Some(a)) >= 1 {
                    acts.push(Act::Drain(a));
                    for &b in &m.aw_live {
                        if b != a {
                            acts.push(Act::Migrate(a, b));
                        }
                    }
                }
            }
        }
        if !sever_open {
            for &a in &m.aw_live {
                for &e in m.ew_ready.keys() {
                    if m.ew_avail(e, t, None) && m.covered_without(t, e) {
                        acts.push(Act::Sever(a, e));
                    }
                }
            }
        }
        if !m.hotspot_used {
            for k in 0..4u32 {
                acts.push(Act::Hotspot(k));
            }
        }
        // §15 control-plane verbs: only once the previous control-plane
        // failover has had time to land, and never the last replica of a
        // role. Dead gateways stay dead (no respawn verb — survivors own
        // the whole hash ring for the rest of the run).
        if t >= m.control_ready {
            if m.store_live.len() >= 2 {
                for &s in &m.store_live {
                    acts.push(Act::KillStore(s));
                }
            }
            for &s in &m.store_killed {
                acts.push(Act::RespawnStore(s));
            }
            if m.gateway_live.len() >= 2 {
                for &g in &m.gateway_live {
                    acts.push(Act::KillGateway(g));
                }
            }
            if !m.orch_acted {
                acts.push(Act::KillOrch);
                acts.push(Act::PromoteOrch);
            }
        }
        if acts.is_empty() {
            continue;
        }

        match acts[rng.index(acts.len())].clone() {
            Act::KillEw(e) => {
                m.ew_killed.insert(e);
                out.push(ScheduledFault { at: t, fault: Fault::KillEw(e) });
            }
            Act::RespawnEw(e) => {
                m.ew_killed.remove(&e);
                m.ew_ready.insert(e, t + Duration::from_millis(150));
                out.push(ScheduledFault { at: t, fault: Fault::RespawnEw(e) });
            }
            Act::ScaleUp => {
                let idx = 2 + m.ups;
                m.ups += 1;
                m.universal.insert(idx);
                m.ew_ready.insert(idx, t + Duration::from_millis(250));
                out.push(ScheduledFault { at: t, fault: Fault::ScaleEwUp });
            }
            Act::ScaleDown(e) => {
                m.ew_retired.insert(e);
                out.push(ScheduledFault { at: t, fault: Fault::ScaleEwDown(e) });
            }
            Act::KillAw(a) => {
                m.aw_live.remove(&a);
                if m.aw_draining.remove(&a) {
                    m.aw_drained_ever.insert(a);
                }
                m.aw_killed.insert(a);
                out.push(ScheduledFault { at: t, fault: Fault::KillAw(a) });
            }
            Act::RespawnAw(a) => {
                m.aw_killed.remove(&a);
                m.aw_live.insert(a);
                out.push(ScheduledFault { at: t, fault: Fault::RespawnAw(a) });
            }
            Act::Drain(a) => {
                m.aw_draining.insert(a);
                m.aw_drained_ever.insert(a);
                out.push(ScheduledFault { at: t, fault: Fault::DrainAw(a) });
            }
            Act::Migrate(a, b) => {
                m.aw_draining.insert(a);
                m.aw_drained_ever.insert(a);
                out.push(ScheduledFault { at: t, fault: Fault::MigrateAw(a, b) });
            }
            Act::Sever(a, e) => {
                let heal = t + Duration::from_millis(rng.range(20, 60));
                m.sever_until = Some(heal);
                out.push(ScheduledFault {
                    at: t,
                    fault: Fault::Sever(NodeId::Aw(a), NodeId::Ew(e)),
                });
                out.push(ScheduledFault {
                    at: heal,
                    fault: Fault::Heal(NodeId::Aw(a), NodeId::Ew(e)),
                });
            }
            Act::Hotspot(k) => {
                m.hotspot_used = true;
                out.push(ScheduledFault { at: t, fault: Fault::Hotspot(k) });
            }
            Act::KillStore(s) => {
                m.store_live.remove(&s);
                m.store_killed.insert(s);
                m.control_ready = t + Duration::from_millis(200);
                out.push(ScheduledFault { at: t, fault: Fault::KillStore(s) });
            }
            Act::RespawnStore(s) => {
                m.store_killed.remove(&s);
                m.store_live.insert(s);
                // Re-sync from the surviving peer is one snapshot message;
                // the cooldown is plenty for it to land.
                m.control_ready = t + Duration::from_millis(200);
                out.push(ScheduledFault { at: t, fault: Fault::RespawnStore(s) });
            }
            Act::KillGateway(g) => {
                m.gateway_live.remove(&g);
                m.control_ready = t + Duration::from_millis(200);
                out.push(ScheduledFault { at: t, fault: Fault::KillGateway(g) });
            }
            Act::KillOrch => {
                m.orch_acted = true;
                m.control_ready = t + Duration::from_millis(200);
                out.push(ScheduledFault { at: t, fault: Fault::KillOrch });
            }
            Act::PromoteOrch => {
                m.orch_acted = true;
                m.control_ready = t + Duration::from_millis(200);
                out.push(ScheduledFault { at: t, fault: Fault::PromoteOrch });
            }
        }
    }
    out
}

fn gen_scenario(seed: u64, steps: usize) -> Scenario {
    let mut rng = Pcg::seeded(seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(seed));
    let mut s = Scenario::new(format!("chaos-{seed}"), chaos_cfg()).seed(seed);
    let n_reqs = rng.range_usize(4, 7);
    for id in 0..n_reqs as u64 {
        // Strictly increasing arrivals: the gateway consumes the
        // schedule in order.
        let arrival = Duration::from_millis(id * 10 + rng.range(0, 8));
        let len = rng.range_usize(3, 9);
        let prompt: Vec<u32> = (0..len).map(|_| rng.range(1, 127) as u32).collect();
        let max_new = rng.range_usize(6, 15);
        s = s.request(id, arrival, prompt, max_new);
    }
    for f in gen_faults(&mut rng, steps) {
        s = s.fault_at(f.at, f.fault);
    }
    s
}

fn render_schedule(s: &Scenario) -> String {
    s.faults.iter().map(|f| format!("  {f}\n")).collect()
}

/// Run a scenario and check every chaos invariant against the baseline.
fn run_and_check(
    s: &Scenario,
    base: &ScenarioOutcome,
    manifest: &std::sync::Arc<tarragon::modelcfg::Manifest>,
    weights: &tarragon::modelcfg::weights::Weights,
) -> Result<ScenarioOutcome, String> {
    let out = s.run(manifest.clone(), weights.clone());
    if !out.completed {
        return Err(format!(
            "did not drain (finished {}/{})",
            out.report.finished, out.report.submitted
        ));
    }
    if !out.rejections.is_empty() {
        return Err(format!("unexpected rejections: {:?}", out.rejections));
    }
    if out.kv_budget > 0 {
        for (aw, &peak) in &out.kv_peaks {
            if peak > out.kv_budget {
                return Err(format!(
                    "aw{aw} peaked at {peak} pages (budget {})",
                    out.kv_budget
                ));
            }
        }
    }
    if out.tokens != base.tokens {
        let diff: Vec<u64> = base
            .tokens
            .keys()
            .filter(|id| base.tokens.get(*id) != out.tokens.get(*id))
            .copied()
            .collect();
        return Err(format!("token streams diverged from baseline for requests {diff:?}"));
    }
    // Recovery anatomy: every detected fault must decompose into
    // coherent phases, and no victim may stall past the chaos budget
    // (looser than the scenario suite — stacked faults can chain).
    for v in out.recovery.victims() {
        if v.detect_s < 0.0 || v.reroute_s < 0.0 || v.restore_s < 0.0 || v.recompute_s < 0.0 {
            return Err(format!(
                "negative recovery phase for req {}: {v:?}\n{}",
                v.request,
                out.recovery.render()
            ));
        }
    }
    if out.recovery.max_total_stall_s() > MAX_STALL_S {
        return Err(format!(
            "victim stalled {:.3}s (budget {MAX_STALL_S}s):\n{}",
            out.recovery.max_total_stall_s(),
            out.recovery.render()
        ));
    }
    Ok(out)
}

/// The schedule with fault `i` (plus its dependent repair, if any)
/// removed, or None when `i` must not be removed: a removal is only
/// sound if it can never *reduce* what the surviving schedule can rely
/// on. Hotspot is workload-shaping (part of the baseline too); heals
/// and respawns are repairs that only leave together with the
/// sever/kill they repair (dropping one alone manufactures a schedule
/// the survivability model never emits, so the "minimized" failure
/// would be an artifact); `scale_ew up` adds capacity later verbs may
/// depend on. Removing a kill/sever/drain/migrate/scale-down only ever
/// leaves the cluster healthier.
fn candidate_without(s: &Scenario, i: usize) -> Option<Scenario> {
    let mut cand = s.clone();
    // Remove fault `i` and the first matching repair scheduled after it.
    fn remove_with_repair(
        cand: &mut Scenario,
        i: usize,
        is_repair: impl Fn(&Fault) -> bool,
    ) {
        cand.faults.remove(i);
        if let Some(j) = cand.faults.iter().skip(i).position(|f| is_repair(&f.fault)) {
            cand.faults.remove(i + j);
        }
    }
    match cand.faults[i].fault {
        Fault::Hotspot(_)
        | Fault::Heal(..)
        | Fault::RespawnEw(_)
        | Fault::RespawnAw(_)
        | Fault::RespawnStore(_)
        | Fault::ScaleEwUp => return None,
        Fault::Sever(a, b) => remove_with_repair(&mut cand, i, |f| {
            matches!(f, Fault::Heal(x, y) if *x == a && *y == b)
        }),
        Fault::KillEw(e) => remove_with_repair(&mut cand, i, |f| {
            matches!(f, Fault::RespawnEw(x) if *x == e)
        }),
        Fault::KillAw(a) => remove_with_repair(&mut cand, i, |f| {
            matches!(f, Fault::RespawnAw(x) if *x == a)
        }),
        Fault::KillStore(s) => remove_with_repair(&mut cand, i, |f| {
            matches!(f, Fault::RespawnStore(x) if *x == s)
        }),
        // KillGateway / KillOrch / PromoteOrch have no dependent repair:
        // removing one only ever leaves the control plane healthier.
        _ => {
            cand.faults.remove(i);
        }
    }
    Some(cand)
}

/// Greedy delta-minimization: drop one fault (or sever+heal pair) at a
/// time while the failure still reproduces.
fn minimize(
    mut s: Scenario,
    base: &ScenarioOutcome,
    manifest: &std::sync::Arc<tarragon::modelcfg::Manifest>,
    weights: &tarragon::modelcfg::weights::Weights,
) -> Scenario {
    let mut budget = MINIMIZE_BUDGET;
    'outer: loop {
        for i in 0..s.faults.len() {
            let Some(cand) = candidate_without(&s, i) else { continue };
            if budget == 0 {
                break 'outer;
            }
            budget -= 1;
            if run_and_check(&cand, base, manifest, weights).is_err() {
                s = cand;
                continue 'outer;
            }
        }
        break;
    }
    s
}

/// Re-run the minimized failing schedule with span tracing enabled and
/// dump a Perfetto trace-event JSON next to the test binary, so the
/// anatomy of the failing recovery can be opened in ui.perfetto.dev.
/// Returns a human-readable path (or an explanation when the dump
/// itself failed — the panic must still fire either way).
fn dump_failure_trace(
    min: &Scenario,
    seed: u64,
    manifest: &std::sync::Arc<tarragon::modelcfg::Manifest>,
    weights: &tarragon::modelcfg::weights::Weights,
) -> String {
    let mut traced = min.clone();
    traced.cfg.trace.enabled = true;
    let out = traced.run(manifest.clone(), weights.clone());
    let json = tarragon::metrics::export::perfetto_json(&out.spans).to_string();
    // The export must itself be well-formed trace-event JSON.
    if let Err(e) = tarragon::util::json::Json::parse(&json) {
        return format!("<perfetto export did not parse: {e}>");
    }
    let path = std::env::temp_dir().join(format!("chaos-{seed}-trace.json"));
    match std::fs::write(&path, &json) {
        Ok(()) => path.display().to_string(),
        Err(e) => format!("<could not write trace: {e}>"),
    }
}

#[test]
fn chaos_soak_full_verb_set() {
    let (manifest, weights, _) = synthetic::ensure();
    let seeds = seeds();
    let steps = steps();
    assert!(!seeds.is_empty(), "TARRAGON_CHAOS_SEEDS parsed to an empty list");
    eprintln!("chaos: seeds {seeds:?}, {steps} steps each (replay: TARRAGON_CHAOS_SEEDS=<seed>)");

    for (si, &seed) in seeds.iter().enumerate() {
        let s = gen_scenario(seed, steps);
        eprintln!("chaos seed {seed}: {} faults\n{}", s.faults.len(), render_schedule(&s));
        let base = s.without_faults().run(manifest.clone(), weights.clone());
        assert!(base.completed, "seed {seed}: baseline did not drain");

        match run_and_check(&s, &base, &manifest, &weights) {
            Ok(out) => {
                // Same-seed rerun must replay byte-identically (checked on
                // the first two seeds to bound suite runtime).
                if si < 2 {
                    let again = s.run(manifest.clone(), weights.clone());
                    assert_eq!(
                        out.event_log, again.event_log,
                        "seed {seed}: same-seed rerun diverged (event logs differ)"
                    );
                    assert_eq!(out.tokens, again.tokens);
                }
            }
            Err(e) => {
                eprintln!("chaos seed {seed} FAILED: {e}\nminimizing...");
                let min = minimize(s, &base, &manifest, &weights);
                let err = run_and_check(&min, &base, &manifest, &weights)
                    .err()
                    .unwrap_or_else(|| "minimized schedule stopped failing".into());
                let trace_path = dump_failure_trace(&min, seed, &manifest, &weights);
                panic!(
                    "chaos seed {seed} failed: {e}\n\
                     minimized schedule ({}):\n{}\
                     recovery trace: {trace_path}\n\
                     replay each line via Scenario::fault(..) with seed {seed}",
                    err,
                    render_schedule(&min)
                );
            }
        }
    }
}

/// The generator itself is deterministic: the same seed produces the
/// same schedule (the suite's replay contract), and every generated
/// line round-trips through the DSL parser.
#[test]
fn chaos_generator_is_deterministic_and_dsl_clean() {
    let a = gen_scenario(42, 12);
    let b = gen_scenario(42, 12);
    assert_eq!(a.faults, b.faults, "generator must be seed-deterministic");
    assert_eq!(a.schedule.len(), b.schedule.len());
    for (x, y) in a.schedule.iter().zip(&b.schedule) {
        assert_eq!(x.prompt, y.prompt);
        assert_eq!(x.arrival_s, y.arrival_s);
    }
    for f in &a.faults {
        let line = f.to_string();
        assert_eq!(
            &ScheduledFault::parse(&line).unwrap(),
            f,
            "generated fault does not round-trip: {line}"
        );
    }
}
