//! Randomized property tests on coordinator invariants (in-repo harness;
//! proptest is unavailable offline — see testing::prop).

use tarragon::checkpoint::store::StoreLog;
use tarragon::coordinator::ert::Ert;
use tarragon::coordinator::router::{self, ExpertGroups};
use tarragon::coordinator::scaler;
use tarragon::proto::ErtTable;
use tarragon::kvcache::{
    page_hash_seed, page_hash_update, BatchAssembler, KvPool, PageId, RequestKv,
};
use tarragon::modelcfg::{Buckets, ModelSpec};
use tarragon::proto::{CommitMeta, SegmentMsg};
use tarragon::tensor::Tensor;
use tarragon::testing::prop::check;
use tarragon::util::rng::Pcg;
use std::sync::Arc;

fn rand_model(rng: &mut Pcg) -> ModelSpec {
    let heads = [2usize, 4][rng.index(2)];
    let kv_heads = [1usize, heads][rng.index(2)].min(heads);
    ModelSpec {
        layers: rng.range_usize(1, 5),
        hidden: 32,
        heads,
        kv_heads,
        head_dim: 8,
        ffn: 64,
        experts: rng.range_usize(2, 9),
        top_k: 2,
        vocab: 64,
        max_seq: rng.range_usize(8, 40),
    }
}

// ---------------------------------------------------------------------------
// Routing invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_routing_covers_every_row_exactly_topk_times() {
    check("routing coverage", 200, |rng, _| {
        let b = rng.range_usize(1, 17);
        let e = rng.range_usize(2, 12);
        let k = rng.range_usize(1, e.min(4) + 1);
        let probs = Tensor::new(
            vec![b, e],
            (0..b * e).map(|_| rng.f32().max(1e-6)).collect(),
        );
        let routes = router::select_top_k(&probs, b, k);
        assert_eq!(routes.len(), b);
        for r in &routes {
            assert_eq!(r.gates.len(), k);
            // weights renormalized, positive, descending
            let sum: f32 = r.gates.iter().map(|(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-4);
            assert!(r.gates.windows(2).all(|w| w[0].1 >= w[1].1));
            // no duplicate experts per row
            let mut es: Vec<usize> = r.gates.iter().map(|(e, _)| *e).collect();
            es.sort();
            es.dedup();
            assert_eq!(es.len(), k);
        }
        let groups = ExpertGroups::from_routes(&routes);
        assert_eq!(groups.num_assignments(), b * k);
        // every (row) appears exactly k times across groups
        let mut counts = vec![0usize; b];
        for rows in groups.groups.values() {
            for &(row, _) in rows {
                counts[row] += 1;
            }
        }
        assert!(counts.iter().all(|&c| c == k));
    });
}

// ---------------------------------------------------------------------------
// ERT invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_ert_resolution_is_consistent_under_failures() {
    check("ert failover", 200, |rng, _| {
        let experts = rng.range_usize(2, 16);
        let ews = rng.range_usize(2, 8);
        let mut ert = Ert::initial(experts, ews, true);
        // Kill a random subset of EWs (never all).
        let mut dead = Vec::new();
        for ew in 0..ews as u32 {
            if dead.len() + 1 < ews && rng.f64() < 0.4 {
                ert.mark_dead(ew);
                dead.push(ew);
            }
        }
        for e in 0..experts {
            match ert.resolve(e) {
                Some(ew) => {
                    assert!(!dead.contains(&ew), "resolved to a dead EW");
                    assert!(ert.candidates(e).contains(&ew));
                    // primary preferred when alive
                    let primary = ert.candidates(e)[0];
                    if !dead.contains(&primary) {
                        assert_eq!(ew, primary);
                    }
                }
                None => {
                    // only possible if every candidate is dead
                    assert!(ert.candidates(e).iter().all(|c| dead.contains(c)));
                }
            }
        }
        // A fresh table update always clears local dead-marks.
        let v = ert.version() + 1;
        let table = ert.table().clone();
        assert!(ert.apply(v, table));
        for ew in &dead {
            assert!(!ert.is_dead(*ew));
        }
    });
}

/// Elastic-scaling ERT invariants (DESIGN.md §11): under arbitrary
/// interleavings of local dead-marks, delayed update delivery, shadow
/// promotions and EW retirements —
///   (1) the orchestrator's table always keeps every expert covered
///       (retire can demote, never strand);
///   (2) an AW replica's version is strictly monotonic (stale updates
///       rejected, accepted updates strictly newer);
///   (3) no expert ever resolves to a retired EW once the remap version
///       that removed it is visible at that replica.
#[test]
fn prop_ert_scaling_interleavings_hold_invariants() {
    check("ert scaling interleavings", 150, |rng, _| {
        let experts = rng.range_usize(2, 10);
        let ews = rng.range_usize(2, 7);
        let initial = Ert::initial(experts, ews, true);
        let mut table: ErtTable = initial.table().clone();
        let mut version = initial.version();
        let mut aw = initial.clone();
        // Updates the orchestrator has issued but the AW has not applied
        // yet (in-order delivery, arbitrary lag).
        let mut pending: std::collections::VecDeque<(u64, ErtTable)> =
            std::collections::VecDeque::new();
        // ew -> version at which it was retired.
        let mut retired: std::collections::BTreeMap<u32, u64> = std::collections::BTreeMap::new();
        let mut last_aw_version = aw.version();

        for _ in 0..rng.range_usize(20, 60) {
            match rng.index(5) {
                // AW-local probe-confirmed dead mark.
                0 => aw.mark_dead(rng.range(0, ews as u64) as u32),
                // Deliver the next pending orchestrator update.
                1 => {
                    if let Some((v, t)) = pending.pop_front() {
                        let accepted = aw.apply(v, t);
                        assert_eq!(accepted, v > last_aw_version, "apply acceptance wrong");
                        assert!(aw.version() >= last_aw_version, "version regressed");
                        last_aw_version = aw.version();
                    }
                }
                // Replay a stale update (must always be rejected).
                2 => {
                    let v = aw.version();
                    assert!(!aw.apply(v, table.clone()), "stale update accepted");
                    assert_eq!(aw.version(), v);
                }
                // Shadow promotion of a random candidate.
                3 => {
                    let e = rng.index(experts);
                    if table[e].len() > 1 {
                        let to = table[e][rng.index(table[e].len())];
                        if scaler::promote(&mut table, e, to) {
                            version += 1;
                            assert_eq!(table[e][0], to);
                            pending.push_back((version, table.clone()));
                        }
                    }
                }
                // Retirement of a random still-live EW.
                _ => {
                    let ew = rng.range(0, ews as u64) as u32;
                    if !retired.contains_key(&ew) {
                        let before = table.clone();
                        if scaler::retire(&mut table, ew) {
                            version += 1;
                            retired.insert(ew, version);
                            pending.push_back((version, table.clone()));
                            assert!(
                                table.iter().all(|c| !c.contains(&ew)),
                                "retired EW still referenced"
                            );
                        } else {
                            assert_eq!(table, before, "refused retire mutated the table");
                        }
                    }
                }
            }

            // (1) Orchestrator-side coverage: every expert keeps >= 1
            // candidate, and none of them is retired.
            for (e, cands) in table.iter().enumerate() {
                assert!(!cands.is_empty(), "expert {e} stranded");
                for c in cands {
                    assert!(!retired.contains_key(c), "expert {e} lists retired ew{c}");
                }
            }
            // (3) Replica-side: a resolve may land on a retired EW only
            // while the remap that removed it is still undelivered.
            for e in 0..experts {
                if let Some(w) = aw.resolve(e) {
                    if let Some(&vr) = retired.get(&w) {
                        assert!(
                            aw.version() < vr,
                            "expert {e} routed to ew{w} retired at v{vr}, \
                             but replica already applied v{}",
                            aw.version()
                        );
                    }
                }
            }
        }

        // Drain delivery: fully caught up, every expert resolves and no
        // retired EW is ever routed to again. A final update supersedes
        // any leftover local dead-marks (probe false positives are
        // cleared by fresh orchestrator knowledge).
        version += 1;
        pending.push_back((version, table.clone()));
        while let Some((v, t)) = pending.pop_front() {
            aw.apply(v, t);
        }
        for e in 0..experts {
            let w = aw.resolve(e).expect("caught-up replica must resolve every expert");
            assert!(!retired.contains_key(&w), "caught-up replica routed to a retired EW");
        }
    });
}

/// The last-replica guard in isolation: retiring an EW that uniquely
/// hosts some expert must refuse (table untouched); retiring a covered
/// EW must fully remove it without stranding anyone.
#[test]
fn prop_ert_retire_never_strands() {
    check("ert retire guard", 200, |rng, _| {
        let experts = rng.range_usize(1, 8);
        let ews = rng.range_usize(1, 6);
        // Random table: each expert gets 1..=3 distinct candidates.
        let mut table: ErtTable = Vec::new();
        for _ in 0..experts {
            let n = rng.range_usize(1, 4.min(ews + 1));
            let mut cands: Vec<u32> = (0..ews as u32).collect();
            rng.shuffle(&mut cands);
            cands.truncate(n);
            table.push(cands);
        }
        let victim = rng.range(0, ews as u64) as u32;
        let sole = table.iter().any(|c| c.len() == 1 && c[0] == victim);
        let before = table.clone();
        let ok = scaler::retire(&mut table, victim);
        if sole {
            assert!(!ok, "retire of a sole replica must refuse");
            assert_eq!(table, before, "refused retire must not mutate");
        } else {
            assert!(ok);
            for (e, cands) in table.iter().enumerate() {
                assert!(!cands.contains(&victim), "victim survives in expert {e}");
                assert!(!cands.is_empty(), "expert {e} stranded by a permitted retire");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Checkpoint store invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_store_commit_never_exceeds_segments() {
    check("store prefix commit", 150, |rng, _| {
        let layers = rng.range_usize(1, 5);
        let mut log = StoreLog::new(layers);
        let positions = rng.range_usize(1, 10);
        // Deliver segments in a random order, committing along the way.
        let mut deliveries: Vec<(u32, u16)> = (0..positions as u32)
            .flat_map(|p| (0..layers as u16).map(move |l| (p, l)))
            .collect();
        rng.shuffle(&mut deliveries);
        for (i, (pos, layer)) in deliveries.iter().enumerate() {
            log.segment(
                0,
                SegmentMsg { request: 1, pos: *pos, layer: *layer, data: Arc::new(vec![0.0; 4]) },
            );
            if rng.f64() < 0.5 {
                let upto = rng.range_usize(1, positions + 1) as u32;
                log.commit(
                    0,
                    CommitMeta {
                        request: 1,
                        committed_pos: upto,
                        last_token: 9,
                        generated: upto,
                        max_new_tokens: 1000,
                        prompt_len: 0,
                    },
                );
                // Invariant: an accepted commit implies all its segments
                // are present.
                if let Some(c) = log.committed(1) {
                    let have: std::collections::HashSet<(u32, u16)> =
                        deliveries[..=i].iter().copied().collect();
                    for p in 0..c.committed_pos {
                        for l in 0..layers as u16 {
                            assert!(have.contains(&(p, l)), "commit beyond durable prefix");
                        }
                    }
                }
            }
        }
        // After everything arrives, a full commit must be accepted.
        log.commit(
            0,
            CommitMeta {
                request: 1,
                committed_pos: positions as u32,
                last_token: 1,
                generated: positions as u32,
                max_new_tokens: 1000,
                prompt_len: 0,
            },
        );
        assert_eq!(log.committed(1).unwrap().committed_pos, positions as u32);
        // Restore covers exactly the committed prefix.
        let data = log.restore_data(1).unwrap();
        assert_eq!(data.segments.len(), positions * layers);
    });
}

#[test]
fn prop_store_commits_are_monotonic_under_any_interleaving() {
    check("store monotonic commits", 150, |rng, _| {
        let layers = rng.range_usize(1, 4);
        let positions = rng.range_usize(2, 10);
        let mut log = StoreLog::new(layers);
        // All segments present up front; commits then arrive in a random
        // order (one-sided writes reorder freely on the wire).
        for p in 0..positions as u32 {
            for l in 0..layers as u16 {
                log.segment(
                    0,
                    SegmentMsg { request: 7, pos: p, layer: l, data: Arc::new(vec![0.0; 4]) },
                );
            }
        }
        let mut commits: Vec<u32> = (1..=positions as u32).collect();
        rng.shuffle(&mut commits);
        let mut high = 0u32;
        for upto in commits {
            log.commit(
                0,
                CommitMeta {
                    request: 7,
                    committed_pos: upto,
                    last_token: upto, // distinguishes commit records
                    generated: upto,
                    max_new_tokens: 1000,
                    prompt_len: 0,
                },
            );
            high = high.max(upto);
            // Invariant: a stale commit never regresses the durable point,
            // and the surviving record is the one for the high-water mark.
            let c = log.committed(7).expect("complete prefix must commit");
            assert_eq!(c.committed_pos, high, "commit regressed");
            assert_eq!(c.last_token, high, "stale commit record survived");
        }
    });
}

#[test]
fn prop_store_tombstones_reject_stragglers_without_leaking() {
    check("store tombstones", 150, |rng, _| {
        let layers = rng.range_usize(1, 4);
        let mut log = StoreLog::new(layers);
        let live: u64 = 1;
        let finished: u64 = 2;
        // Both requests accumulate some state...
        for req in [live, finished] {
            for p in 0..3u32 {
                for l in 0..layers as u16 {
                    log.segment(
                        0,
                        SegmentMsg { request: req, pos: p, layer: l, data: Arc::new(vec![0.0; 4]) },
                    );
                }
            }
        }
        // ...then one finishes and is reclaimed.
        log.forget(finished);
        assert!(log.committed(finished).is_none());
        let resident_before = log.resident_bytes();
        let dropped_before = log.stragglers_dropped;
        // A random burst of stragglers for the tombstoned request: late
        // segments and late commits, interleaved.
        let n = rng.range_usize(1, 12);
        for _ in 0..n {
            if rng.f64() < 0.5 {
                log.segment(
                    0,
                    SegmentMsg {
                        request: finished,
                        pos: rng.range(0, 8) as u32,
                        layer: rng.range(0, layers as u64) as u16,
                        data: Arc::new(vec![0.0; 4]),
                    },
                );
            } else {
                log.commit(
                    0,
                    CommitMeta {
                        request: finished,
                        committed_pos: rng.range(1, 4) as u32,
                        last_token: 0,
                        generated: 1,
                        max_new_tokens: 1000,
                        prompt_len: 0,
                    },
                );
            }
        }
        // Invariants: nothing resurrected, nothing leaked, every straggler
        // counted; the live request is untouched.
        assert!(log.committed(finished).is_none(), "tombstoned request resurrected");
        assert_eq!(log.resident_bytes(), resident_before, "stragglers leaked payload bytes");
        assert_eq!(log.stragglers_dropped, dropped_before + n as u64);
        assert_eq!(log.num_requests(), 1);
        log.commit(
            0,
            CommitMeta {
                request: live,
                committed_pos: 3,
                last_token: 5,
                generated: 3,
                max_new_tokens: 1000,
                prompt_len: 0,
            },
        );
        assert_eq!(log.committed(live).unwrap().committed_pos, 3);
    });
}

/// K-replica convergence (DESIGN.md §15): the AW fans every segment,
/// commit and forget out to all store replicas, but one-sided writes
/// reorder freely per link. Whatever interleaving each replica observes,
/// all replicas converge to the same observable state — same accepted
/// commit, same restorable prefix, same tombstones — because segments
/// are idempotent inserts, commits are monotonic high-water marks, and
/// tombstones dominate stragglers in either order.
#[test]
fn prop_replicated_stores_converge_under_any_interleaving() {
    #[derive(Clone)]
    enum Op {
        Seg(u64, u32, u16),
        Commit(u64, u32),
        Forget(u64),
    }
    check("replica convergence", 120, |rng, _| {
        let layers = rng.range_usize(1, 4);
        let replicas = rng.range_usize(2, 5);
        let requests = rng.range_usize(1, 4) as u64;
        // One canonical op multiset, as the AW would fan it out.
        let mut ops: Vec<Op> = Vec::new();
        for req in 1..=requests {
            let positions = rng.range_usize(1, 6) as u32;
            for p in 0..positions {
                for l in 0..layers as u16 {
                    ops.push(Op::Seg(req, p, l));
                }
            }
            for _ in 0..rng.range_usize(1, 4) {
                ops.push(Op::Commit(req, rng.range(1, positions as u64 + 1) as u32));
            }
            if rng.f64() < 0.3 {
                ops.push(Op::Forget(req));
            }
        }
        let seg = |req: u64, p: u32, l: u16| SegmentMsg {
            request: req,
            pos: p,
            layer: l,
            // content-addressed payload: every replica logs identical bytes
            data: Arc::new(vec![(req * 1000 + p as u64 * 10 + l as u64) as f32; 4]),
        };
        let mut logs: Vec<StoreLog> = Vec::new();
        for _ in 0..replicas {
            let mut order = ops.clone();
            rng.shuffle(&mut order); // per-replica wire reordering
            let mut log = StoreLog::new(layers);
            for op in order {
                match op {
                    Op::Seg(req, p, l) => log.segment(0, seg(req, p, l)),
                    Op::Commit(req, upto) => log.commit(
                        0,
                        CommitMeta {
                            request: req,
                            committed_pos: upto,
                            last_token: upto,
                            generated: upto,
                            max_new_tokens: 1000,
                            prompt_len: 0,
                        },
                    ),
                    Op::Forget(req) => log.forget(req),
                }
            }
            logs.push(log);
        }
        // Every replica agrees on the observable state of every request.
        let (first, rest) = logs.split_first().unwrap();
        for other in rest {
            assert_eq!(first.num_requests(), other.num_requests(), "replica request sets differ");
            for req in 1..=requests {
                assert_eq!(first.is_finished(req), other.is_finished(req), "tombstones diverged");
                assert_eq!(first.committed(req), other.committed(req), "commit records diverged");
                match (first.restore_data(req), other.restore_data(req)) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        assert_eq!(a.meta, b.meta);
                        assert_eq!(a.segments.len(), b.segments.len());
                        for (x, y) in a.segments.iter().zip(b.segments.iter()) {
                            assert_eq!((x.0, x.1), (y.0, y.1), "restore prefix order diverged");
                            assert_eq!(x.2.as_slice(), y.2.as_slice(), "restore payload diverged");
                        }
                    }
                    _ => panic!("replicas disagree on restorability of request {req}"),
                }
            }
        }
        // A rebuilt replica re-synced from any survivor matches it, and the
        // anti-entropy import is idempotent.
        let donor = &logs[rng.index(replicas)];
        let snap = donor.export_sync();
        let mut rebuilt = StoreLog::new(layers);
        rebuilt.import_sync(snap.clone());
        rebuilt.import_sync(snap); // duplicate sync must be harmless
        assert_eq!(rebuilt.num_requests(), donor.num_requests());
        for req in 1..=requests {
            assert_eq!(rebuilt.is_finished(req), donor.is_finished(req));
            assert_eq!(rebuilt.committed(req), donor.committed(req), "re-sync lost a commit");
            if let Some(a) = donor.restore_data(req) {
                let b = rebuilt.restore_data(req).expect("re-synced replica must serve restores");
                assert_eq!(a.meta, b.meta);
                for (x, y) in a.segments.iter().zip(b.segments.iter()) {
                    assert_eq!(x.2.as_slice(), y.2.as_slice(), "re-synced payload differs");
                }
            }
        }
        assert_eq!(rebuilt.resident_bytes(), donor.resident_bytes(), "re-sync leaked or lost bytes");
    });
}

/// Rendezvous sharding stability (DESIGN.md §15): the owner is always a
/// member of the live set, is independent of the set's order, and losing
/// a shard reassigns exactly that shard's keys — every other request
/// keeps its gateway, so one gateway failure never reshuffles the
/// survivors' admissions. Restoring the shard restores the original map.
#[test]
fn prop_chash_sharding_is_stable_and_minimal() {
    use tarragon::util::chash;
    check("chash stability", 300, |rng, _| {
        let n = rng.range_usize(1, 8);
        let mut shards: Vec<u32> = (0..16).collect();
        rng.shuffle(&mut shards);
        shards.truncate(n);
        let keys: Vec<u64> = (0..rng.range_usize(1, 40)).map(|_| rng.range(0, 1 << 48)).collect();
        assert_eq!(chash::owner(keys[0], &[]), None, "empty set must own nothing");
        let before: Vec<u32> = keys
            .iter()
            .map(|&k| {
                let o = chash::owner(k, &shards).unwrap();
                assert!(shards.contains(&o), "owner outside the shard set");
                // deterministic and order-independent
                let mut perm = shards.clone();
                rng.shuffle(&mut perm);
                assert_eq!(chash::owner(k, &perm), Some(o), "owner depends on set order");
                o
            })
            .collect();
        if n == 1 {
            assert!(before.iter().all(|&o| o == shards[0]));
            return;
        }
        // Kill one shard: only its keys move; every survivor's keys stay.
        let dead = shards[rng.index(n)];
        let live: Vec<u32> = shards.iter().copied().filter(|&s| s != dead).collect();
        for (&k, &was) in keys.iter().zip(before.iter()) {
            let now = chash::owner(k, &live).unwrap();
            assert!(live.contains(&now));
            if was != dead {
                assert_eq!(now, was, "failover moved a key the dead shard never owned");
            }
        }
        // Respawn: the original assignment comes back exactly.
        for (&k, &was) in keys.iter().zip(before.iter()) {
            assert_eq!(chash::owner(k, &shards), Some(was), "respawn must restore the map");
        }
    });
}

// ---------------------------------------------------------------------------
// KV cache / batch assembly invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_batch_assembly_preserves_rows_and_padding() {
    check("batch assembly", 100, |rng, _| {
        let m = rand_model(rng);
        // Random page size exercises page-boundary handling in the gather.
        let pool = KvPool::with_page_tokens(&m, rng.range_usize(1, m.max_seq + 1));
        let n = rng.range_usize(1, 5);
        let bucket = n + rng.range_usize(0, 4);
        let mut kvs: Vec<RequestKv> = Vec::new();
        for _ in 0..n {
            let mut kv = RequestKv::new(&m, &pool);
            let len = rng.range_usize(0, m.max_seq);
            for pos in 0..len {
                let k: Vec<f32> = (0..m.kv_heads * m.head_dim).map(|_| rng.f32()).collect();
                let v: Vec<f32> = (0..m.kv_heads * m.head_dim).map(|_| rng.f32()).collect();
                kv.write(m.layers - 1, pos, &k, &v);
            }
            kv.set_len(len);
            kvs.push(kv);
        }
        let mut asm = BatchAssembler::new(&m);
        let refs: Vec<&RequestKv> = kvs.iter().collect();
        let (kc, vc, pos) = asm.gather(&refs, m.layers - 1, bucket, m.kv_heads, m.head_dim);
        assert_eq!(kc.shape(), &[bucket, m.max_seq, m.kv_heads, m.head_dim]);
        assert_eq!(pos.len(), bucket);
        let seg = m.kv_heads * m.head_dim;
        let row = m.max_seq * seg;
        for (i, kv) in kvs.iter().enumerate() {
            assert_eq!(pos[i] as usize, kv.len());
            // gathered valid prefix equals the per-request cache content
            let valid = kv.len() * seg;
            let (kvec, vvec) = kv.layer_vecs(m.layers - 1);
            assert_eq!(&kc.data()[i * row..i * row + valid], kvec);
            assert_eq!(&vc.data()[i * row..i * row + valid], vvec);
            // positions past len are zero (the artifact masks by pos)
            assert!(kc.data()[i * row + valid..(i + 1) * row].iter().all(|&x| x == 0.0));
        }
        // padding rows all zero, pos zero
        for i in n..bucket {
            assert_eq!(pos[i], 0);
            assert!(kc.data()[i * row..(i + 1) * row].iter().all(|&x| x == 0.0));
        }
    });
}

#[test]
fn prop_kv_segment_roundtrip() {
    check("kv segment roundtrip", 100, |rng, _| {
        let m = rand_model(rng);
        let pool = KvPool::with_page_tokens(&m, rng.range_usize(1, m.max_seq + 1));
        let mut a = RequestKv::new(&m, &pool);
        let mut b = RequestKv::new(&m, &pool);
        let len = rng.range_usize(1, m.max_seq + 1);
        for pos in 0..len {
            for layer in 0..m.layers {
                let k: Vec<f32> = (0..m.kv_heads * m.head_dim).map(|_| rng.f32()).collect();
                let v: Vec<f32> = (0..m.kv_heads * m.head_dim).map(|_| rng.f32()).collect();
                a.write(layer, pos, &k, &v);
                // restoration path: segment-wise copy into b
                b.write_segment(layer, pos, &a.read_segment(layer, pos));
            }
        }
        a.set_len(len);
        b.set_len(len);
        for layer in 0..m.layers {
            assert_eq!(a.layer_vecs(layer), b.layer_vecs(layer));
        }
    });
}

// ---------------------------------------------------------------------------
// KV page-pool invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_pool_alloc_free_roundtrip_no_double_handout() {
    check("pool alloc/free", 150, |rng, _| {
        let m = rand_model(rng);
        let pool = KvPool::with_page_tokens(&m, rng.range_usize(1, 9));
        let mut live: Vec<PageId> = Vec::new();
        let mut peak = 0usize;
        for _ in 0..rng.range_usize(10, 120) {
            if live.is_empty() || rng.f64() < 0.55 {
                let id = pool.alloc();
                // no double-hand-out: a live page is never issued again
                assert!(!live.contains(&id), "page {id:?} handed out twice");
                live.push(id);
            } else {
                let id = live.swap_remove(rng.index(live.len()));
                pool.free(id);
            }
            peak = peak.max(live.len());
            assert_eq!(pool.pages_in_use(), live.len());
            // slab recycling: the arena never grows past the peak demand
            assert!(pool.pages_resident() <= peak);
        }
        for id in live.drain(..) {
            pool.free(id);
        }
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.peak_pages(), peak);
        // a full drain leaves every page reusable
        let again: Vec<PageId> = (0..peak).map(|_| pool.alloc()).collect();
        assert_eq!(pool.pages_resident(), peak, "drained pages must be recycled");
        for id in again {
            pool.free(id);
        }
    });
}

#[test]
fn prop_restore_into_pages_reproduces_exact_prefix() {
    check("restore into pages", 75, |rng, _| {
        let m = rand_model(rng);
        let pool = KvPool::with_page_tokens(&m, rng.range_usize(1, 9));
        let seg_elems = m.kv_heads * m.head_dim;
        // Source AW writes a random sequence and streams every segment.
        let mut src = RequestKv::new(&m, &pool);
        let len = rng.range_usize(1, m.max_seq + 1);
        for pos in 0..len {
            for layer in 0..m.layers {
                let k: Vec<f32> = (0..seg_elems).map(|_| rng.f32()).collect();
                let v: Vec<f32> = (0..seg_elems).map(|_| rng.f32()).collect();
                src.write(layer, pos, &k, &v);
            }
        }
        src.set_len(len);
        let mut log = StoreLog::new(m.layers);
        let mut deliveries: Vec<(u32, u16)> = (0..len as u32)
            .flat_map(|p| (0..m.layers as u16).map(move |l| (p, l)))
            .collect();
        rng.shuffle(&mut deliveries); // out-of-order one-sided writes
        for (pos, layer) in deliveries {
            log.segment(
                0,
                SegmentMsg {
                    request: 1,
                    pos,
                    layer,
                    data: src.segment_payload(layer as usize, pos as usize),
                },
            );
        }
        log.commit(
            0,
            CommitMeta {
                request: 1,
                committed_pos: len as u32,
                last_token: 7,
                generated: len as u32,
                max_new_tokens: 1000,
                prompt_len: 1,
            },
        );
        // Adopting AW installs the restore payload into fresh pages.
        let data = log.restore_data(1).unwrap();
        let mut dst = RequestKv::new(&m, &pool);
        for (pos, layer, seg) in &data.segments {
            dst.write_segment(*layer as usize, *pos as usize, seg.as_slice());
        }
        dst.set_len(data.meta.committed_pos as usize);
        assert_eq!(dst.len(), len);
        for pos in 0..len {
            for layer in 0..m.layers {
                assert_eq!(
                    dst.read_segment(layer, pos),
                    src.read_segment(layer, pos),
                    "restored segment differs at pos {pos} layer {layer}"
                );
            }
        }
        // Restore allocated only what the prefix needs.
        let pt = pool.page_tokens();
        assert_eq!(dst.allocated_pages(), m.layers * ((len + pt - 1) / pt));
    });
}

#[test]
fn prop_fragmentation_bounded_under_random_churn() {
    check("pool churn", 50, |rng, _| {
        let m = rand_model(rng);
        let pt = rng.range_usize(1, 9);
        let pool = KvPool::with_page_tokens(&m, pt);
        let mut live: Vec<RequestKv> = Vec::new();
        for _ in 0..60 {
            if live.is_empty() || rng.f64() < 0.6 {
                let mut kv = RequestKv::new(&m, &pool);
                let len = rng.range_usize(0, m.max_seq + 1);
                for pos in 0..len {
                    for layer in 0..m.layers {
                        kv.write(layer, pos, &vec![1.0; m.kv_heads * m.head_dim], &vec![2.0; m.kv_heads * m.head_dim]);
                    }
                }
                kv.set_len(len);
                live.push(kv);
            } else {
                live.swap_remove(rng.index(live.len()));
            }
            // Internal fragmentation is bounded: every live request holds
            // exactly ceil(len / page_tokens) pages per layer — never more
            // than one partially-filled page per (request, layer).
            let expect: usize =
                live.iter().map(|kv| ((kv.len() + pt - 1) / pt) * m.layers).sum();
            assert_eq!(pool.pages_in_use(), expect);
        }
        live.clear();
        assert_eq!(pool.pages_in_use(), 0, "churn must not leak pages");
    });
}

/// Acceptance: resident KV memory scales with the actual sequence, not
/// `max_seq`. Admitting short requests must cost < 10% of what the seed's
/// full preallocation (`layers * max_seq * 2 * seg` floats per request)
/// would have pinned.
#[test]
fn paged_short_requests_use_under_10pct_of_preallocation() {
    let m = ModelSpec {
        layers: 4,
        hidden: 128,
        heads: 4,
        kv_heads: 1,
        head_dim: 32,
        ffn: 256,
        experts: 8,
        top_k: 2,
        vocab: 512,
        max_seq: 256,
    };
    let pool = KvPool::for_model(&m); // default 16-token pages
    let seg = m.kv_heads * m.head_dim;
    let n_reqs = 8;
    let short_len = 8;
    let mut kvs = Vec::new();
    for _ in 0..n_reqs {
        let mut kv = RequestKv::new(&m, &pool);
        for pos in 0..short_len {
            for layer in 0..m.layers {
                kv.write(layer, pos, &vec![1.0; seg], &vec![2.0; seg]);
            }
        }
        kv.set_len(short_len);
        kvs.push(kv);
    }
    let paged_bytes = pool.bytes_in_use();
    let prealloc_bytes = n_reqs * m.kv_request_bytes();
    assert!(
        (paged_bytes as f64) < 0.10 * prealloc_bytes as f64,
        "paged {paged_bytes} B vs preallocated {prealloc_bytes} B"
    );
    // And it is exactly one page per (request, layer) here.
    assert_eq!(pool.pages_in_use(), n_reqs * m.layers);
}

// ---------------------------------------------------------------------------
// Prefix sharing / copy-on-write invariants (DESIGN.md §13)
// ---------------------------------------------------------------------------

/// Deterministic prompt K/V for content class `c`: identical `c` means
/// bitwise-identical rows, so full pages hash-match and share; distinct
/// `c` (or distinct positions) never collide within `max_seq`.
fn prompt_kv(m: &ModelSpec, c: usize, len: usize) -> (Tensor, Tensor) {
    let seg = m.kv_heads * m.head_dim;
    let f = |t: usize, j: usize, salt: usize| ((c * 131 + t * 17 + j * 3 + salt) % 97) as f32 * 0.125;
    let k = Tensor::new(vec![len, seg], (0..len * seg).map(|i| f(i / seg, i % seg, 0)).collect());
    let v = Tensor::new(vec![len, seg], (0..len * seg).map(|i| f(i / seg, i % seg, 1)).collect());
    (k, v)
}

/// Under random admit / deep-clone / drop churn with prompts drawn from a
/// few canonical contents, refcounts always balance: physical pages never
/// exceed logical page references, and a full drain returns every page.
#[test]
fn prop_shared_pages_refcount_balances() {
    check("shared refcount balance", 60, |rng, _| {
        let m = rand_model(rng);
        let pt = rng.range_usize(1, 9);
        let pool = KvPool::with_page_tokens(&m, pt);
        let mut live: Vec<RequestKv> = Vec::new();
        for _ in 0..rng.range_usize(10, 50) {
            let roll = rng.f64();
            if live.is_empty() || roll < 0.55 {
                let c = rng.index(3);
                let len = rng.range_usize(1, m.max_seq + 1);
                let (k, v) = prompt_kv(&m, c, len);
                let mut kv = RequestKv::new(&m, &pool);
                for layer in 0..m.layers {
                    let out = kv.write_prompt_layer(layer, len, &k, &v);
                    // shared + written partition the prompt exactly
                    assert_eq!(out.shared.len() * pt + out.written.len(), len);
                }
                kv.set_len(len);
                live.push(kv);
            } else if roll < 0.75 {
                // Deep copy: duplicates physical pages, shares nothing.
                let src = rng.index(live.len());
                if let Some(dup) = live[src].try_clone() {
                    live.push(dup);
                }
            } else {
                live.swap_remove(rng.index(live.len()));
            }
            let logical: usize = live.iter().map(|kv| kv.allocated_pages()).sum();
            let physical = pool.pages_in_use();
            assert!(physical <= logical, "physical {physical} > logical {logical}");
            assert!(pool.pages_shared_now() <= physical);
        }
        live.clear();
        assert_eq!(pool.pages_in_use(), 0, "sharing churn leaked pages");
        assert_eq!(pool.pages_shared_now(), 0);
        assert_eq!(pool.total_allocs(), pool.total_frees());
    });
}

/// Copy-on-write isolation, bitwise: after two requests share a prefix, a
/// write into the shared region privatizes exactly one page for the writer;
/// the co-holder's bytes never change, and the writer diverges only at the
/// written position.
#[test]
fn prop_cow_divergence_is_bitwise_isolated() {
    check("cow diverge", 60, |rng, _| {
        let m = rand_model(rng);
        let pt = rng.range_usize(1, m.max_seq.min(8) + 1); // >= one full page
        let seg = m.kv_heads * m.head_dim;
        let pool = KvPool::with_page_tokens(&m, pt);
        let len = rng.range_usize(pt, m.max_seq + 1);
        let (k, v) = prompt_kv(&m, 0, len);
        let mut a = RequestKv::new(&m, &pool);
        let mut b = RequestKv::new(&m, &pool);
        for layer in 0..m.layers {
            a.write_prompt_layer(layer, len, &k, &v);
            let out = b.write_prompt_layer(layer, len, &k, &v);
            assert_eq!(out.shared.len(), len / pt, "every full page must hit");
        }
        a.set_len(len);
        b.set_len(len);
        assert_eq!(pool.prefix_hits(), (m.layers * (len / pt)) as u64);
        // Physical footprint: A's pages plus only B's partial tail.
        let tail = usize::from(len % pt != 0);
        assert_eq!(pool.pages_in_use(), m.layers * (len.div_ceil(pt) + tail));
        let snap: Vec<Vec<f32>> = (0..m.layers)
            .flat_map(|l| (0..len).map(move |p| (l, p)))
            .map(|(l, p)| a.read_segment(l, p))
            .collect();
        // B mutates one random position inside the shared prefix.
        let physical_before = pool.pages_in_use();
        let wl = rng.index(m.layers);
        let wp = rng.range_usize(0, (len / pt) * pt);
        b.write(wl, wp, &vec![-1.0; seg], &vec![-2.0; seg]);
        assert_eq!(pool.cow_breaks(), 1, "exactly one page privatized");
        assert_eq!(pool.pages_in_use(), physical_before + 1);
        for l in 0..m.layers {
            for p in 0..len {
                assert_eq!(a.read_segment(l, p), snap[l * len + p], "CoW mutated the co-holder");
                if (l, p) == (wl, wp) {
                    let got = b.read_segment(l, p);
                    assert_eq!(&got[..seg], &vec![-1.0; seg][..]);
                    assert_eq!(&got[seg..], &vec![-2.0; seg][..]);
                } else {
                    // the privatized page was copied before the write, so
                    // every other position still mirrors A bitwise
                    assert_eq!(b.read_segment(l, p), a.read_segment(l, p));
                }
            }
        }
        drop(a);
        drop(b);
        assert_eq!(pool.pages_in_use(), 0, "CoW divergence leaked pages");
        assert_eq!(pool.total_allocs(), pool.total_frees());
    });
}

/// The restore path's sharing install (aw::install_restored): an adopting
/// request re-derives page hashes from checkpoint segments, takes verified
/// references on the sealed prefix, writes only the tail — and dropping
/// sharer and sharee in either order returns every physical page.
#[test]
fn prop_shared_restore_returns_every_page_on_drop() {
    check("shared restore drop", 60, |rng, _| {
        let m = rand_model(rng);
        let pt = rng.range_usize(1, 9);
        let seg = m.kv_heads * m.head_dim;
        let pool = KvPool::with_page_tokens(&m, pt);
        let len = rng.range_usize(1, m.max_seq + 1);
        let (k, v) = prompt_kv(&m, 1, len);
        let mut src = RequestKv::new(&m, &pool);
        for layer in 0..m.layers {
            src.write_prompt_layer(layer, len, &k, &v);
        }
        src.set_len(len);
        let src_pages = pool.pages_in_use();
        let full = len / pt;
        let mut dst = RequestKv::new(&m, &pool);
        for layer in 0..m.layers {
            for page in 0..full {
                let mut h = page_hash_seed(layer);
                for t in page * pt..(page + 1) * pt {
                    h = page_hash_update(h, k.row(t));
                    h = page_hash_update(h, v.row(t));
                }
                let ok = dst.try_share_page(layer, h, |raw| {
                    (0..pt).all(|t| {
                        let off = t * 2 * seg;
                        raw[off..off + seg] == *k.row(page * pt + t)
                            && raw[off + seg..off + 2 * seg] == *v.row(page * pt + t)
                    })
                });
                assert!(ok, "sealed prefix page must be shareable on restore");
            }
            assert_eq!(dst.shared_prefix_pages(layer), full);
            for pos in full * pt..len {
                dst.write_segment(layer, pos, &src.read_segment(layer, pos));
            }
        }
        dst.set_len(len);
        // Shared install added only the partial tail physically.
        assert_eq!(pool.pages_in_use(), src_pages + m.layers * usize::from(len % pt != 0));
        for layer in 0..m.layers {
            for pos in 0..len {
                assert_eq!(dst.read_segment(layer, pos), src.read_segment(layer, pos));
            }
        }
        if rng.f64() < 0.5 {
            drop(src);
            drop(dst);
        } else {
            drop(dst);
            drop(src);
        }
        assert_eq!(pool.pages_in_use(), 0, "shared restore leaked pages");
        assert_eq!(pool.total_allocs(), pool.total_frees());
    });
}

/// `try_clone` at the page budget: succeeds iff a full deep copy fits;
/// a refusal rolls back completely — no page leaked, and every page of
/// the remaining headroom is still allocatable.
#[test]
fn prop_try_clone_rolls_back_without_leaking_at_budget() {
    use tarragon::kvcache::PoolConfig;
    check("try_clone budget rollback", 100, |rng, _| {
        let m = rand_model(rng);
        let pt = rng.range_usize(1, 9);
        let seg = m.kv_heads * m.head_dim;
        let len = rng.range_usize(1, m.max_seq + 1);
        let pages = m.layers * len.div_ceil(pt);
        let budget = pages + rng.range_usize(0, pages + 3);
        let pool = KvPool::bounded(PoolConfig { page_tokens: pt, seg }, budget);
        let mut kv = RequestKv::new(&m, &pool);
        for pos in 0..len {
            for layer in 0..m.layers {
                kv.write(layer, pos, &vec![pos as f32; seg], &vec![layer as f32; seg]);
            }
        }
        kv.set_len(len);
        assert_eq!(pool.pages_in_use(), pages);
        match kv.try_clone() {
            Some(dup) => {
                assert!(budget >= 2 * pages, "clone succeeded without headroom");
                assert_eq!(pool.pages_in_use(), 2 * pages);
                for pos in 0..len {
                    for layer in 0..m.layers {
                        assert_eq!(dup.read_segment(layer, pos), kv.read_segment(layer, pos));
                    }
                }
                drop(dup);
                assert_eq!(pool.pages_in_use(), pages);
            }
            None => {
                assert!(budget < 2 * pages, "clone refused despite headroom");
                assert_eq!(pool.pages_in_use(), pages, "failed clone leaked pages");
                // the rollback returned every page: headroom is exactly intact
                let headroom: Vec<PageId> =
                    (0..budget - pages).map(|_| pool.try_alloc().unwrap()).collect();
                assert!(pool.try_alloc().is_none());
                for id in headroom {
                    pool.free(id);
                }
            }
        }
        drop(kv);
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.total_allocs(), pool.total_frees());
    });
}

/// `gather_paged` over any batch size 0..=bucket: never panics (a bucket
/// drained by a preemption race gathers an empty view), `pos` pads to the
/// bucket, and each live row mirrors that request's page table.
#[test]
fn prop_paged_gather_handles_any_batch_size() {
    check("paged gather batch sizes", 100, |rng, _| {
        let m = rand_model(rng);
        let pool = KvPool::with_page_tokens(&m, rng.range_usize(1, 9));
        let layer = m.layers - 1;
        let n = rng.range_usize(0, 5);
        let bucket = n.max(1) + rng.range_usize(0, 3);
        let mut kvs: Vec<RequestKv> = Vec::new();
        for _ in 0..n {
            let mut kv = RequestKv::new(&m, &pool);
            let len = rng.range_usize(0, m.max_seq + 1);
            for pos in 0..len {
                let seg = m.kv_heads * m.head_dim;
                kv.write(layer, pos, &vec![1.0; seg], &vec![2.0; seg]);
            }
            kv.set_len(len);
            kvs.push(kv);
        }
        let mut asm = BatchAssembler::new(&m);
        let refs: Vec<&RequestKv> = kvs.iter().collect();
        let mut pos = Vec::new();
        let view = asm.gather_paged(&pool, &refs, layer, bucket, &mut pos);
        assert_eq!(pos.len(), bucket);
        assert_eq!(view.tables.len(), n, "one table row per live request");
        for i in 0..bucket {
            if i < n {
                assert_eq!(pos[i] as usize, kvs[i].len());
                assert_eq!(view.tables[i].as_slice(), kvs[i].page_table(layer));
            } else {
                assert_eq!(pos[i], 0, "padding rows must read as empty");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Bucket fitting invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_bucket_fit_is_minimal_and_sufficient() {
    check("bucket fit", 300, |rng, _| {
        let mut buckets: Vec<usize> = (0..rng.range_usize(1, 6))
            .map(|_| rng.range_usize(1, 300))
            .collect();
        buckets.sort();
        buckets.dedup();
        let n = rng.range_usize(1, 350);
        match Buckets::fit(&buckets, n) {
            Some(b) => {
                assert!(b >= n);
                assert!(buckets.contains(&b));
                // minimal: no smaller bucket also fits
                assert!(buckets.iter().all(|&x| x < n || x >= b));
            }
            None => assert!(buckets.iter().all(|&x| x < n)),
        }
    });
}

// ---------------------------------------------------------------------------
// Overload-scheduler invariants (DESIGN.md §9): page budget, pressure
// watermarks, preempt-evict accounting
// ---------------------------------------------------------------------------

/// The page budget is a hard invariant: under any alloc/free interleaving
/// `pages_in_use` never exceeds it, `try_alloc` fails exactly at the cap,
/// and freed headroom is immediately reusable.
#[test]
fn prop_kv_budget_never_exceeded() {
    use tarragon::kvcache::PoolConfig;
    check("kv budget", 100, |rng, _| {
        let budget = rng.range_usize(1, 24);
        let pool = KvPool::bounded(
            PoolConfig { page_tokens: rng.range_usize(1, 9), seg: 4 },
            budget,
        );
        let mut held: Vec<PageId> = Vec::new();
        for _ in 0..300 {
            if rng.f64() < 0.55 {
                match pool.try_alloc() {
                    Some(id) => held.push(id),
                    None => assert_eq!(
                        pool.pages_in_use(),
                        budget,
                        "try_alloc must fail exactly at the budget"
                    ),
                }
            } else if !held.is_empty() {
                pool.free(held.swap_remove(rng.index(held.len())));
            }
            assert!(pool.pages_in_use() <= budget, "budget exceeded");
            assert!(pool.peak_pages() <= budget, "peak accounting exceeded budget");
            assert_eq!(pool.free_pages(), Some(budget - pool.pages_in_use()));
        }
        for id in held {
            pool.free(id);
        }
        assert_eq!(pool.pages_in_use(), 0);
        assert_eq!(pool.total_allocs(), pool.total_frees());
    });
}

/// Pressure is monotone under alloc/free: each alloc raises it by exactly
/// 1/budget, each free lowers it by the same, and it stays within [0, 1].
#[test]
fn prop_kv_pressure_monotone_under_interleavings() {
    use tarragon::kvcache::PoolConfig;
    check("kv pressure", 100, |rng, _| {
        let budget = rng.range_usize(1, 16);
        let pool = KvPool::bounded(PoolConfig { page_tokens: 2, seg: 2 }, budget);
        let step = 1.0 / budget as f64;
        let mut held: Vec<PageId> = Vec::new();
        for _ in 0..200 {
            let before = pool.pressure();
            if rng.f64() < 0.5 {
                if let Some(id) = pool.try_alloc() {
                    held.push(id);
                    assert!((pool.pressure() - (before + step)).abs() < 1e-9);
                } else {
                    assert!((pool.pressure() - 1.0).abs() < 1e-9);
                }
            } else if !held.is_empty() {
                pool.free(held.swap_remove(rng.index(held.len())));
                assert!((pool.pressure() - (before - step)).abs() < 1e-9);
            }
            assert!(pool.pressure() >= -1e-9 && pool.pressure() <= 1.0 + 1e-9);
        }
    });
}

/// Preempt-evict must return every page: repeated evict (drop) → restore
/// (write_segment) cycles across random sequence lengths neither leak nor
/// double-free, and the restored contents round-trip exactly.
#[test]
fn prop_preempt_evict_restore_cycles_return_every_page() {
    check("evict/restore cycles", 60, |rng, _| {
        let m = rand_model(rng);
        let seg = m.kv_heads * m.head_dim;
        let pool = KvPool::with_page_tokens(&m, rng.range_usize(1, 9));
        for cycle in 0..6usize {
            let len = rng.range_usize(1, m.max_seq + 1);
            // Build a resident request (decode state).
            let mut kv = RequestKv::new(&m, &pool);
            for pos in 0..len {
                for layer in 0..m.layers {
                    let fill = (cycle * 1000 + pos * 10 + layer) as f32;
                    kv.write(layer, pos, &vec![fill; seg], &vec![fill + 0.5; seg]);
                }
            }
            kv.set_len(len);
            let pages = kv.allocated_pages();
            assert_eq!(pool.pages_in_use(), pages);
            // "Flush": capture every segment the streamer would emit.
            let mut segments = Vec::new();
            for pos in 0..len {
                for layer in 0..m.layers {
                    segments.push((pos, layer, kv.read_segment(layer, pos)));
                }
            }
            // Evict: every page must come back to the arena.
            drop(kv);
            assert_eq!(pool.pages_in_use(), 0, "evict leaked pages (cycle {cycle})");
            // Restore into a fresh cache (the adopting AW's install path).
            let mut restored = RequestKv::new(&m, &pool);
            for (pos, layer, data) in &segments {
                restored.write_segment(*layer, *pos, data);
            }
            restored.set_len(len);
            assert_eq!(restored.allocated_pages(), pages, "restore footprint changed");
            for (pos, layer, data) in &segments {
                assert_eq!(&restored.read_segment(*layer, *pos), data, "restore corrupted");
            }
            drop(restored);
            assert_eq!(pool.pages_in_use(), 0, "restore cycle leaked pages");
        }
        assert_eq!(pool.total_allocs(), pool.total_frees());
    });
}
