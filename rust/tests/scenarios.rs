//! Failure-scenario matrix on the virtual clock (testing::scenario).
//!
//! Every scenario runs the full cluster — gateway, orchestrator,
//! checkpoint store, AWs, EWs, fabric — under deterministic virtual time
//! against the synthetic in-repo model, and asserts the paper's §5/§6
//! recovery guarantee: the generated token streams are identical to the
//! failure-free run. Probe timeouts, silence windows and T_w cost virtual
//! time only, so the whole matrix completes in seconds of wall time.

use std::time::Duration;
use tarragon::config::Config;
use tarragon::metrics::FailureClass;
use tarragon::runtime::kern;
use tarragon::testing::scenario::Scenario;
use tarragon::testing::synthetic;

/// Stall budgets for the recovery-anatomy assertions: detection must
/// land within the silence window plus the full probe ladder (10ms
/// silence + 3 probes x (15ms timeout + 10ms interval) at 1ms wire
/// latency, measured from the victim's last pre-fault progress), and
/// no victim may stall longer than `MAX_STALL` end to end.
const MAX_DETECT: Duration = Duration::from_millis(250);
const MAX_STALL: Duration = Duration::from_secs(2);

/// Scenario base: 2 AWs × 2 EWs, and a transport latency high enough
/// that decode pacing is dominated by (virtual) wire time — failure
/// injection offsets then land deterministically mid-decode.
fn scenario_cfg(latency: Duration) -> Config {
    let mut cfg = Config::small_test();
    cfg.transport.latency = latency;
    // Virtual: bring-up and provisioning cost no wall time.
    cfg.transport.worker_extra_init = Duration::from_millis(200);
    cfg
}

/// Two requests, one per AW (gateway round-robin): req 0 -> aw0,
/// req 1 -> aw1.
fn two_request_scenario(name: &str, latency: Duration) -> Scenario {
    Scenario::new(name, scenario_cfg(latency))
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
}

fn assert_streams_match(faulty: &tarragon::testing::scenario::ScenarioOutcome, name: &str) {
    assert!(faulty.completed, "{name}: faulty run did not drain");
    for (id, toks) in &faulty.tokens {
        assert_eq!(toks.len(), 32, "{name}: req {id} truncated");
    }
}

#[test]
fn ew_kill_mid_decode_replays_to_shadows_with_identical_streams() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = two_request_scenario("ew-kill", Duration::from_millis(1))
        .fault("at 60ms kill ew0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_streams_match(&faulty, "ew-kill");
    assert_eq!(faulty.tokens, clean.tokens, "EW failover changed token streams");
    assert!(faulty.report.ew_failures >= 1, "EW failure went unhandled");
    assert_eq!(faulty.report.aw_failures, 0);
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
    assert!(
        faulty.recovery.incidents.iter().all(|i| i.class == FailureClass::Ew),
        "EW kill must attribute as an EW incident:\n{}",
        faulty.recovery.render()
    );
    assert!(clean.recovery.is_empty(), "failure-free run must have no incidents");
}

#[test]
fn ew_kill_under_simd_backend_keeps_streams_identical() {
    let (manifest, weights, _) = synthetic::ensure();
    // The recovery guarantee is backend-relative: a cluster running the
    // simd kernels everywhere must replay onto shadows with streams
    // identical to its own failure-free run (which is itself
    // deterministic — same bits on every execution).
    let mut cfg = scenario_cfg(Duration::from_millis(1));
    cfg.kernels.backend = kern::BackendKind::Simd;
    let s = Scenario::new("ew-kill-simd", cfg)
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
        .fault("at 60ms kill ew0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let again = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_streams_match(&faulty, "ew-kill-simd");
    assert_eq!(clean.tokens, again.tokens, "simd backend must be deterministic run to run");
    assert_eq!(faulty.tokens, clean.tokens, "EW failover under simd changed token streams");
    assert!(faulty.report.ew_failures >= 1, "EW failure went unhandled");
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
}

#[test]
fn aw_kill_before_first_commit_resubmits_from_prompt() {
    let (manifest, weights, _) = synthetic::ensure();
    // Slow wire (5 ms latency): prefill spans tens of virtual ms, so a
    // kill 8 ms after submission reliably lands before the first commit.
    let s = Scenario::new("aw-kill-precommit", scenario_cfg(Duration::from_millis(5)))
        .request(0, Duration::from_millis(20), vec![1, 2, 3, 4, 5, 6, 7, 8], 16)
        .fault("at 28ms kill aw0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_eq!(faulty.tokens, clean.tokens, "prompt resubmission changed token streams");
    assert!(faulty.report.aw_failures >= 1);
    // The request went through the gateway's resubmit path (Migrated).
    assert!(
        faulty.event_log.contains("migrated"),
        "expected a resubmission in the event log:\n{}",
        faulty.event_log
    );
    // 5ms wire latency slows every probe hop: looser detect budget.
    faulty.assert_recovery(1, Duration::from_millis(500), MAX_STALL);
    assert!(
        faulty.recovery.incidents.iter().any(|i| i.class == FailureClass::Aw),
        "AW kill must attribute as an AW incident:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn aw_kill_after_commit_adopts_restores_and_resumes() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = two_request_scenario("aw-kill-adopt", Duration::from_millis(1))
        .fault("at 60ms kill aw0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_streams_match(&faulty, "aw-kill-adopt");
    assert_eq!(faulty.tokens, clean.tokens, "adopt->restore->resume changed token streams");
    assert!(faulty.report.aw_failures >= 1);
    // Mid-decode kill with committed checkpoints: restoration, not
    // resubmission — the stream continues from the committed token.
    assert_eq!(faulty.report.finished, 2);
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
    // The adopt path pulls from the checkpoint store: at least one
    // victim must show a real (non-zero) restore phase, ordered inside
    // its total stall.
    assert!(
        faulty.recovery.victims().any(|v| v.restore_s > 0.0),
        "adoption must exercise a checkpoint restore:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn aw_kill_with_warm_shared_prefix_adopts_and_streams_identically() {
    let (manifest, weights, _) = synthetic::ensure();
    // Requests 0 and 2 land on aw0 (gateway round-robin) with an
    // identical 16-token prompt — exactly one full KV page per layer
    // (page_tokens = 16), so the later prefill takes verified refs on
    // the sealed pages instead of rewriting them, and its checkpoint
    // emits page references the store resolves from its content index.
    // Killing aw0 then forces the adopter to rebuild both requests from
    // the store, re-sealing and re-sharing the warm prefix; the streams
    // must still be byte-identical to the failure-free run.
    let prompt: Vec<u32> = (1..=16).collect();
    let s = Scenario::new("aw-kill-shared-prefix", scenario_cfg(Duration::from_millis(1)))
        .request(0, Duration::ZERO, prompt.clone(), 16)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 16)
        .request(2, Duration::from_millis(10), prompt, 16)
        .fault("at 70ms kill aw0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    for (id, toks) in &faulty.tokens {
        assert_eq!(toks.len(), 16, "shared-prefix: req {id} truncated");
    }
    assert_eq!(faulty.tokens, clean.tokens, "warm shared prefix changed recovery streams");
    assert!(
        clean.report.sharing.prefix_hits > 0,
        "identical one-page prompts on one AW must share"
    );
    assert!(
        faulty.report.sharing.prefix_hits > 0,
        "recovery must re-establish the shared prefix"
    );
    assert!(faulty.report.aw_failures >= 1);
    assert_eq!(faulty.report.finished, 3);
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
}

#[test]
fn link_sever_self_heals_locally_without_global_recovery() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = two_request_scenario("sever", Duration::from_millis(1))
        .fault("at 60ms sever aw0 ew0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_eq!(faulty.tokens, clean.tokens, "link sever changed token streams");
    // Both endpoints stay alive: the orchestrator must treat the failure
    // reports as stale (nodes reachable) — purely local rerouting.
    assert_eq!(faulty.report.ew_failures, 0, "sever must not trigger EW recovery");
    assert_eq!(faulty.report.aw_failures, 0, "sever must not trigger AW recovery");
    // The severed REFE still sees its probe fail and reroutes locally;
    // any incident it logs must be EW-class with a pure local reroute —
    // no checkpoint restore phase (that would mean global recovery ran).
    for i in &faulty.recovery.incidents {
        assert_eq!(i.class, FailureClass::Ew, "sever can only look like a local EW loss");
        for v in &i.victims {
            assert_eq!(
                v.restore_s, 0.0,
                "sever must self-heal without a restore:\n{}",
                faulty.recovery.render()
            );
        }
    }
}

#[test]
fn simultaneous_aw_and_ew_failure_recovers_both() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = two_request_scenario("aw-plus-ew", Duration::from_millis(1))
        .fault("at 60ms kill aw0")
        .fault("at 60ms kill ew1");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_streams_match(&faulty, "aw-plus-ew");
    assert_eq!(faulty.tokens, clean.tokens, "simultaneous failure changed token streams");
    assert!(faulty.report.aw_failures >= 1);
    assert!(faulty.report.ew_failures >= 1);
    // Two distinct incidents — one per class — each within budget.
    faulty.assert_recovery(2, MAX_DETECT, MAX_STALL);
    let classes: Vec<_> = faulty.recovery.incidents.iter().map(|i| i.class).collect();
    assert!(
        classes.contains(&FailureClass::Aw) && classes.contains(&FailureClass::Ew),
        "expected one AW and one EW incident:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn kill_then_respawn_without_provisioning_restores_capacity() {
    let (manifest, weights, _) = synthetic::ensure();
    let mut cfg = scenario_cfg(Duration::from_millis(1));
    cfg.resilience.provisioning = false; // the DSL respawn is the only replacement
    let s = Scenario::new("respawn", cfg)
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
        .fault("at 60ms kill ew0")
        .fault("at 400ms respawn ew0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_eq!(faulty.tokens, clean.tokens, "kill+respawn changed token streams");
    assert!(faulty.report.ew_failures >= 1);
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
}

// ---------------------------------------------------------------------------
// Elastic EW scaling (DESIGN.md §11)
// ---------------------------------------------------------------------------

#[test]
fn scale_in_during_decode_keeps_streams_identical() {
    let (manifest, weights, _) = synthetic::ensure();
    // Retire ew0 mid-decode: its primaries remap onto ew1 (ring shadows
    // are already resident), in-flight dispatches resolve under the ERT
    // version they were routed under, and the streams must not move.
    let s = two_request_scenario("scale-in", Duration::from_millis(1))
        .fault("at 60ms scale_ew down ew0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_streams_match(&faulty, "scale-in");
    assert_eq!(faulty.tokens, clean.tokens, "scale-in changed token streams");
    assert!(faulty.report.scale_ins >= 1, "scale-in went unexecuted");
    // Planned mobility, not a failure: zero EW/AW recoveries.
    assert_eq!(faulty.report.ew_failures, 0, "scale-in must not count as an EW failure");
    assert_eq!(faulty.report.aw_failures, 0);
    assert!(
        faulty.recovery.is_empty(),
        "planned retirement must not register as an incident:\n{}",
        faulty.recovery.render()
    );
}

#[test]
fn hotspot_drives_shadow_promotion_with_identical_streams() {
    let (manifest, weights, _) = synthetic::ensure();
    let mut cfg = scenario_cfg(Duration::from_millis(1));
    cfg.scaler.enabled = true;
    cfg.scaler.window = Duration::from_millis(30);
    cfg.scaler.hot_threshold = 4;
    cfg.scaler.cold_threshold = 0; // scale-in off: isolate the promotion
    cfg.scaler.cooldown = Duration::from_secs(10); // at most one action
    let s = Scenario::new("hotspot-promote", cfg.clone())
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
        .fault("at 0ms hotspot e1");
    // Baseline: same workload and hotspot skew, scaler off — proves the
    // promotion (not the skew) is what is being exercised, and that it
    // leaves the streams untouched.
    let mut base_cfg = cfg;
    base_cfg.scaler.enabled = false;
    let base = Scenario::new("hotspot-base", base_cfg)
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
        .fault("at 0ms hotspot e1");
    let clean = base.run(manifest.clone(), weights.clone());
    let scaled = s.run(manifest, weights);
    assert!(clean.completed && scaled.completed);
    assert_eq!(scaled.tokens, clean.tokens, "shadow promotion changed token streams");
    assert!(
        scaled.report.shadow_promotions >= 1,
        "hotspot never drove a promotion (scale_outs={}, event log:\n{})",
        scaled.report.scale_outs,
        scaled.event_log
    );
    assert_eq!(scaled.report.ew_failures, 0, "promotion must not count as a failure");
    assert!(scaled.event_log.contains("shadow_promoted"), "event log missing the promotion");
    assert!(
        scaled.recovery.is_empty(),
        "promotion must not register as an incident:\n{}",
        scaled.recovery.render()
    );
}

#[test]
fn scale_out_racing_an_ew_kill_recovers_with_identical_streams() {
    let (manifest, weights, _) = synthetic::ensure();
    // A fresh universal-shadow EW provisions while ew0 dies: the failover
    // (to ring shadows) and the scale-out (new tail candidates) interleave
    // on the same ERT datapath, and the streams still must not move.
    let s = two_request_scenario("scale-race", Duration::from_millis(1))
        .fault("at 55ms scale_ew up")
        .fault("at 60ms kill ew0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed);
    assert_streams_match(&faulty, "scale-race");
    assert_eq!(faulty.tokens, clean.tokens, "scale-out racing a kill changed streams");
    assert!(faulty.report.ew_failures >= 1, "the kill is a real failure");
    assert!(faulty.report.scale_outs >= 1, "scale-out went unexecuted");
    faulty.assert_recovery(1, MAX_DETECT, MAX_STALL);
}

#[test]
fn scale_down_of_last_replica_is_rejected_not_stranded() {
    let (manifest, weights, _) = synthetic::ensure();
    let mut cfg = scenario_cfg(Duration::from_millis(1));
    // No shadows: every expert has exactly one replica, so retiring any
    // EW would strand its experts — the orchestrator must refuse and the
    // workload must still drain on the untouched layout.
    cfg.resilience.shadow_experts = false;
    let s = Scenario::new("scale-down-last", cfg)
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
        .fault("at 60ms scale_ew down ew0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let faulty = s.run(manifest, weights);
    assert!(clean.completed && faulty.completed, "rejected scale-in must not strand tokens");
    assert_eq!(faulty.tokens, clean.tokens);
    assert!(faulty.report.scale_rejected >= 1, "last-replica scale-in must be rejected");
    assert_eq!(faulty.report.scale_ins, 0, "nothing may actually retire");
    assert_eq!(faulty.report.ew_failures, 0);
    assert!(faulty.recovery.is_empty(), "a refused scale-in must leave no incident");
}

#[test]
fn same_seed_replays_byte_identical_event_logs() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = two_request_scenario("determinism", Duration::from_millis(1))
        .fault("at 60ms kill ew0")
        .seed(42);
    let a = s.run(manifest.clone(), weights.clone());
    let b = s.run(manifest.clone(), weights.clone());
    assert!(a.completed && b.completed);
    assert!(!a.event_log.is_empty());
    assert_eq!(a.event_log, b.event_log, "same scenario + seed must replay identically");
    assert_eq!(a.tokens, b.tokens);

    // A different seed may interleave differently (timestamps can move),
    // but the final token streams are invariant.
    let c = s.clone().seed(43).run(manifest, weights);
    assert!(c.completed);
    assert_eq!(c.tokens, a.tokens, "token streams must be seed-invariant");
}
