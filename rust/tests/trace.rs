//! End-to-end recovery-anatomy tracing (DESIGN.md §14): a full cluster
//! run with `[trace]` enabled must capture the failure lifecycle as
//! spans — queueing, prefill/decode, dispatch, checkpoint emit/commit,
//! detection, restore pull/install — and export them as Perfetto
//! trace-event JSON that parses and carries the restore anatomy.
//!
//! The flip side is the observer-effect contract: enabling tracing must
//! not move the workload. Token streams and the canonical event-log
//! rendering are asserted byte-identical between a trace-off and a
//! trace-on run of the same scenario + seed.

use std::time::Duration;
use tarragon::metrics::export::{perfetto_json, prometheus_text};
use tarragon::metrics::trace::SpanKind;
use tarragon::testing::scenario::Scenario;
use tarragon::testing::synthetic;
use tarragon::util::json::Json;

/// The aw-kill-adopt scenario from the scenario suite: mid-decode AW
/// death with committed checkpoints, so the full detect → adopt →
/// restore → resume anatomy runs.
fn adopt_scenario(trace: bool) -> Scenario {
    let mut cfg = tarragon::config::Config::small_test();
    cfg.transport.latency = Duration::from_millis(1);
    cfg.transport.worker_extra_init = Duration::from_millis(200);
    cfg.trace.enabled = trace;
    Scenario::new(if trace { "trace-on" } else { "trace-off" }, cfg)
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
        .fault("at 60ms kill aw0")
}

#[test]
fn traced_failure_run_exports_restore_anatomy_as_perfetto_json() {
    let (manifest, weights, _) = synthetic::ensure();
    let out = adopt_scenario(true).run(manifest, weights);
    assert!(out.completed, "traced run did not drain");
    assert!(!out.spans.is_empty(), "trace-on run captured no spans");

    // The span log covers the whole recovery anatomy, not just the
    // steady state.
    let has = |k: SpanKind| out.spans.iter().any(|sp| sp.kind == k);
    assert!(has(SpanKind::GatewayQueue), "missing gateway queueing span");
    assert!(has(SpanKind::Prefill), "missing prefill span");
    assert!(has(SpanKind::DecodeStep), "missing decode-step span");
    assert!(has(SpanKind::DispatchRound), "missing REFE dispatch span");
    assert!(has(SpanKind::ExpertBatch), "missing EW expert-batch span");
    assert!(has(SpanKind::CkptEmit), "missing checkpoint-emit span");
    assert!(has(SpanKind::CkptCommit), "missing checkpoint-commit span");
    assert!(has(SpanKind::RestorePull), "missing restore-pull span");
    assert!(has(SpanKind::RestoreInstall), "missing restore-install span");

    // Every span is well-formed: end >= start, restore spans name the
    // adopted request.
    for sp in &out.spans {
        assert!(sp.end >= sp.start, "span ends before it starts: {sp:?}");
    }
    assert!(
        out.spans
            .iter()
            .any(|sp| sp.kind == SpanKind::RestoreInstall && sp.request == 0),
        "restore-install must carry the victim request id"
    );

    // The Perfetto export parses and carries >= 1 restore_install event.
    let text = perfetto_json(&out.spans).to_string();
    let doc = Json::parse(&text).expect("perfetto export must be valid JSON");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), out.spans.len());
    let installs = events
        .iter()
        .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("restore_install"))
        .count();
    assert!(installs >= 1, "exported trace lost the restore anatomy");

    // Prometheus exposition of the same run stays well-formed.
    let prom = prometheus_text(&out.report);
    assert!(prom.contains("tarragon_aw_failures_total 1"));
    for line in prom.lines() {
        assert!(
            line.starts_with('#') || line.split_whitespace().count() == 2,
            "malformed exposition line: {line:?}"
        );
    }
}

#[test]
fn tracing_does_not_perturb_the_run() {
    let (manifest, weights, _) = synthetic::ensure();
    let off = adopt_scenario(false).run(manifest.clone(), weights.clone());
    let on = adopt_scenario(true).run(manifest, weights);
    assert!(off.completed && on.completed);
    assert!(off.spans.is_empty(), "trace-off run must record no spans");
    assert_eq!(on.tokens, off.tokens, "tracing changed the token streams");
    assert_eq!(
        on.event_log, off.event_log,
        "tracing changed the event log — the observer effect is real"
    );
    // Stall attribution is derived from the (unconditional) lifecycle
    // events, so it is available with tracing off too.
    assert!(!off.recovery.is_empty());
    assert_eq!(off.recovery.incidents.len(), on.recovery.incidents.len());
}
