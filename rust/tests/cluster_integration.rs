//! End-to-end integration: the full TARRAGON cluster (gateway,
//! orchestrator, checkpoint store, AWs, EWs over the simulated fabric)
//! must generate exactly the tokens of the pure-jnp golden fixture, with
//! and without injected failures.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use tarragon::config::Config;
use tarragon::coordinator::cluster::{Cluster, LaunchOptions};
use tarragon::modelcfg::{weights::Weights, Manifest};
use tarragon::util::json::Json;
use tarragon::workload::Request;

fn setup() -> Option<(Arc<Manifest>, Weights, Vec<(Vec<u32>, Vec<u32>)>)> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = Arc::new(Manifest::load(&dir).unwrap());
    let weights = Weights::load(&manifest).unwrap();
    let golden = load_golden(dir.join("golden.json"));
    Some((manifest, weights, golden))
}

fn load_golden(path: PathBuf) -> Vec<(Vec<u32>, Vec<u32>)> {
    let j = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
    j.get("cases")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| {
            let p = c.get("prompt").unwrap().usize_vec().unwrap();
            let g = c.get("generated").unwrap().usize_vec().unwrap();
            (
                p.into_iter().map(|x| x as u32).collect(),
                g.into_iter().map(|x| x as u32).collect(),
            )
        })
        .collect()
}

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.num_aws = 2;
    cfg.cluster.num_ews = 2;
    cfg.transport.worker_extra_init = Duration::from_millis(10);
    cfg
}

fn golden_schedule(golden: &[(Vec<u32>, Vec<u32>)]) -> Vec<Request> {
    golden
        .iter()
        .enumerate()
        .map(|(i, (prompt, gen))| Request {
            id: i as u64,
            arrival_s: 0.01 * i as f64,
            prompt: prompt.clone(),
            max_new_tokens: gen.len(),
        })
        .collect()
}

#[test]
fn cluster_matches_golden_fixture() {
    let Some((manifest, weights, golden)) = setup() else { return };
    let cluster = Cluster::launch(
        small_cfg(),
        manifest,
        weights,
        golden_schedule(&golden),
        LaunchOptions::default(),
    );
    assert!(cluster.wait_done(Duration::from_secs(120)), "workload did not drain");
    for (i, (_, want)) in golden.iter().enumerate() {
        let got = cluster.gw.generated_of(i as u64);
        assert_eq!(&got, want, "request {i} tokens diverge from jnp oracle");
    }
    let report = cluster.finish(1.0);
    assert_eq!(report.finished, golden.len());
    assert_eq!(report.aw_failures + report.ew_failures, 0);
}

#[test]
fn cluster_survives_ew_failure_with_identical_tokens() {
    let Some((manifest, weights, golden)) = setup() else { return };
    // Longer decode so the failure lands mid-generation.
    let schedule = vec![Request {
        id: 0,
        arrival_s: 0.0,
        prompt: golden[0].0.clone(),
        max_new_tokens: 120,
    }];
    let cluster = Cluster::launch(
        small_cfg(),
        manifest.clone(),
        weights.clone(),
        schedule.clone(),
        LaunchOptions::default(),
    );
    std::thread::sleep(Duration::from_millis(150));
    cluster.kill_ew(0);
    assert!(cluster.wait_done(Duration::from_secs(180)), "did not drain after EW failure");
    let got = cluster.gw.generated_of(0);
    let report = cluster.finish(1.0);
    assert_eq!(report.finished, 1);

    // Reference: same schedule, no failure.
    let c2 = Cluster::launch(small_cfg(), manifest, weights, schedule, LaunchOptions::default());
    assert!(c2.wait_done(Duration::from_secs(120)));
    let want = c2.gw.generated_of(0);
    c2.finish(1.0);
    assert_eq!(got, want, "EW failover changed generated tokens");
}

#[test]
fn cluster_survives_aw_failure_with_identical_tokens() {
    let Some((manifest, weights, golden)) = setup() else { return };
    let schedule = vec![Request {
        id: 0,
        arrival_s: 0.0,
        prompt: golden[0].0.clone(),
        max_new_tokens: 120,
    }];
    let cluster = Cluster::launch(
        small_cfg(),
        manifest.clone(),
        weights.clone(),
        schedule.clone(),
        LaunchOptions::default(),
    );
    // Let it decode a while, then kill the AW that owns request 0.
    std::thread::sleep(Duration::from_millis(150));
    cluster.kill_aw(0);
    assert!(cluster.wait_done(Duration::from_secs(180)), "did not drain after AW failure");
    let got = cluster.gw.generated_of(0);
    let report = cluster.finish(1.0);
    assert_eq!(report.finished, 1, "request did not finish after AW failover");
    assert!(report.aw_failures >= 1);

    let c2 = Cluster::launch(small_cfg(), manifest, weights, schedule, LaunchOptions::default());
    assert!(c2.wait_done(Duration::from_secs(120)));
    let want = c2.gw.generated_of(0);
    c2.finish(1.0);
    assert_eq!(got.len(), want.len(), "token count differs after AW failover");
    assert_eq!(got, want, "AW restoration changed generated tokens");
}

// ---------------------------------------------------------------------------
// Baselines
// ---------------------------------------------------------------------------

use tarragon::baselines::{megascale, VllmEngine, VllmKind};
use tarragon::baselines::vllm::VllmOptions;

#[test]
fn vllm_tp_matches_golden_fixture() {
    let Some((manifest, weights, golden)) = setup() else { return };
    let report = VllmEngine::run(
        manifest,
        weights,
        golden_schedule(&golden),
        VllmOptions { worker_extra_init: Duration::from_millis(10), ..Default::default() },
    );
    assert_eq!(report.finished, golden.len());
    for (i, (_, want)) in golden.iter().enumerate() {
        assert_eq!(report.generated[&(i as u64)], *want, "vllm-tp diverges on req {i}");
    }
    assert!(report.analysis.total_tokens > 0);
}

#[test]
fn vllm_pp_matches_golden_fixture() {
    let Some((manifest, weights, golden)) = setup() else { return };
    let report = VllmEngine::run(
        manifest,
        weights,
        golden_schedule(&golden),
        VllmOptions {
            kind: VllmKind::Pp,
            worker_extra_init: Duration::from_millis(10),
            ..Default::default()
        },
    );
    assert_eq!(report.finished, golden.len());
    for (i, (_, want)) in golden.iter().enumerate() {
        assert_eq!(report.generated[&(i as u64)], *want, "vllm-pp diverges on req {i}");
    }
}

#[test]
fn megascale_baseline_serves_without_failures() {
    let Some((manifest, weights, golden)) = setup() else { return };
    let cfg = megascale::megascale_config(small_cfg());
    let cluster = Cluster::launch(
        cfg,
        manifest,
        weights,
        golden_schedule(&golden),
        megascale::megascale_options(),
    );
    assert!(cluster.wait_done(Duration::from_secs(120)));
    for (i, (_, want)) in golden.iter().enumerate() {
        assert_eq!(&cluster.gw.generated_of(i as u64), want, "megascale diverges on req {i}");
    }
    let report = cluster.finish(1.0);
    assert_eq!(report.finished, golden.len());
    assert_eq!(report.restarts, 0);
}

#[test]
fn megascale_coarse_restart_recovers_after_failure() {
    let Some((manifest, weights, golden)) = setup() else { return };
    let cfg = megascale::megascale_config(small_cfg());
    let schedule = vec![Request {
        id: 0,
        arrival_s: 0.0,
        prompt: golden[0].0.clone(),
        max_new_tokens: 60,
    }];
    let cluster = Cluster::launch(
        cfg,
        manifest,
        weights,
        schedule,
        megascale::megascale_options(),
    );
    std::thread::sleep(Duration::from_millis(150));
    cluster.kill_ew(0);
    assert!(
        cluster.wait_done(Duration::from_secs(300)),
        "baseline did not recover via coarse restart"
    );
    let got = cluster.gw.generated_of(0);
    let report = cluster.finish(1.0);
    assert_eq!(report.finished, 1);
    assert!(report.restarts >= 1, "expected a full restart");
    assert_eq!(got.len(), 60);
    // Recovery must have produced a visible stall >= the CCL abort budget.
    assert!(
        report.analysis.max_token_gap_s >= 1.0,
        "expected a long stall, got {}",
        report.analysis.max_token_gap_s
    );
}
