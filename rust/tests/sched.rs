//! Overload-scheduler scenario matrix (DESIGN.md §9) on the virtual
//! clock: KV-pressure admission, queueing backpressure, checkpoint-backed
//! preemption, and the planned `drain`/`migrate` verbs.
//!
//! The invariant under test everywhere: however hard the cluster is
//! oversubscribed, *zero requests are dropped* and every preempted /
//! migrated / drained request's token stream is byte-identical to the
//! uncontended baseline — and no AW arena ever exceeds its page budget.

use std::time::Duration;
use tarragon::config::Config;
use tarragon::testing::scenario::Scenario;
use tarragon::testing::synthetic;

/// Scenario base: 2 AWs × 2 EWs with an optional per-AW KV page budget
/// (0 = unbounded, the uncontended baseline).
fn sched_cfg(budget_pages: usize) -> Config {
    let mut cfg = Config::small_test();
    cfg.transport.latency = Duration::from_millis(1);
    cfg.transport.worker_extra_init = Duration::from_millis(200);
    cfg.sched.kv_budget_pages = budget_pages;
    cfg
}

/// Overload burst: 6 requests of (8-token prompt, 24 new tokens) arriving
/// within 10 ms. Worst-case footprint is 4 pages each (2 layers × 2
/// pages), so with `budget_pages = 8` per AW the offered load exceeds the
/// aggregate KV budget and the cluster must queue + preempt to survive.
fn burst_scenario(name: &str, budget_pages: usize) -> Scenario {
    let mut s = Scenario::new(name, sched_cfg(budget_pages));
    for i in 0..6u64 {
        s = s.request(
            i,
            Duration::from_millis(2 * i),
            vec![(1 + i) as u32, 2, 3, 4, 5, 6, 7, 8],
            24,
        );
    }
    s
}

#[test]
fn overload_burst_completes_with_zero_drops_and_identical_streams() {
    let (manifest, weights, _) = synthetic::ensure();
    let uncontended = burst_scenario("burst-baseline", 0).run(manifest.clone(), weights.clone());
    assert!(uncontended.completed);
    assert_eq!(uncontended.report.finished, 6);

    let overloaded = burst_scenario("burst-overload", 8).run(manifest, weights);
    assert!(overloaded.completed, "overloaded run did not drain:\n{}", overloaded.event_log);
    // Zero drops: every request was admitted (possibly after queueing)
    // and finished.
    assert_eq!(overloaded.report.submitted, 6);
    assert_eq!(overloaded.report.finished, 6, "requests were dropped under overload");
    assert_eq!(overloaded.report.rejected, 0);
    // Byte-identical streams vs the uncontended baseline.
    assert_eq!(
        overloaded.tokens, uncontended.tokens,
        "preemption/queueing changed token streams"
    );
    for (id, toks) in &overloaded.tokens {
        assert_eq!(toks.len(), 24, "req {id} truncated");
    }
    // The page budget is a hard invariant.
    overloaded.assert_kv_budget_held();
}

#[test]
fn pressure_preemption_triggers_and_replays_deterministically() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = burst_scenario("preempt-pressure", 8).seed(42);
    let a = s.run(manifest.clone(), weights.clone());
    assert!(a.completed);
    assert!(
        a.report.preemptions > 0,
        "offered load above the KV budget must trigger preemption\n{}",
        a.event_log
    );
    assert!(a.event_log.contains("preempted"), "preemptions missing from the event log");
    a.assert_kv_budget_held();

    // Same scenario + seed: byte-identical event logs.
    let b = s.run(manifest.clone(), weights.clone());
    assert!(b.completed);
    assert_eq!(a.event_log, b.event_log, "same seed must replay byte-identically");
    assert_eq!(a.tokens, b.tokens);

    // Different seed: timestamps may move, token streams may not.
    let c = s.clone().seed(1007).run(manifest, weights);
    assert!(c.completed);
    assert_eq!(c.tokens, a.tokens, "token streams must be seed-invariant");
}

/// Two requests, one per AW (least-pressure placement with queue-depth
/// tie-breaks lands req 0 on aw0, req 1 on aw1).
fn two_request_scenario(name: &str, budget_pages: usize) -> Scenario {
    Scenario::new(name, sched_cfg(budget_pages))
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
}

#[test]
fn drain_aw_migrates_all_requests_with_identical_streams() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = two_request_scenario("drain", 0).fault("at 60ms drain aw0");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let drained = s.run(manifest, weights);
    assert!(clean.completed && drained.completed);
    assert_eq!(drained.tokens, clean.tokens, "drain changed token streams");
    assert_eq!(drained.report.finished, 2);
    // The drain is planned mobility, not a failure.
    assert_eq!(drained.report.aw_failures, 0, "drain must not look like a failure");
    assert!(
        drained.report.preemptions >= 1,
        "drain must evict via the checkpoint path:\n{}",
        drained.event_log
    );
    assert!(
        drained.event_log.contains("migrated"),
        "drained requests must re-admit elsewhere:\n{}",
        drained.event_log
    );
}

#[test]
fn migrate_verb_steers_requests_onto_the_named_target() {
    let (manifest, weights, _) = synthetic::ensure();
    let s = two_request_scenario("migrate", 0).fault("at 60ms migrate aw0 aw1");
    let clean = s.without_faults().run(manifest.clone(), weights.clone());
    let moved = s.run(manifest, weights);
    assert!(clean.completed && moved.completed);
    assert_eq!(moved.tokens, clean.tokens, "migration changed token streams");
    assert_eq!(moved.report.aw_failures, 0);
    assert!(moved.report.preemptions >= 1);
    // The migrated request re-binds onto aw1 specifically.
    assert!(
        moved.event_log.contains("migrated req=0 idx=0 worker=1"),
        "expected req 0 to land on aw1:\n{}",
        moved.event_log
    );
}

#[test]
fn oversized_prompt_is_rejected_at_the_gateway_with_an_error() {
    let (manifest, weights, _) = synthetic::ensure();
    // Prompt of 20 tokens exceeds the synthetic model's largest prefill
    // bucket (16). The old AW path dropped it silently and the run hung
    // until the drain timeout; now the gateway rejects it up front.
    let s = Scenario::new("oversized", sched_cfg(0))
        .request(0, Duration::ZERO, (1..=20).collect(), 8)
        .request(1, Duration::from_millis(2), vec![1, 2, 3], 8);
    let out = s.run(manifest, weights);
    assert!(out.completed, "a rejected request must not stall the drain");
    assert_eq!(out.report.rejected, 1);
    assert_eq!(out.report.finished, 1, "the well-formed request must still finish");
    let err = out.rejections.get(&0).expect("stream-level error for req 0");
    assert!(err.contains("prefill bucket"), "unhelpful rejection reason: {err}");
    assert!(out.event_log.contains("rejected req=0"), "rejection missing from event log");
    assert_eq!(out.tokens[&1].len(), 8);
    assert!(out.tokens[&0].is_empty(), "rejected requests produce no tokens");
}

#[test]
fn oversized_kv_footprint_is_rejected_when_budgeted() {
    let (manifest, weights, _) = synthetic::ensure();
    // 8 + 120 = 128 tokens -> 8 pages/layer x 2 layers = 16 pages, over
    // a 8-page budget: can never be served, reject at admission.
    let s = Scenario::new("oversized-kv", sched_cfg(8))
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 120)
        .request(1, Duration::from_millis(2), vec![1, 2, 3], 8);
    let out = s.run(manifest, weights);
    assert!(out.completed);
    assert_eq!(out.report.rejected, 1);
    assert_eq!(out.report.finished, 1);
    assert!(out.rejections.get(&0).expect("error").contains("budget"));
    out.assert_kv_budget_held();
}

#[test]
fn queueing_backpressure_shows_up_as_queued_admissions_not_drops() {
    let (manifest, weights, _) = synthetic::ensure();
    // A tight budget (one worst-case request per AW) forces later
    // arrivals to wait at the gateway until headroom opens.
    let mut s = Scenario::new("backpressure", sched_cfg(4));
    for i in 0..4u64 {
        s = s.request(i, Duration::from_millis(i), vec![(1 + i) as u32, 2, 3, 4], 20);
    }
    let out = s.run(manifest, weights);
    assert!(out.completed, "backpressured run did not drain:\n{}", out.event_log);
    assert_eq!(out.report.finished, 4, "backpressure must not drop requests");
    assert_eq!(out.report.rejected, 0);
    out.assert_kv_budget_held();
    // Tokens are complete for everyone.
    for (id, toks) in &out.tokens {
        assert_eq!(toks.len(), 20, "req {id} truncated");
    }
}
