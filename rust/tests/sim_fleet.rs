//! tier1-fleet: fleet-scale macro-sim smoke, determinism, and
//! cross-validation against the real-math harness.
//!
//! The macro-sim (`tarragon::sim`) replaces the data plane with cost
//! accounting but drives the production router/scaler/ERT policies, so
//! these tests assert three things: (1) an O(100)-worker fleet survives
//! the full scenario-DSL fault vocabulary with nothing lost and every
//! control-plane class detected, (2) runs are byte-deterministic, and
//! (3) a small macro-sim run and the same scenario on the real harness
//! both satisfy the same recovery budgets.
//!
//! The O(1000)-worker / 10^6-request replay is `#[ignore]`d (minutes of
//! CPU): `cargo test --release --test sim_fleet -- --ignored`.

use std::time::Duration;
use tarragon::config::Config;
use tarragon::metrics::export::prometheus_text;
use tarragon::metrics::FailureClass;
use tarragon::sim::{run_fleet, EventLevel, FleetConfig, TraceSpec};
use tarragon::testing::scenario::{Scenario, ScheduledFault};
use tarragon::testing::synthetic;

/// Budgets shared with the real-harness scenario matrix
/// (`rust/tests/scenarios.rs`): detection within the silence window +
/// probe ladder, and a bounded end-to-end stall.
const MAX_DETECT: Duration = Duration::from_millis(250);
const MAX_STALL: Duration = Duration::from_secs(2);

fn faults(lines: &[&str]) -> Vec<ScheduledFault> {
    lines
        .iter()
        .map(|l| ScheduledFault::parse(l).expect("fault DSL line"))
        .collect()
}

/// O(100) workers: 64 AWs + 32 EWs + replicated control plane, a bursty
/// trace, and every fault verb the scenario DSL knows.
fn smoke_cfg() -> FleetConfig {
    let mut cfg = FleetConfig::new(64, 32);
    cfg.scaler.enabled = true;
    cfg.scaler.hot_threshold = 64;
    cfg.scaler.cold_threshold = 0; // scale-in exercised via the DSL verb
    cfg
}

fn smoke_faults() -> Vec<ScheduledFault> {
    faults(&[
        "at 0ms hotspot e7",
        "at 1s sever aw1 ew2",
        "at 2s kill aw3",
        "at 2500ms kill store0",
        "at 3s kill ew5",
        "at 3500ms kill gateway1",
        "at 4s respawn aw3",
        "at 4500ms kill orch",
        "at 5s drain aw10",
        "at 5s scale_ew up",
        "at 6s respawn ew5",
        "at 7s migrate aw11 aw12",
        "at 8s scale_ew down ew20",
    ])
}

#[test]
fn fleet_smoke_survives_the_full_fault_vocabulary() {
    let trace = TraceSpec::bursty(400.0, Duration::from_secs(10), 20260807).generate();
    let r = run_fleet(smoke_cfg(), &trace, &smoke_faults());

    // Nothing lost: every submitted request finished or was rejected at
    // admission, and the strict gateway ledger never saw an unpaired
    // release.
    assert_eq!(r.report.submitted, trace.len());
    assert_eq!(r.report.finished + r.report.rejected, trace.len());
    assert_eq!(r.unfinished, 0, "requests stranded at the horizon");
    assert_eq!(r.unpaired_departures, 0, "gateway ledger lost pairing");
    assert_eq!(r.report.aw_failures, 1);
    assert_eq!(r.report.ew_failures, 1);
    assert_eq!(r.report.store_failovers, 1);
    assert_eq!(r.report.gateway_failovers, 1);
    assert_eq!(r.report.orch_promotions, 1);
    assert!(r.report.scale_outs >= 1, "scale_ew up must provision");
    assert!(r.report.preemptions >= 1, "drain/migrate must preempt residents");

    // Every control-plane failure class surfaced as a detected incident.
    let classes: Vec<FailureClass> =
        r.recovery.incidents.iter().map(|i| i.class).collect();
    for want in [
        FailureClass::Aw,
        FailureClass::Ew,
        FailureClass::Store,
        FailureClass::Gateway,
        FailureClass::Orch,
    ] {
        assert!(classes.contains(&want), "missing incident class {want:?}: {classes:?}");
    }

    // Detection is exact under the virtual clock: kill time + the
    // configured silence-window + probe-ladder latency.
    let detect = smoke_cfg().detection.as_secs_f64();
    for (class, killed_at) in
        [(FailureClass::Aw, 2.0), (FailureClass::Ew, 3.0)]
    {
        let inc = r
            .recovery
            .incidents
            .iter()
            .find(|i| i.class == class)
            .expect("incident present");
        let expected = killed_at + detect;
        assert!(
            (inc.t_detect_s - expected).abs() < 1e-6,
            "{class:?} detected at {} expected {expected}",
            inc.t_detect_s
        );
    }

    // The standard exporters consume the macro-sim report unchanged.
    let prom = prometheus_text(&r.report);
    assert!(prom.contains("tarragon_aw_failures_total 1"));
    assert!(prom.contains("tarragon_ew_failures_total 1"));
    assert!(prom.contains("tarragon_store_failovers_total 1"));
    let anatomy = r.recovery.render();
    assert!(anatomy.contains("aw"), "recovery anatomy renders:\n{anatomy}");
}

#[test]
fn fleet_runs_are_byte_deterministic() {
    let spec = TraceSpec::multi_tenant(TraceSpec::diurnal(
        100.0,
        Duration::from_secs(8),
        77,
    ));
    let trace = spec.generate();
    let fs = faults(&["at 1s kill ew1", "at 2s kill aw2", "at 3s respawn ew1"]);
    let mk = || {
        let mut cfg = FleetConfig::new(16, 8);
        cfg.scaler.enabled = true;
        cfg.scaler.hot_threshold = 64;
        cfg.scaler.cold_threshold = 0;
        cfg
    };
    let a = run_fleet(mk(), &trace, &fs);
    let b = run_fleet(mk(), &trace, &fs);
    // Same config + trace + faults ⇒ the rendered event logs are
    // byte-identical, not merely statistically similar.
    assert_eq!(a.events.render(), b.events.render());
    assert_eq!(a.report.finished, b.report.finished);
    assert_eq!(a.report.preemptions, b.report.preemptions);
    assert_eq!(a.sim_end, b.sim_end);
    assert_eq!(a.unpaired_departures, 0);
}

#[test]
fn macro_sim_and_real_harness_satisfy_the_same_recovery_budgets() {
    // Real-math harness: 2 AWs x 2 EWs, kill ew0 mid-run (the same
    // scenario the matrix in scenarios.rs asserts).
    let (manifest, weights, _) = synthetic::ensure();
    let mut cfg = Config::small_test();
    cfg.transport.latency = Duration::from_millis(1);
    cfg.transport.worker_extra_init = Duration::from_millis(200);
    let resilience = cfg.resilience.clone();
    let s = Scenario::new("xval-ew-kill", cfg)
        .request(0, Duration::ZERO, vec![1, 2, 3, 4, 5, 6, 7, 8], 32)
        .request(1, Duration::from_millis(5), vec![9, 10, 11], 32)
        .fault("at 60ms kill ew0");
    let real = s.run(manifest, weights);
    assert!(real.completed, "real harness did not drain");
    real.assert_recovery(1, MAX_DETECT, MAX_STALL);

    // Macro-sim: same topology, same fault schedule, detection latency
    // derived from the same ResilienceConfig.
    let mut mcfg = FleetConfig::new(2, 2);
    mcfg.detection = FleetConfig::detection_latency(&resilience);
    let trace = vec![
        tarragon::sim::SimRequest {
            id: 0,
            arrival: Duration::ZERO,
            prompt_len: 8,
            max_new: 32,
            tenant: 0,
        },
        tarragon::sim::SimRequest {
            id: 1,
            arrival: Duration::from_millis(5),
            prompt_len: 3,
            max_new: 32,
            tenant: 0,
        },
    ];
    let sim = run_fleet(mcfg.clone(), &trace, &faults(&["at 60ms kill ew0"]));
    assert_eq!(sim.report.finished, 2, "macro-sim lost a request");
    assert_eq!(sim.report.ew_failures, 1);
    assert_eq!(sim.unpaired_departures, 0);

    // Cross-validation: both stacks confirm the same death class inside
    // the same detection budget, and neither stalls past the cap.
    let sim_inc = sim
        .recovery
        .incidents
        .iter()
        .find(|i| i.class == FailureClass::Ew)
        .expect("macro-sim missed the EW incident");
    let real_has_ew =
        real.recovery.incidents.iter().any(|i| i.class == FailureClass::Ew);
    assert!(real_has_ew, "real harness missed the EW incident:\n{}", real.recovery.render());
    let sim_detect = sim_inc.t_detect_s - 0.060;
    assert!(
        (sim_detect - mcfg.detection.as_secs_f64()).abs() < 1e-6,
        "macro detection drifted: {sim_detect}"
    );
    assert!(
        sim_detect <= MAX_DETECT.as_secs_f64(),
        "macro detection {sim_detect} outside the shared budget"
    );
    for inc in &sim.recovery.incidents {
        for v in &inc.victims {
            assert!(
                v.total_stall_s <= MAX_STALL.as_secs_f64(),
                "macro victim stalled {}s",
                v.total_stall_s
            );
        }
    }
}

/// The headline scale claim: O(1000) workers, O(10^6) requests, one
/// process. Lifecycle event level keeps the log at ~5 events/request.
#[test]
#[ignore = "minutes of CPU; run with --release -- --ignored"]
fn full_scale_fleet_replays_a_million_requests() {
    let spec = TraceSpec::multi_tenant(TraceSpec::diurnal(
        4100.0,
        Duration::from_secs(250),
        1_000_003,
    ));
    let trace = spec.generate();
    assert!(
        trace.len() >= 1_000_000,
        "trace generator undershot: {}",
        trace.len()
    );
    let mut cfg = FleetConfig::new(1000, 250);
    cfg.event_level = EventLevel::Lifecycle;
    let fs = faults(&[
        "at 0ms hotspot e11",
        "at 30s kill aw7",
        "at 40s respawn aw7",
        "at 60s kill ew3",
        "at 80s respawn ew3",
        "at 100s drain aw500",
    ]);
    let r = run_fleet(cfg, &trace, &fs);
    assert_eq!(r.report.submitted, trace.len());
    assert_eq!(r.report.finished + r.report.rejected, trace.len());
    assert_eq!(r.unfinished, 0);
    assert_eq!(r.unpaired_departures, 0);
    assert_eq!(r.report.aw_failures, 1);
    assert_eq!(r.report.ew_failures, 1);
    let classes: Vec<FailureClass> =
        r.recovery.incidents.iter().map(|i| i.class).collect();
    assert!(classes.contains(&FailureClass::Aw));
    assert!(classes.contains(&FailureClass::Ew));
}
