//! Kernel-backend microbenchmark: the three FLOP-dominant decode ops —
//! blocked W^T matmul, RMSNorm, and one-step decode attention — timed
//! under the `reference` and `simd` backends (DESIGN.md §12).
//!
//! Every timed pair is also cross-checked numerically before it is
//! reported (ULP-style relative tolerance, the same contract the
//! property suite in `runtime/kern/simd.rs` pins), so a green bench run
//! doubles as a smoke check that the simd backend agrees with the
//! reference on realistic shapes.
//!
//! Run:   cargo bench --bench kernels            (full sweep, emits
//!        BENCH_kernels.json in the working directory)
//!        cargo bench --bench kernels -- --smoke (CI: tiny sweep)

use tarragon::runtime::kern::{self, BackendKind, KernelBackend};
use tarragon::testing::bench::{bench, black_box};
use tarragon::util::json::{arr, num, obj, s};
use tarragon::util::rng::Pcg;

const RMS_EPS: f32 = 1e-5;

fn rand_vec(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() - 0.5) * 0.2).collect()
}

/// Relative agreement check between the two backends' outputs: reduction
/// ops may differ by accumulation order, never by more than tight ULPs.
fn assert_close(op: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{op}: output lengths differ");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = 1e-4 * (1.0 + x.abs().max(y.abs()));
        assert!(
            (x - y).abs() <= tol,
            "{op}: backends disagree at {i}: reference={x} simd={y}"
        );
    }
}

struct Row {
    op: &'static str,
    shape: String,
    ref_median_us: f64,
    simd_median_us: f64,
}

impl Row {
    fn speedup(&self) -> f64 {
        self.ref_median_us / self.simd_median_us
    }
}

fn backends() -> [(&'static str, &'static dyn KernelBackend); 2] {
    [
        ("reference", kern::backend(BackendKind::Reference)),
        ("simd", kern::backend(BackendKind::Simd)),
    ]
}

fn bench_matmul(rows: &mut Vec<Row>, n: usize, k: usize, m: usize, warmup: usize, iters: usize) {
    let mut rng = Pcg::seeded(0x4A11 + (n * 31 + k * 7 + m) as u64);
    let x = rand_vec(&mut rng, n * k);
    let wt = rand_vec(&mut rng, m * k);
    let mut outs: Vec<Vec<f32>> = Vec::new();
    let mut medians = [0.0f64; 2];
    for (i, (name, bk)) in backends().into_iter().enumerate() {
        let mut out = vec![0.0f32; n * m];
        let label = format!("matmul[{n}x{k}x{m}] {name}");
        let r = bench(&label, warmup, iters, || {
            bk.matmul_wt_into(&x, &wt, n, k, m, &mut out);
            black_box(out.first().copied());
        });
        medians[i] = r.median_us;
        outs.push(out);
    }
    assert_close("matmul", &outs[0], &outs[1]);
    rows.push(Row {
        op: "matmul_wt_into",
        shape: format!("{n}x{k}x{m}"),
        ref_median_us: medians[0],
        simd_median_us: medians[1],
    });
}

fn bench_rms_norm(rows: &mut Vec<Row>, n: usize, h: usize, warmup: usize, iters: usize) {
    let mut rng = Pcg::seeded(0x4312 + (n * 131 + h) as u64);
    let x = rand_vec(&mut rng, n * h);
    let gamma = rand_vec(&mut rng, h);
    let mut outs: Vec<Vec<f32>> = Vec::new();
    let mut medians = [0.0f64; 2];
    for (i, (name, bk)) in backends().into_iter().enumerate() {
        let mut out = vec![0.0f32; n * h];
        let label = format!("rms_norm[{n}x{h}] {name}");
        let r = bench(&label, warmup, iters, || {
            bk.rms_norm_into(&x, &gamma, n, h, RMS_EPS, &mut out);
            black_box(out.first().copied());
        });
        medians[i] = r.median_us;
        outs.push(out);
    }
    assert_close("rms_norm", &outs[0], &outs[1]);
    rows.push(Row {
        op: "rms_norm_into",
        shape: format!("{n}x{h}"),
        ref_median_us: medians[0],
        simd_median_us: medians[1],
    });
}

/// One-step GQA decode attention over a dense KV cache at context `ctx`
/// (batch 8, 4 heads over 1 KV head, head_dim 32 — the decode shape the
/// synthetic cluster runs, scaled up to a realistic head width).
fn bench_attn_decode(rows: &mut Vec<Row>, ctx: usize, warmup: usize, iters: usize) {
    const B: usize = 8;
    const HEADS: usize = 4;
    const KV: usize = 1;
    const D: usize = 32;
    let s_max = ctx + 1;
    let mut rng = Pcg::seeded(0xA77 + ctx as u64);
    let q = rand_vec(&mut rng, B * HEADS * D);
    let k_new = rand_vec(&mut rng, B * KV * D);
    let v_new = rand_vec(&mut rng, B * KV * D);
    let k_cache = rand_vec(&mut rng, B * s_max * KV * D);
    let v_cache = rand_vec(&mut rng, B * s_max * KV * D);
    let pos = vec![ctx as i32; B];
    let src = kern::DenseKv { k: &k_cache, v: &v_cache, s: s_max, kv: KV, d: D };
    let mut outs: Vec<Vec<f32>> = Vec::new();
    let mut medians = [0.0f64; 2];
    for (i, (name, bk)) in backends().into_iter().enumerate() {
        let mut scores = vec![0.0f32; s_max];
        let mut attn = vec![0.0f32; B * HEADS * D];
        let label = format!("attn_decode[b{B} ctx{ctx}] {name}");
        let r = bench(&label, warmup, iters, || {
            attn.iter_mut().for_each(|v| *v = 0.0);
            bk.attn_decode_into(
                &q, &k_new, &v_new, &pos, &src, B, HEADS, KV, D, s_max, &mut scores, &mut attn,
            );
            black_box(attn.first().copied());
        });
        medians[i] = r.median_us;
        outs.push(attn);
    }
    assert_close("attn_decode", &outs[0], &outs[1]);
    rows.push(Row {
        op: "attn_decode_into",
        shape: format!("b{B} ctx{ctx}"),
        ref_median_us: medians[0],
        simd_median_us: medians[1],
    });
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (warmup, iters) = if smoke { (3, 20) } else { (10, 200) };
    println!("== kernel backend sweep (smoke={smoke}) ==");

    let mut rows: Vec<Row> = Vec::new();
    let matmul_shapes: &[(usize, usize, usize)] = if smoke {
        &[(8, 128, 128), (8, 128, 512)]
    } else {
        &[(8, 128, 128), (8, 128, 512), (64, 128, 128), (128, 256, 256)]
    };
    for &(n, k, m) in matmul_shapes {
        bench_matmul(&mut rows, n, k, m, warmup, iters);
    }
    let rms_shapes: &[(usize, usize)] = if smoke { &[(8, 128)] } else { &[(8, 128), (64, 256)] };
    for &(n, h) in rms_shapes {
        bench_rms_norm(&mut rows, n, h, warmup, iters);
    }
    let ctxs: &[usize] = if smoke { &[128] } else { &[128, 512, 2048] };
    for &ctx in ctxs {
        bench_attn_decode(&mut rows, ctx, warmup, iters);
    }

    for r in &rows {
        println!(
            "{:<18} {:<12} reference {:>9.2} us | simd {:>9.2} us | speedup {:.2}x",
            r.op,
            r.shape,
            r.ref_median_us,
            r.simd_median_us,
            r.speedup()
        );
    }
    write_report(&rows, smoke);
    println!("== done ==");
}

fn write_report(rows: &[Row], smoke: bool) {
    let entries = rows.iter().map(|r| {
        obj(vec![
            ("op", s(r.op)),
            ("shape", s(&r.shape)),
            ("reference_median_us", num(r.ref_median_us)),
            ("simd_median_us", num(r.simd_median_us)),
            ("speedup_simd", num(r.speedup())),
        ])
    });
    let j = obj(vec![
        (
            "bench",
            s("kernel backends: reference (cache-blocked f32) vs simd (AVX2 / 8-lane \
               scalar fallback) on matmul, rms_norm, decode attention"),
        ),
        ("command", s("cargo bench --bench kernels")),
        ("smoke", s(if smoke { "true" } else { "false" })),
        (
            "acceptance",
            obj(vec![
                (
                    "agreement",
                    s("every timed pair is cross-checked: |ref - simd| <= 1e-4 * (1 + max|.|)"),
                ),
                (
                    "determinism",
                    s("each backend is bitwise run-to-run (pinned lane order; see \
                       runtime/kern/simd.rs)"),
                ),
                ("speedup_simd_target", s(">= 1.0x on AVX2 hosts for matmul-bound shapes")),
            ]),
        ),
        ("results", arr(entries)),
    ]);
    let path = "BENCH_kernels.json";
    match std::fs::write(path, j.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
