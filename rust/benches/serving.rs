//! End-to-end serving benchmarks — one per paper table/figure family:
//! steady-state decode throughput (Fig. 11), artifact execution costs
//! (Table 1 inputs / Fig. 13b), and checkpoint-path overhead (§7.4).
//! Custom harness (criterion is unavailable offline).
//!
//! Run: cargo bench --offline --bench serving

use std::sync::Arc;
use std::time::Duration;

use tarragon::config::Config;
use tarragon::coordinator::cluster::{Cluster, LaunchOptions};
use tarragon::modelcfg::{weights::Weights, Manifest};
use tarragon::runtime::{ArgValue, Device, DeviceRole};
use tarragon::tensor::Tensor;
use tarragon::testing::bench::{bench, once};
use tarragon::workload::Request;

fn main() {
    let dir = Manifest::default_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("artifacts not built — run `make artifacts` first");
        return;
    };
    let manifest = Arc::new(manifest);
    let weights = Weights::load(&manifest).expect("weights");
    let m = manifest.model.clone();

    println!("== artifact execution (Table 1 inputs / Fig. 13b) ==");
    let device = Device::spawn(
        "bench",
        manifest.clone(),
        weights.clone(),
        DeviceRole::Monolithic.plan(&manifest),
        Duration::ZERO,
    )
    .expect("device");

    let b = *manifest.buckets.decode_b.last().unwrap();
    let s = m.max_seq;
    let kv_shape = vec![b, s, m.kv_heads, m.head_dim];
    let kc = Tensor::zeros(kv_shape.clone());
    let vc = Tensor::zeros(kv_shape);
    bench(&format!("attn_decode_b{b} (S={s})"), 5, 100, || {
        let mut args = vec![
            ArgValue::f32(Tensor::zeros(vec![b, m.hidden])),
            ArgValue::f32(kc.clone()),
            ArgValue::f32(vc.clone()),
            ArgValue::i32(vec![64; b]),
        ];
        for wname in ["wq", "wk", "wv", "wo", "ln1", "ln2"] {
            args.push(ArgValue::weight(format!("layer0.{wname}")));
        }
        device.execute(&format!("attn_decode_b{b}"), args).unwrap();
    });

    let t = *manifest.buckets.prefill_t.last().unwrap();
    bench(&format!("attn_prefill_t{t}"), 3, 50, || {
        let mut args = vec![ArgValue::f32(Tensor::zeros(vec![t, m.hidden]))];
        for wname in ["wq", "wk", "wv", "wo", "ln1", "ln2"] {
            args.push(ArgValue::weight(format!("layer0.{wname}")));
        }
        device.execute(&format!("attn_prefill_t{t}"), args).unwrap();
    });

    for &eb in &[1usize, 16, 256] {
        bench(&format!("expert_b{eb} (SwiGLU Pallas kernel)"), 5, 100, || {
            device
                .execute(
                    &format!("expert_b{eb}"),
                    vec![
                        ArgValue::f32(Tensor::zeros(vec![eb, m.hidden])),
                        ArgValue::weight("layer0.expert0.w1"),
                        ArgValue::weight("layer0.expert0.w3"),
                        ArgValue::weight("layer0.expert0.w2"),
                    ],
                )
                .unwrap();
        });
    }
    device.shutdown();

    println!("\n== end-to-end cluster (Fig. 11-style throughput) ==");
    let schedule: Vec<Request> = (0..6u64)
        .map(|i| Request {
            id: i,
            arrival_s: 0.05 * i as f64,
            prompt: vec![1 + i as u32; 8],
            max_new_tokens: 48,
        })
        .collect();
    let mut cfg = Config::default();
    cfg.cluster.num_aws = 2;
    cfg.cluster.num_ews = 2;
    cfg.transport.worker_extra_init = Duration::from_millis(10);

    once("cluster bring-up (2 AW + 2 EW, T_w)", || {
        let c = Cluster::launch(
            cfg.clone(),
            manifest.clone(),
            weights.clone(),
            vec![],
            LaunchOptions::default(),
        );
        c.finish(1.0);
    });

    let c = Cluster::launch(cfg, manifest, weights, schedule, LaunchOptions::default());
    let t0 = std::time::Instant::now();
    assert!(c.wait_done(Duration::from_secs(300)));
    let wall = t0.elapsed();
    let report = c.finish(1.0);
    println!(
        "decode throughput: {:.0} tok/s ({} tokens in {:.2}s, TBT median {:.2} ms)",
        report.analysis.total_tokens as f64 / wall.as_secs_f64(),
        report.analysis.total_tokens,
        wall.as_secs_f64(),
        report.analysis.tbt().median_ms,
    );
}
