//! End-to-end serving benchmarks: artifact execution costs (Table 1
//! inputs / Fig. 13b) when real artifacts are present, plus the overload
//! load-sweep harness (DESIGN.md §9) — throughput, p50/p99 TTFT and TBT,
//! and preemption rate vs. offered load — which runs the full cluster on
//! the synthetic model under a deterministic virtual clock, so it needs
//! no artifacts and costs seconds of wall time. Results are written to
//! `BENCH_serving.json`.
//!
//! Run: cargo bench --offline --bench serving
//! CI smoke: cargo bench --offline --bench serving -- --smoke

use std::sync::Arc;
use std::time::Duration;

use tarragon::config::Config;
use tarragon::metrics::hist::LogHistogram;
use tarragon::modelcfg::{weights::Weights, Manifest};
use tarragon::runtime::{ArgValue, Device, DeviceRole};
use tarragon::tensor::Tensor;
use tarragon::testing::bench::bench;
use tarragon::testing::scenario::Scenario;
use tarragon::testing::synthetic;
use tarragon::util::json::{arr, num, obj, s, Json};
use tarragon::workload;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");

    if let Ok(manifest) = Manifest::load(&Manifest::default_dir()) {
        artifact_benches(Arc::new(manifest));
    } else {
        println!("artifacts not built — skipping artifact benches (the load sweep below uses the in-repo synthetic model)");
    }

    load_sweep(smoke);
    shared_prefix_sweep(smoke);
}

/// Artifact-level microbenches (only with Python-built artifacts).
fn artifact_benches(manifest: Arc<Manifest>) {
    let weights = Weights::load(&manifest).expect("weights");
    let m = manifest.model.clone();

    println!("== artifact execution (Table 1 inputs / Fig. 13b) ==");
    let device = Device::spawn(
        "bench",
        manifest.clone(),
        weights.clone(),
        DeviceRole::Monolithic.plan(&manifest),
        Duration::ZERO,
    )
    .expect("device");

    let b = *manifest.buckets.decode_b.last().unwrap();
    let seq = m.max_seq;
    let kv_shape = vec![b, seq, m.kv_heads, m.head_dim];
    let kc = Tensor::zeros(kv_shape.clone());
    let vc = Tensor::zeros(kv_shape);
    bench(&format!("attn_decode_b{b} (S={seq})"), 5, 100, || {
        let mut args = vec![
            ArgValue::f32(Tensor::zeros(vec![b, m.hidden])),
            ArgValue::f32(kc.clone()),
            ArgValue::f32(vc.clone()),
            ArgValue::i32(vec![64; b]),
        ];
        for wname in ["wq", "wk", "wv", "wo", "ln1", "ln2"] {
            args.push(ArgValue::weight(format!("layer0.{wname}")));
        }
        device.execute(&format!("attn_decode_b{b}"), args).unwrap();
    });

    let t = *manifest.buckets.prefill_t.last().unwrap();
    bench(&format!("attn_prefill_t{t}"), 3, 50, || {
        let mut args = vec![ArgValue::f32(Tensor::zeros(vec![t, m.hidden]))];
        for wname in ["wq", "wk", "wv", "wo", "ln1", "ln2"] {
            args.push(ArgValue::weight(format!("layer0.{wname}")));
        }
        device.execute(&format!("attn_prefill_t{t}"), args).unwrap();
    });

    for &eb in &[1usize, 16, 256] {
        bench(&format!("expert_b{eb} (SwiGLU Pallas kernel)"), 5, 100, || {
            device
                .execute(
                    &format!("expert_b{eb}"),
                    vec![
                        ArgValue::f32(Tensor::zeros(vec![eb, m.hidden])),
                        ArgValue::weight("layer0.expert0.w1"),
                        ArgValue::weight("layer0.expert0.w3"),
                        ArgValue::weight("layer0.expert0.w2"),
                    ],
                )
                .unwrap();
        });
    }
    device.shutdown();
}

struct SweepPoint {
    offered_rps: f64,
    completed: bool,
    finished: usize,
    throughput_tps: f64,
    ttft_p50_ms: f64,
    ttft_p99_ms: f64,
    tbt_p50_ms: f64,
    tbt_p99_ms: f64,
    preemptions: u64,
    preemption_rate: f64,
    wall_ms: f64,
}

/// Offered-load sweep on the synthetic model under a virtual clock: the
/// per-AW KV budget (8 pages) is undersized on purpose, so high offered
/// loads force queueing + checkpoint-backed preemption — the bench
/// records how latency and preemption rate degrade, with zero drops.
fn load_sweep(smoke: bool) {
    const N_REQS: usize = 16;
    const N_REQS_SMOKE: usize = 8;
    const BASE_GAP_MS: u64 = 20;
    const BUDGET_PAGES: usize = 8;

    println!("\n== overload load sweep (virtual clock, synthetic model) ==");
    let (manifest, weights, _) = synthetic::ensure();
    let mults: &[f64] = if smoke { &[1.0, 4.0] } else { &[0.5, 1.0, 2.0, 4.0] };
    let n = if smoke { N_REQS_SMOKE } else { N_REQS };

    let mut points = Vec::new();
    for &mult in mults {
        let gap = Duration::from_micros((BASE_GAP_MS as f64 * 1000.0 / mult) as u64);
        let mut cfg = Config::small_test();
        cfg.transport.latency = Duration::from_millis(1);
        cfg.transport.worker_extra_init = Duration::from_millis(50);
        cfg.sched.kv_budget_pages = BUDGET_PAGES;
        let mut scen = Scenario::new(format!("sweep-x{mult}"), cfg);
        for i in 0..n as u64 {
            scen = scen.request(i, gap * i as u32, vec![(1 + i % 100) as u32, 2, 3, 4, 5, 6, 7, 8], 24);
        }
        scen.drain_timeout = Duration::from_secs(300);

        let t0 = std::time::Instant::now();
        let out = scen.run(manifest.clone(), weights.clone());
        let wall = t0.elapsed();
        out.assert_kv_budget_held();
        assert_eq!(out.report.finished, n, "load sweep dropped requests at x{mult}");

        let a = &out.report.analysis;
        // Log-bucketed tails: O(buckets) memory however long the sweep
        // runs, with <= 5% relative quantile error (metrics::hist).
        let ttft = LogHistogram::of(&a.ttft_ms);
        let tbt = LogHistogram::of(&a.tbt_ms);
        let p = SweepPoint {
            offered_rps: 1000.0 / (gap.as_secs_f64() * 1000.0),
            completed: out.completed,
            finished: out.report.finished,
            throughput_tps: a.throughput_tps,
            ttft_p50_ms: ttft.percentile(50.0),
            ttft_p99_ms: ttft.percentile(99.0),
            tbt_p50_ms: tbt.percentile(50.0),
            tbt_p99_ms: tbt.percentile(99.0),
            preemptions: out.report.preemptions,
            preemption_rate: out.report.preemptions as f64 / out.report.finished.max(1) as f64,
            wall_ms: wall.as_secs_f64() * 1e3,
        };
        println!(
            "x{mult:<4} offered {:>7.1} rps | {:>8.1} tok/s | TTFT p50 {:>8.2} p99 {:>8.2} ms | TBT p50 {:>7.2} p99 {:>7.2} ms | preempt {:>3} ({:.2}/req) | wall {:>7.1} ms",
            p.offered_rps,
            p.throughput_tps,
            p.ttft_p50_ms,
            p.ttft_p99_ms,
            p.tbt_p50_ms,
            p.tbt_p99_ms,
            p.preemptions,
            p.preemption_rate,
            p.wall_ms,
        );
        points.push(p);
    }
    write_report(&points, smoke, n, BUDGET_PAGES);
}

struct SharePoint {
    ratio: f64,
    peak_pages: usize,
    prefix_hits: u64,
    cow_breaks: u64,
    pages_shared: u64,
}

/// Shared-prefix sweep (DESIGN.md §13): a fraction of requests carries
/// one identical 16-token prompt — exactly one sealed KV page per layer
/// on the synthetic model — against all-distinct prompts at the same
/// offered load. Prefix caching must cut the *physical* page peak while
/// the budget holds; the vLLM-family baselines in `src/baselines` share
/// through the same `write_prompt_layer` path, so this is the
/// like-for-like comparison axis (`workload.shared_prefix_ratio`).
fn shared_prefix_sweep(smoke: bool) {
    const PREFIX_TOKENS: usize = 16;
    const MAX_NEW: usize = 8;
    const BUDGET_PAGES: usize = 24; // roomy: compare footprints, not preemption
    let n: usize = if smoke { 8 } else { 16 };

    println!("\n== shared-prefix sweep (identical one-page prompts vs distinct) ==");
    let (manifest, weights, _) = synthetic::ensure();
    let vocab = manifest.model.vocab;
    let shared: Vec<u32> = (0..PREFIX_TOKENS)
        .map(|i| workload::shared_prefix_token(i, vocab))
        .collect();

    let mut points: Vec<SharePoint> = Vec::new();
    for &ratio in &[0.0, 0.8] {
        let mut cfg = Config::small_test();
        cfg.transport.latency = Duration::from_millis(1);
        cfg.transport.worker_extra_init = Duration::from_millis(50);
        cfg.sched.kv_budget_pages = BUDGET_PAGES;
        cfg.workload.shared_prefix_ratio = ratio;
        let n_shared = (ratio * n as f64).round() as u64;
        let mut scen = Scenario::new(format!("share-r{ratio}"), cfg);
        for i in 0..n as u64 {
            let prompt: Vec<u32> = if i < n_shared {
                shared.clone()
            } else {
                // distinct full pages: token walks never coincide within
                // the sweep's request count
                (0..PREFIX_TOKENS)
                    .map(|t| 1 + ((i as usize * PREFIX_TOKENS + t) % (vocab - 1)) as u32)
                    .collect()
            };
            scen = scen.request(i, Duration::from_millis(2) * i as u32, prompt, MAX_NEW);
        }
        scen.drain_timeout = Duration::from_secs(300);

        let out = scen.run(manifest.clone(), weights.clone());
        out.assert_kv_budget_held();
        assert!(out.completed, "shared-prefix sweep did not drain at ratio {ratio}");
        assert_eq!(out.report.finished, n);
        let peak: usize = out.kv_peaks.values().sum();
        let sh = out.report.sharing;
        if ratio > 0.0 {
            assert!(sh.prefix_hits > 0, "identical prompts must hit the prefix index");
        } else {
            assert_eq!(sh.prefix_hits, 0, "distinct prompts must not share");
        }
        println!(
            "ratio {ratio:<4} | physical peak pages {peak:>3} (sum over AWs) | prefix hits {:>3} | cow breaks {:>2} | shared-page peak {:>3}",
            sh.prefix_hits, sh.cow_breaks, sh.pages_shared,
        );
        points.push(SharePoint {
            ratio,
            peak_pages: peak,
            prefix_hits: sh.prefix_hits,
            cow_breaks: sh.cow_breaks,
            pages_shared: sh.pages_shared,
        });
    }
    assert!(
        points[1].peak_pages < points[0].peak_pages,
        "sharing must reduce the physical KV peak at equal load ({} !< {})",
        points[1].peak_pages,
        points[0].peak_pages,
    );
    write_share_report(&points, smoke, n);
}

fn write_share_report(points: &[SharePoint], smoke: bool, n_reqs: usize) {
    let entries = points.iter().map(|p| {
        obj(vec![
            ("shared_prefix_ratio", num(p.ratio)),
            ("physical_peak_pages", num(p.peak_pages as f64)),
            ("prefix_hits", num(p.prefix_hits as f64)),
            ("cow_breaks", num(p.cow_breaks as f64)),
            ("pages_shared_peak", num(p.pages_shared as f64)),
        ])
    });
    let j = obj(vec![
        (
            "bench",
            s("shared-prefix sweep: physical KV peak vs prefix-sharing ratio at equal load"),
        ),
        ("command", s("cargo bench --bench serving")),
        ("smoke", Json::Bool(smoke)),
        (
            "setup",
            obj(vec![
                ("cluster", s("2 AW x 2 EW, virtual clock, synthetic model")),
                ("requests", num(n_reqs as f64)),
                ("prompt_tokens", num(16.0)),
                ("max_new_tokens", num(8.0)),
                ("kv_budget_pages_per_aw", num(24.0)),
            ]),
        ),
        ("results", arr(entries)),
    ]);
    let path = "BENCH_serving_prefix.json";
    match std::fs::write(path, j.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn write_report(points: &[SweepPoint], smoke: bool, n_reqs: usize, budget: usize) {
    let entries = points.iter().map(|p| {
        obj(vec![
            ("offered_rps", num(p.offered_rps)),
            ("completed", Json::Bool(p.completed)),
            ("finished", num(p.finished as f64)),
            ("throughput_tps", num(p.throughput_tps)),
            ("ttft_p50_ms", num(p.ttft_p50_ms)),
            ("ttft_p99_ms", num(p.ttft_p99_ms)),
            ("tbt_p50_ms", num(p.tbt_p50_ms)),
            ("tbt_p99_ms", num(p.tbt_p99_ms)),
            ("preemptions", num(p.preemptions as f64)),
            ("preemption_rate", num(p.preemption_rate)),
            ("wall_ms", num(p.wall_ms)),
        ])
    });
    let j = obj(vec![
        (
            "bench",
            s("overload load sweep: throughput, TTFT/TBT tails, preemption rate vs offered load"),
        ),
        ("command", s("cargo bench --bench serving")),
        ("smoke", Json::Bool(smoke)),
        (
            "setup",
            obj(vec![
                ("cluster", s("2 AW x 2 EW, virtual clock, synthetic model")),
                ("requests", num(n_reqs as f64)),
                ("prompt_tokens", num(8.0)),
                ("max_new_tokens", num(24.0)),
                ("kv_budget_pages_per_aw", num(budget as f64)),
            ]),
        ),
        ("results", arr(entries)),
    ]);
    let path = "BENCH_serving.json";
    match std::fs::write(path, j.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
