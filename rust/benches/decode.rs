//! Decode hot-path benchmark: tokens/sec and allocations/token for the
//! zero-copy decode path (blocked transposed-weight matmuls + in-place
//! paged attention + view dispatch + scratch arena) versus the seed
//! path (naive triple-loop matmuls + dense `[B, S, kv, d]` KV gather +
//! copy-per-row dispatch).
//!
//! Both paths run the same single-thread per-step arithmetic the AW/EW
//! cluster performs — embed → per layer (attention, router, top-2,
//! dispatch, expert FFN, slot-ordered accumulation) → LM head — and
//! produce bitwise-identical tokens (the kernels preserve f32
//! accumulation order; see `runtime::xla::kern`).
//!
//! Run:   cargo bench --bench decode            (full sweep, emits
//!        BENCH_decode.json in the working directory)
//!        cargo bench --bench decode -- --smoke (CI: tiny sweep)
//!
//! The acceptance bar for the zero-copy rewrite is >= 2x single-thread
//! decode throughput on the synthetic model shape and ~zero
//! allocations/token in steady state (`speedup` / `allocs_per_token`
//! fields below; the hard zero-alloc guarantee is pinned by
//! rust/tests/alloc.rs).

use std::sync::Arc;
use std::time::Instant;

use tarragon::kvcache::{BatchAssembler, KvPool, PoolConfig, RequestKv};
use tarragon::modelcfg::ModelSpec;
use tarragon::runtime::xla::kern;
use tarragon::tensor::{ops, Tensor};
use tarragon::testing::alloccount::{allocation_count, CountingAlloc};
use tarragon::util::json::{arr, num, obj, s};
use tarragon::util::rng::Pcg;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

const RMS_EPS: f32 = 1e-5;
const ROPE_THETA: f32 = 10000.0;

const LAYERS: usize = 4;
const H: usize = 128;
const HEADS: usize = 4;
const KV: usize = 1;
const D: usize = 32;
const KVD: usize = KV * D;
const F: usize = 256;
const E: usize = 8;
const TOP_K: usize = 2;
const VOCAB: usize = 512;

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    /// Seed kernels: naive matmul, dense KV gather, row copies.
    Naive,
    /// Zero-copy path: blocked W^T matmul, paged attention, row views.
    ZeroCopy,
}

struct Weights {
    embed: Vec<f32>,
    // per layer
    wq: Vec<Vec<f32>>,
    wk: Vec<Vec<f32>>,
    wv: Vec<Vec<f32>>,
    wo: Vec<Vec<f32>>,
    wg: Vec<Vec<f32>>,
    // per layer per expert
    w1: Vec<Vec<Vec<f32>>>,
    w3: Vec<Vec<Vec<f32>>>,
    w2: Vec<Vec<Vec<f32>>>,
    ln: Vec<f32>,
    lm: Vec<f32>,
    // transposed copies (computed once, like the weight-upload prewarm)
    wq_t: Vec<Vec<f32>>,
    wk_t: Vec<Vec<f32>>,
    wv_t: Vec<Vec<f32>>,
    wo_t: Vec<Vec<f32>>,
    wg_t: Vec<Vec<f32>>,
    w1_t: Vec<Vec<Vec<f32>>>,
    w3_t: Vec<Vec<Vec<f32>>>,
    w2_t: Vec<Vec<Vec<f32>>>,
    lm_t: Vec<f32>,
}

fn rand_vec(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.f32() - 0.5) * 0.2).collect()
}

impl Weights {
    fn new(rng: &mut Pcg) -> Weights {
        let per_layer = |rng: &mut Pcg, k: usize, m: usize| -> Vec<Vec<f32>> {
            (0..LAYERS).map(|_| rand_vec(rng, k * m)).collect()
        };
        let per_expert = |rng: &mut Pcg, k: usize, m: usize| -> Vec<Vec<Vec<f32>>> {
            (0..LAYERS).map(|_| (0..E).map(|_| rand_vec(rng, k * m)).collect()).collect()
        };
        let t_layer = |w: &[Vec<f32>], k: usize, m: usize| -> Vec<Vec<f32>> {
            w.iter().map(|w| kern::transpose(w, k, m)).collect()
        };
        let t_expert = |w: &[Vec<Vec<f32>>], k: usize, m: usize| -> Vec<Vec<Vec<f32>>> {
            w.iter().map(|l| l.iter().map(|w| kern::transpose(w, k, m)).collect()).collect()
        };
        let wq = per_layer(rng, H, H);
        let wk = per_layer(rng, H, KVD);
        let wv = per_layer(rng, H, KVD);
        let wo = per_layer(rng, H, H);
        let wg = per_layer(rng, H, E);
        let w1 = per_expert(rng, H, F);
        let w3 = per_expert(rng, H, F);
        let w2 = per_expert(rng, F, H);
        let lm = rand_vec(rng, H * VOCAB);
        Weights {
            embed: rand_vec(rng, VOCAB * H),
            wq_t: t_layer(&wq, H, H),
            wk_t: t_layer(&wk, H, KVD),
            wv_t: t_layer(&wv, H, KVD),
            wo_t: t_layer(&wo, H, H),
            wg_t: t_layer(&wg, H, E),
            w1_t: t_expert(&w1, H, F),
            w3_t: t_expert(&w3, H, F),
            w2_t: t_expert(&w2, F, H),
            lm_t: kern::transpose(&lm, H, VOCAB),
            wq,
            wk,
            wv,
            wo,
            wg,
            w1,
            w3,
            w2,
            ln: vec![1.0; H],
            lm,
        }
    }
}

/// One decode workload at (batch, context): steady-state steps over a
/// fixed-length context (KV append overwrites the same next position, so
/// the measured cost profile does not drift across iterations).
struct Sim {
    b: usize,
    ctx: usize,
    s_max: usize,
    mode: Mode,
    w: Arc<Weights>,
    kvs: Vec<RequestKv>,
    asm: BatchAssembler,
    pos: Vec<i32>,
    next_tok: Vec<u32>,
    freqs: Vec<f32>,
}

impl Sim {
    fn new(b: usize, ctx: usize, s_max: usize, mode: Mode, w: Arc<Weights>) -> Sim {
        let m = ModelSpec {
            layers: LAYERS,
            hidden: H,
            heads: HEADS,
            kv_heads: KV,
            head_dim: D,
            ffn: F,
            experts: E,
            top_k: TOP_K,
            vocab: VOCAB,
            max_seq: s_max,
        };
        let mut rng = Pcg::seeded(7 + b as u64 * 1000 + ctx as u64);
        let pool = KvPool::new(PoolConfig { page_tokens: 16, seg: KVD });
        let mut kvs: Vec<RequestKv> = (0..b).map(|_| RequestKv::new(&m, &pool)).collect();
        for r in kvs.iter_mut() {
            r.reserve(ctx + 1);
            for layer in 0..LAYERS {
                for t in 0..ctx {
                    let k = rand_vec(&mut rng, KVD);
                    let v = rand_vec(&mut rng, KVD);
                    r.write(layer, t, &k, &v);
                }
            }
            r.set_len(ctx);
        }
        drop(pool); // kept alive by the request KVs' Arcs
        Sim {
            b,
            ctx,
            s_max,
            mode,
            w,
            kvs,
            asm: BatchAssembler::new(&m),
            pos: vec![ctx as i32; b],
            next_tok: (0..b as u32).map(|i| (i * 13 + 5) % VOCAB as u32).collect(),
            freqs: kern::rope_freqs(D, ROPE_THETA),
        }
    }

    fn matmul(&self, x: &[f32], w: &[f32], wt: &[f32], n: usize, k: usize, m: usize) -> Tensor {
        match self.mode {
            Mode::Naive => Tensor::new(vec![n, m], kern::matmul_naive(x, w, n, k, m)),
            Mode::ZeroCopy => {
                let mut out = Tensor::uninit([n, m]);
                kern::matmul_wt_into(x, wt, n, k, m, out.data_mut());
                out
            }
        }
    }

    /// One decode step; returns the per-request tokens.
    fn step(&mut self) {
        let (b, w) = (self.b, self.w.clone());
        let mut x = Tensor::uninit([b, H]);
        {
            let xd = x.data_mut();
            for i in 0..b {
                let tok = self.next_tok[i] as usize;
                xd[i * H..(i + 1) * H].copy_from_slice(&w.embed[tok * H..(tok + 1) * H]);
            }
        }
        for layer in 0..LAYERS {
            let mut n_t = Tensor::uninit([b, H]);
            kern::rms_norm_into(x.data(), &w.ln, b, H, RMS_EPS, n_t.data_mut());
            let mut q = self.matmul(n_t.data(), &w.wq[layer], &w.wq_t[layer], b, H, H);
            let mut k_new = self.matmul(n_t.data(), &w.wk[layer], &w.wk_t[layer], b, H, KVD);
            let v_new = self.matmul(n_t.data(), &w.wv[layer], &w.wv_t[layer], b, H, KVD);
            let pos = &self.pos;
            kern::rope_with_freqs(q.data_mut(), b, HEADS, D, &self.freqs, |i| pos[i] as f32);
            kern::rope_with_freqs(k_new.data_mut(), b, KV, D, &self.freqs, |i| pos[i] as f32);
            let mut attn = Tensor::zeros([b, H]);
            let mut scores = Tensor::uninit([self.s_max]);
            match self.mode {
                Mode::Naive => {
                    // Seed behavior: materialize a contiguous [B, S, kv, d]
                    // copy of the paged KV, then run dense attention.
                    let refs: Vec<&RequestKv> = self.kvs.iter().collect();
                    let (kc, vc, _pos) =
                        self.asm.gather(&refs, layer, b, KV, D);
                    let src = kern::DenseKv {
                        k: kc.data(),
                        v: vc.data(),
                        s: self.s_max,
                        kv: KV,
                        d: D,
                    };
                    kern::attn_decode_into(
                        q.data(),
                        k_new.data(),
                        v_new.data(),
                        &self.pos,
                        &src,
                        b,
                        HEADS,
                        KV,
                        D,
                        self.s_max,
                        scores.data_mut(),
                        attn.data_mut(),
                    );
                }
                Mode::ZeroCopy => {
                    // Paged reads in place — the gather refills recycled
                    // page-table rows, so steady state allocates nothing.
                    let refs: Vec<&RequestKv> = self.kvs.iter().collect();
                    let view =
                        self.asm.gather_paged(self.kvs[0].pool(), &refs, layer, b, &mut self.pos);
                    let read = view.pool.read();
                    let src = kern::PagedKv { read: &read, tables: &view.tables, d: D };
                    kern::attn_decode_into(
                        q.data(),
                        k_new.data(),
                        v_new.data(),
                        &self.pos,
                        &src,
                        b,
                        HEADS,
                        KV,
                        D,
                        self.s_max,
                        scores.data_mut(),
                        attn.data_mut(),
                    );
                }
            }
            // Steady-state append (same position each iteration: the
            // context length stays fixed across measured steps).
            for i in 0..b {
                self.kvs[i].write(layer, self.ctx, k_new.row(i), v_new.row(i));
            }
            let proj = self.matmul(attn.data(), &w.wo[layer], &w.wo_t[layer], b, H, H);
            let mut h_out = Tensor::uninit([b, H]);
            for ((o, a), p) in h_out.data_mut().iter_mut().zip(x.data()).zip(proj.data()) {
                *o = a + p;
            }
            let mut g = Tensor::uninit([b, H]);
            kern::rms_norm_into(h_out.data(), &w.ln, b, H, RMS_EPS, g.data_mut());
            // Router + top-2 + expert mix, expert-ascending.
            let mut logits = self.matmul(g.data(), &w.wg[layer], &w.wg_t[layer], b, H, E);
            kern::softmax_rows(logits.data_mut(), b, E);
            let mut groups: Vec<Vec<(usize, f32)>> = vec![Vec::new(); E];
            for i in 0..b {
                let row = logits.row(i);
                let mut top = ops::top_k(row, TOP_K);
                ops::renormalize(&mut top);
                for (e, wgt) in top {
                    groups[e].push((i, wgt));
                }
            }
            for (e, rows) in groups.iter().enumerate() {
                if rows.is_empty() {
                    continue;
                }
                let n = rows.len();
                // EW staging (both modes pad to the row count here).
                let mut xe = Tensor::zeros([n, H]);
                {
                    let xd = xe.data_mut();
                    for (j, &(row, _)) in rows.iter().enumerate() {
                        match self.mode {
                            // Seed path: dispatch copied row-by-row.
                            Mode::Naive => {
                                let copy = g.row(row).to_vec();
                                xd[j * H..(j + 1) * H].copy_from_slice(&copy);
                            }
                            // Zero-copy path: stage straight from a view.
                            Mode::ZeroCopy => {
                                let view = g.row_tensor(row);
                                xd[j * H..(j + 1) * H].copy_from_slice(view.data());
                            }
                        }
                    }
                }
                let mut a = self.matmul(xe.data(), &w.w1[layer][e], &w.w1_t[layer][e], n, H, F);
                let gate = self.matmul(xe.data(), &w.w3[layer][e], &w.w3_t[layer][e], n, H, F);
                for (av, gv) in a.data_mut().iter_mut().zip(gate.data()) {
                    *av = kern::silu(*av) * gv;
                }
                let y = self.matmul(a.data(), &w.w2[layer][e], &w.w2_t[layer][e], n, F, H);
                for (j, &(row, wgt)) in rows.iter().enumerate() {
                    match self.mode {
                        Mode::Naive => {
                            // Seed path: returned rows copied out.
                            let out = y.row(j).to_vec();
                            ops::axpy_row(h_out.row_mut(row), wgt, &out);
                        }
                        Mode::ZeroCopy => {
                            let view = y.row_tensor(j);
                            ops::axpy_row(h_out.row_mut(row), wgt, view.data());
                        }
                    }
                }
            }
            x = h_out;
        }
        let mut normed = Tensor::uninit([b, H]);
        kern::rms_norm_into(x.data(), &w.ln, b, H, RMS_EPS, normed.data_mut());
        let logits = self.matmul(normed.data(), &w.lm, &w.lm_t, b, H, VOCAB);
        for i in 0..b {
            self.next_tok[i] = ops::argmax(logits.row(i)) as u32;
        }
    }
}

struct Row {
    phase: &'static str,
    mode: &'static str,
    batch: usize,
    ctx: usize,
    tokens_per_sec: f64,
    us_per_token: f64,
    allocs_per_token: f64,
}

fn measure(sim: &mut Sim, warmup: usize, iters: usize) -> (f64, f64, f64) {
    for _ in 0..warmup {
        sim.step();
    }
    let a0 = allocation_count();
    let t0 = Instant::now();
    for _ in 0..iters {
        sim.step();
    }
    let dt = t0.elapsed().as_secs_f64();
    let allocs = (allocation_count() - a0) as f64;
    let tokens = (iters * sim.b) as f64;
    (tokens / dt, dt * 1e6 / tokens, allocs / tokens)
}

/// Prefill comparison: the matmul-bound path (QKV + output projections +
/// causal attention) at window `t`, naive vs blocked kernels.
fn prefill_once(w: &Weights, t: usize, blocked: bool) -> f64 {
    let mut rng = Pcg::seeded(0xBEEF + t as u64);
    let x = rand_vec(&mut rng, t * H);
    let t0 = Instant::now();
    let mut n_t = vec![0.0f32; t * H];
    kern::rms_norm_into(&x, &w.ln, t, H, RMS_EPS, &mut n_t);
    let mm = |xs: &[f32], wd: &[f32], wt: &[f32], n: usize, k: usize, m: usize| -> Vec<f32> {
        if blocked {
            let mut out = vec![0.0f32; n * m];
            kern::matmul_wt_into(xs, wt, n, k, m, &mut out);
            out
        } else {
            kern::matmul_naive(xs, wd, n, k, m)
        }
    };
    let mut q = mm(&n_t, &w.wq[0], &w.wq_t[0], t, H, H);
    let mut k = mm(&n_t, &w.wk[0], &w.wk_t[0], t, H, KVD);
    let v = mm(&n_t, &w.wv[0], &w.wv_t[0], t, H, KVD);
    kern::rope(&mut q, t, HEADS, D, ROPE_THETA, |i| i as f32);
    kern::rope(&mut k, t, KV, D, ROPE_THETA, |i| i as f32);
    let mut attn = vec![0.0f32; t * H];
    let mut scores = vec![0.0f32; t];
    kern::attn_prefill_into(&q, &k, &v, t, HEADS, KV, D, &mut scores, &mut attn);
    let proj = mm(&attn, &w.wo[0], &w.wo_t[0], t, H, H);
    std::hint::black_box(&proj);
    t0.elapsed().as_secs_f64() * 1e6 / t as f64 // us per token
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (batches, ctxs, iters): (&[usize], &[usize], usize) = if smoke {
        (&[1, 8], &[128, 512], 4)
    } else {
        (&[1, 8, 32], &[128, 512, 2048], 12)
    };
    let s_max = *ctxs.last().unwrap() + 16;
    let mut rng = Pcg::seeded(0xDEC0DE);
    let w = Arc::new(Weights::new(&mut rng));
    println!("== decode hot-path sweep (smoke={smoke}) ==");

    let mut rows: Vec<Row> = Vec::new();
    for &b in batches {
        for &ctx in ctxs {
            let mut naive = Sim::new(b, ctx, s_max, Mode::Naive, w.clone());
            let (tps_n, uspt_n, apt_n) = measure(&mut naive, 1, iters.max(2));
            drop(naive);
            let mut fast = Sim::new(b, ctx, s_max, Mode::ZeroCopy, w.clone());
            let (tps_f, uspt_f, apt_f) = measure(&mut fast, 2, iters.max(2) * 2);
            println!(
                "decode B={b:<3} ctx={ctx:<5} naive {tps_n:>9.1} tok/s ({apt_n:>7.1} allocs/tok) | zero-copy {tps_f:>9.1} tok/s ({apt_f:>7.1} allocs/tok) | speedup {:.2}x",
                tps_f / tps_n
            );
            rows.push(Row {
                phase: "decode",
                mode: "naive",
                batch: b,
                ctx,
                tokens_per_sec: tps_n,
                us_per_token: uspt_n,
                allocs_per_token: apt_n,
            });
            rows.push(Row {
                phase: "decode",
                mode: "zero_copy",
                batch: b,
                ctx,
                tokens_per_sec: tps_f,
                us_per_token: uspt_f,
                allocs_per_token: apt_f,
            });
        }
    }

    // Prefill (matmul-bound) windows.
    let prefill_ts: &[usize] = if smoke { &[128] } else { &[128, 512] };
    for &t in prefill_ts {
        let naive_us = prefill_once(&w, t, false);
        let blocked_us = prefill_once(&w, t, true);
        println!(
            "prefill t={t:<5} naive {naive_us:>8.2} us/tok | blocked {blocked_us:>8.2} us/tok | speedup {:.2}x",
            naive_us / blocked_us
        );
        for (mode, us) in [("naive", naive_us), ("zero_copy", blocked_us)] {
            rows.push(Row {
                phase: "prefill",
                mode,
                batch: 1,
                ctx: t,
                tokens_per_sec: 1e6 / us,
                us_per_token: us,
                allocs_per_token: f64::NAN,
            });
        }
    }

    write_report(&rows, smoke);
    println!("== done ==");
}

fn write_report(rows: &[Row], smoke: bool) {
    let entries = rows.iter().map(|r| {
        obj(vec![
            ("phase", s(r.phase)),
            ("mode", s(r.mode)),
            ("batch", num(r.batch as f64)),
            ("context", num(r.ctx as f64)),
            ("tokens_per_sec", num(r.tokens_per_sec)),
            ("us_per_token", num(r.us_per_token)),
            (
                "allocs_per_token",
                if r.allocs_per_token.is_nan() { s("n/a") } else { num(r.allocs_per_token) },
            ),
        ])
    });
    let j = obj(vec![
        (
            "bench",
            s("decode hot path: zero-copy (blocked W^T matmul + paged attention + view dispatch + scratch arena) vs seed (naive matmul + dense gather + row copies)"),
        ),
        ("command", s("cargo bench --bench decode")),
        ("smoke", s(if smoke { "true" } else { "false" })),
        (
            "acceptance",
            obj(vec![
                ("decode_speedup_target", s(">= 2.0x single-thread tokens/sec, zero-copy vs naive")),
                ("allocs_per_token_target", s("~0 in steady state (hard zero pinned by rust/tests/alloc.rs)")),
            ]),
        ),
        (
            "model",
            obj(vec![
                ("layers", num(LAYERS as f64)),
                ("hidden", num(H as f64)),
                ("heads", num(HEADS as f64)),
                ("kv_heads", num(KV as f64)),
                ("head_dim", num(D as f64)),
                ("ffn", num(F as f64)),
                ("experts", num(E as f64)),
                ("top_k", num(TOP_K as f64)),
                ("vocab", num(VOCAB as f64)),
            ]),
        ),
        ("results", arr(entries)),
    ]);
    let path = "BENCH_decode.json";
    match std::fs::write(path, j.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
