//! Micro-benchmarks of the L3 hot paths (custom harness; criterion is not
//! available offline). One section per paper-relevant cost center:
//!
//! - ERT resolution + top-k gating + dispatch grouping (per-layer routing)
//! - KV batch assembly (the per-layer gather on the decode path)
//! - checkpoint segment read + streamer queueing
//! - JSON/manifest parse (startup path)
//! - transport post/recv round-trip
//!
//! Run: cargo bench --offline  (or: cargo bench --bench hotpath)

use std::sync::Arc;
use std::time::Duration;

use tarragon::config::TransportConfig;
use tarragon::coordinator::ert::Ert;
use tarragon::coordinator::router::{self, ExpertGroups};
use tarragon::kvcache::{BatchAssembler, RequestKv};
use tarragon::modelcfg::ModelSpec;
use tarragon::proto::ClusterMsg;
use tarragon::tensor::Tensor;
use tarragon::testing::bench::{bench, black_box};
use tarragon::transport::{link::TrafficClass, Fabric, NodeId, Plane};
use tarragon::util::rng::Pcg;

fn model() -> ModelSpec {
    ModelSpec {
        layers: 4,
        hidden: 128,
        heads: 4,
        kv_heads: 1,
        head_dim: 32,
        ffn: 256,
        experts: 8,
        top_k: 2,
        vocab: 512,
        max_seq: 160,
    }
}

fn main() {
    println!("== L3 hot-path microbenchmarks ==");
    let m = model();
    let mut rng = Pcg::seeded(42);

    // --- routing: top-k + grouping over a decode batch ------------------
    let b = 8;
    let probs = Tensor::new(
        vec![b, m.experts],
        (0..b * m.experts).map(|_| rng.f32()).collect(),
    );
    bench("router: top-2 select + group (B=8)", 100, 5000, || {
        let routes = router::select_top_k(&probs, b, m.top_k);
        black_box(ExpertGroups::from_routes(&routes));
    });

    // --- ERT resolution --------------------------------------------------
    let mut ert = Ert::initial(m.experts, 4, true);
    bench("ert: resolve 8 experts", 100, 10000, || {
        for e in 0..m.experts {
            black_box(ert.resolve(e));
        }
    });
    ert.mark_dead(1);
    bench("ert: resolve with failover (1 dead)", 100, 10000, || {
        for e in 0..m.experts {
            black_box(ert.resolve(e));
        }
    });

    // --- KV batch assembly (per layer per decode step) -------------------
    let mut kvs: Vec<RequestKv> = (0..b)
        .map(|_| {
            let mut kv = RequestKv::new(&m);
            kv.set_len(96);
            kv
        })
        .collect();
    for kv in kvs.iter_mut() {
        for pos in 0..96 {
            kv.write(0, pos, &vec![1.0; 32], &vec![2.0; 32]);
        }
    }
    let mut asm = BatchAssembler::new(&m);
    bench("kvcache: gather batch B=8 S=160 (one layer)", 20, 2000, || {
        let refs: Vec<&RequestKv> = kvs.iter().collect();
        black_box(asm.gather(&refs, 0, b, m.kv_heads, m.head_dim));
    });

    // --- checkpoint segment path ----------------------------------------
    let kv = &kvs[0];
    bench("kvcache: read one segment", 100, 10000, || {
        black_box(kv.read_segment(0, 40));
    });

    // --- transport round trip ---------------------------------------------
    let fabric: Arc<Fabric<ClusterMsg>> = Fabric::new(TransportConfig {
        latency: Duration::ZERO,
        bandwidth_bps: 1e12,
        worker_extra_init: Duration::ZERO,
    });
    let (inbox, _h) = fabric.register(NodeId::Ew(0));
    let (_i2, _h2) = fabric.register(NodeId::Aw(0));
    let qp = fabric.qp(NodeId::Aw(0), NodeId::Ew(0), Plane::Data).unwrap();
    bench("transport: post + recv (zero-latency link)", 100, 5000, || {
        qp.post(ClusterMsg::ActiveBeacon { active: true }, 48, TrafficClass::Control)
            .unwrap();
        black_box(inbox.recv(Duration::from_millis(10)).unwrap());
    });

    // --- manifest parse (startup) -----------------------------------------
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        bench("json: parse manifest.json", 5, 200, || {
            black_box(tarragon::util::json::Json::parse(&text).unwrap());
        });
    }

    println!("== done ==");
}
