//! Micro-benchmarks of the L3 hot paths (custom harness; criterion is not
//! available offline). One section per paper-relevant cost center:
//!
//! - ERT resolution + top-k gating + dispatch grouping (per-layer routing)
//! - KV batch assembly (the per-layer gather on the decode path), paged
//!   vs. the old contiguous max_seq layout — results land in
//!   BENCH_kvpool.json
//! - checkpoint segment read + streamer queueing
//! - JSON/manifest parse (startup path)
//! - transport post/recv round-trip
//!
//! Run: cargo bench --offline  (or: cargo bench --bench hotpath)

use std::sync::Arc;
use std::time::Duration;

use tarragon::config::TransportConfig;
use tarragon::coordinator::ert::Ert;
use tarragon::coordinator::router::{self, ExpertGroups};
use tarragon::kvcache::{BatchAssembler, KvPool, RequestKv};
use tarragon::modelcfg::ModelSpec;
use tarragon::proto::ClusterMsg;
use tarragon::tensor::Tensor;
use tarragon::testing::bench::{bench, black_box, BenchResult};
use tarragon::transport::{link::TrafficClass, Fabric, NodeId, Plane};
use tarragon::util::json::{arr, num, obj, s};
use tarragon::util::rng::Pcg;

/// The seed's contiguous per-request layout (full `max_seq` K/V buffers
/// per layer), kept here as the benchmark baseline for the paged design.
struct ContiguousKv {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    len: usize,
    s_max: usize,
    seg: usize,
}

impl ContiguousKv {
    fn new(m: &ModelSpec) -> ContiguousKv {
        let seg = m.kv_heads * m.head_dim;
        ContiguousKv {
            k: (0..m.layers).map(|_| vec![0.0; m.max_seq * seg]).collect(),
            v: (0..m.layers).map(|_| vec![0.0; m.max_seq * seg]).collect(),
            len: 0,
            s_max: m.max_seq,
            seg,
        }
    }

    fn write(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        let off = pos * self.seg;
        self.k[layer][off..off + self.seg].copy_from_slice(k_row);
        self.v[layer][off..off + self.seg].copy_from_slice(v_row);
    }

    /// The seed's gather: copies every request's full max_seq buffer.
    fn gather(reqs: &[&ContiguousKv], layer: usize, bucket: usize) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let s_max = reqs[0].s_max;
        let seg = reqs[0].seg;
        let row = s_max * seg;
        let mut k_buf = vec![0.0f32; bucket * row];
        let mut v_buf = vec![0.0f32; bucket * row];
        let mut pos = Vec::with_capacity(bucket);
        for (i, r) in reqs.iter().enumerate() {
            k_buf[i * row..(i + 1) * row].copy_from_slice(&r.k[layer]);
            v_buf[i * row..(i + 1) * row].copy_from_slice(&r.v[layer]);
            pos.push(r.len as i32);
        }
        pos.resize(bucket, 0);
        (k_buf, v_buf, pos)
    }

    fn resident_bytes(&self) -> usize {
        2 * self.k.len() * self.s_max * self.seg * 4
    }
}

fn model() -> ModelSpec {
    ModelSpec {
        layers: 4,
        hidden: 128,
        heads: 4,
        kv_heads: 1,
        head_dim: 32,
        ffn: 256,
        experts: 8,
        top_k: 2,
        vocab: 512,
        max_seq: 160,
    }
}

fn main() {
    println!("== L3 hot-path microbenchmarks ==");
    let m = model();
    let mut rng = Pcg::seeded(42);

    // --- routing: top-k + grouping over a decode batch ------------------
    let b = 8;
    let probs = Tensor::new(
        vec![b, m.experts],
        (0..b * m.experts).map(|_| rng.f32()).collect(),
    );
    bench("router: top-2 select + group (B=8)", 100, 5000, || {
        let routes = router::select_top_k(&probs, b, m.top_k);
        black_box(ExpertGroups::from_routes(&routes));
    });

    // --- ERT resolution --------------------------------------------------
    let mut ert = Ert::initial(m.experts, 4, true);
    bench("ert: resolve 8 experts", 100, 10000, || {
        for e in 0..m.experts {
            black_box(ert.resolve(e));
        }
    });
    ert.mark_dead(1);
    bench("ert: resolve with failover (1 dead)", 100, 10000, || {
        for e in 0..m.experts {
            black_box(ert.resolve(e));
        }
    });

    // --- KV batch assembly (per layer per decode step) -------------------
    let pool = KvPool::for_model(&m);
    // Fill every layer so resident-bytes comparisons reflect a real
    // decode workload (the gather itself is still one layer per call).
    let mk_paged = |len: usize| -> Vec<RequestKv> {
        (0..b)
            .map(|_| {
                let mut kv = RequestKv::new(&m, &pool);
                for layer in 0..m.layers {
                    for pos in 0..len {
                        kv.write(layer, pos, &[1.0; 32], &[2.0; 32]);
                    }
                }
                kv.set_len(len);
                kv
            })
            .collect()
    };
    let mk_contig = |len: usize| -> Vec<ContiguousKv> {
        (0..b)
            .map(|_| {
                let mut kv = ContiguousKv::new(&m);
                for layer in 0..m.layers {
                    for pos in 0..len {
                        kv.write(layer, pos, &[1.0; 32], &[2.0; 32]);
                    }
                }
                kv.len = len;
                kv
            })
            .collect()
    };

    let mut asm = BatchAssembler::new(&m);
    let mut kvpool_results: Vec<(String, BenchResult, usize)> = Vec::new();
    for len in [16usize, 96] {
        let kvs = mk_paged(len);
        let paged_bytes = pool.bytes_in_use();
        let r = bench(&format!("kvcache: paged gather B=8 len={len} (S=160)"), 20, 2000, || {
            let refs: Vec<&RequestKv> = kvs.iter().collect();
            black_box(asm.gather(&refs, 0, b, m.kv_heads, m.head_dim));
        });
        kvpool_results.push((format!("paged_len{len}"), r, paged_bytes));

        let ckvs = mk_contig(len);
        let contig_bytes: usize = ckvs.iter().map(|kv| kv.resident_bytes()).sum();
        let r = bench(&format!("kvcache: contiguous gather B=8 len={len} (S=160)"), 20, 2000, || {
            let refs: Vec<&ContiguousKv> = ckvs.iter().collect();
            black_box(ContiguousKv::gather(&refs, 0, b));
        });
        kvpool_results.push((format!("contiguous_len{len}"), r, contig_bytes));
    }
    write_kvpool_report(&m, &kvpool_results);

    // --- checkpoint segment path ----------------------------------------
    let kvs = mk_paged(96);
    let kv = &kvs[0];
    bench("kvcache: read one segment", 100, 10000, || {
        black_box(kv.read_segment(0, 40));
    });
    bench("kvcache: segment payload (Arc emit)", 100, 10000, || {
        black_box(kv.segment_payload(0, 40));
    });

    // --- transport round trip ---------------------------------------------
    let fabric: Arc<Fabric<ClusterMsg>> = Fabric::new(TransportConfig {
        latency: Duration::ZERO,
        bandwidth_bps: 1e12,
        worker_extra_init: Duration::ZERO,
    });
    let (inbox, _h) = fabric.register(NodeId::Ew(0));
    let (_i2, _h2) = fabric.register(NodeId::Aw(0));
    let qp = fabric.qp(NodeId::Aw(0), NodeId::Ew(0), Plane::Data).unwrap();
    bench("transport: post + recv (zero-latency link)", 100, 5000, || {
        qp.post(ClusterMsg::ActiveBeacon { active: true }, 48, TrafficClass::Control)
            .unwrap();
        black_box(inbox.recv(Duration::from_millis(10)).unwrap());
    });

    // --- manifest parse (startup) -----------------------------------------
    if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
        bench("json: parse manifest.json", 5, 200, || {
            black_box(tarragon::util::json::Json::parse(&text).unwrap());
        });
    }

    println!("== done ==");
}

/// Record the paged-vs-contiguous comparison in BENCH_kvpool.json
/// (written into the directory `cargo bench` runs from — the repo root).
fn write_kvpool_report(m: &ModelSpec, results: &[(String, BenchResult, usize)]) {
    let entries = results.iter().map(|(name, r, bytes)| {
        obj(vec![
            ("name", s(name)),
            ("mean_us", num(r.mean_us)),
            ("median_us", num(r.median_us)),
            ("p95_us", num(r.p95_us)),
            ("iters", num(r.iters as f64)),
            ("resident_kv_bytes_b8", num(*bytes as f64)),
        ])
    });
    let j = obj(vec![
        (
            "bench",
            s("kvcache batch assembly: paged pool vs contiguous max_seq buffers"),
        ),
        ("command", s("cargo bench --bench hotpath")),
        (
            "model",
            obj(vec![
                ("layers", num(m.layers as f64)),
                ("kv_heads", num(m.kv_heads as f64)),
                ("head_dim", num(m.head_dim as f64)),
                ("max_seq", num(m.max_seq as f64)),
                ("batch", num(8.0)),
            ]),
        ),
        ("results", arr(entries)),
    ]);
    let path = "BENCH_kvpool.json";
    match std::fs::write(path, j.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
