//! Macro-sim fleet-scale benchmark: how fast the discrete-event
//! simulator (`tarragon::sim`) replays serving traces as fleet size
//! grows — wall time, simulated-requests/sec and recorded-events/sec at
//! O(100) through O(1000) workers, each run with an AW kill and an EW
//! kill mid-trace so the recovery paths are on the measured path.
//! Results are written to `BENCH_fleet.json`.
//!
//! Run: cargo bench --offline --bench fleet
//! CI smoke: cargo bench --offline --bench fleet -- --smoke
//! (The 10^6-request replay lives in the `#[ignore]`d test
//! `full_scale_fleet_replays_a_million_requests` in tests/sim_fleet.rs.)

use std::time::Duration;

use tarragon::sim::{run_fleet, EventLevel, FleetConfig, TraceSpec};
use tarragon::testing::scenario::ScheduledFault;
use tarragon::util::json::{arr, num, obj, s, Json};

struct Point {
    aws: usize,
    ews: usize,
    requests: usize,
    sim_s: f64,
    wall_ms: f64,
    events: usize,
    finished: usize,
    preemptions: u64,
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // (AWs, EWs, offered rps, trace seconds). Offered load is ~60% of
    // the cost-model capacity (~8.5 rps/AW at the default trace length
    // profile), so queues stay bounded and wall time measures the
    // engine, not a death spiral.
    let scales: &[(usize, usize, f64, u64)] = if smoke {
        &[(64, 16, 320.0, 5)]
    } else {
        &[(100, 25, 500.0, 10), (250, 64, 1250.0, 10), (1000, 250, 5000.0, 20)]
    };

    println!("== macro-sim fleet sweep (discrete-event clock, cost-model steps) ==");
    let mut points = Vec::new();
    for &(aws, ews, rps, secs) in scales {
        let trace =
            TraceSpec::bursty(rps, Duration::from_secs(secs), 0xF1EE7).generate();
        let faults: Vec<ScheduledFault> = [
            format!("at {}ms kill aw1", secs * 300),
            format!("at {}ms kill ew1", secs * 500),
        ]
        .iter()
        .map(|l| ScheduledFault::parse(l).expect("fault line"))
        .collect();
        let mut cfg = FleetConfig::new(aws, ews);
        // Lifecycle keeps the log proportional to requests, not tokens —
        // the regime any fleet-sized run uses.
        cfg.event_level = EventLevel::Lifecycle;

        let t0 = std::time::Instant::now();
        let r = run_fleet(cfg, &trace, &faults);
        let wall = t0.elapsed();
        assert_eq!(
            r.report.finished + r.report.rejected,
            trace.len(),
            "fleet bench lost requests at {aws} AWs"
        );
        assert_eq!(r.unfinished, 0);
        assert_eq!(r.unpaired_departures, 0);

        let events = r.events.snapshot().len();
        let p = Point {
            aws,
            ews,
            requests: trace.len(),
            sim_s: r.sim_end.as_secs_f64(),
            wall_ms: wall.as_secs_f64() * 1e3,
            events,
            finished: r.report.finished,
            preemptions: r.report.preemptions,
        };
        println!(
            "{:>5} AW x {:>4} EW | {:>7} reqs | sim {:>7.1}s in wall {:>8.1}ms ({:>9.0} req/s, {:>9.0} ev/s) | preempt {:>4}",
            p.aws,
            p.ews,
            p.requests,
            p.sim_s,
            p.wall_ms,
            p.requests as f64 / (wall.as_secs_f64().max(1e-9)),
            p.events as f64 / (wall.as_secs_f64().max(1e-9)),
            p.preemptions,
        );
        points.push(p);
    }
    write_report(&points, smoke);
}

fn write_report(points: &[Point], smoke: bool) {
    let entries = points.iter().map(|p| {
        obj(vec![
            ("aws", num(p.aws as f64)),
            ("ews", num(p.ews as f64)),
            ("requests", num(p.requests as f64)),
            ("finished", num(p.finished as f64)),
            ("sim_seconds", num(p.sim_s)),
            ("wall_ms", num(p.wall_ms)),
            ("events_recorded", num(p.events as f64)),
            ("requests_per_wall_s", num(p.requests as f64 / (p.wall_ms / 1e3).max(1e-9))),
            ("events_per_wall_s", num(p.events as f64 / (p.wall_ms / 1e3).max(1e-9))),
            ("preemptions", num(p.preemptions as f64)),
        ])
    });
    let j = obj(vec![
        (
            "bench",
            s("macro-sim fleet sweep: wall time vs fleet size with mid-trace AW+EW kills"),
        ),
        ("command", s("cargo bench --bench fleet")),
        ("smoke", Json::Bool(smoke)),
        (
            "setup",
            obj(vec![
                ("trace", s("bursty 4x/200ms-per-2s, default length profile, fixed seed")),
                ("event_level", s("lifecycle")),
                ("faults", s("kill aw1 at 30% of trace, kill ew1 at 50%")),
            ]),
        ),
        ("results", arr(entries)),
    ]);
    let path = "BENCH_fleet.json";
    match std::fs::write(path, j.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
