//! The fabric: node registry, inboxes, QP sender handles, and fault
//! injection. See module docs in `transport`.
//!
//! All blocking (delivery deadlines, probe costs, recv timeouts) goes
//! through the fabric's [`Clock`], so a cluster built on a virtual clock
//! replays deterministically with no real sleeping.

use super::link::{Link, TrafficClass};
use super::{NodeId, Plane};
use crate::config::TransportConfig;
use crate::util::clock::{self, Clock};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QpError {
    LocalDown(NodeId),
    RetryExceeded(NodeId),
    Timeout,
    Unknown(NodeId),
}

impl std::fmt::Display for QpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QpError::LocalDown(n) => write!(f, "local node {n} is down"),
            QpError::RetryExceeded(n) => {
                write!(f, "retry exceeded toward {n} (peer dead or link severed)")
            }
            QpError::Timeout => write!(f, "recv timed out"),
            QpError::Unknown(n) => write!(f, "node {n} is not registered"),
        }
    }
}

impl std::error::Error for QpError {}

/// A delivered message with its transport metadata. `deliver_at` is an
/// offset from the fabric clock's epoch.
#[derive(Debug)]
pub struct Envelope<M> {
    pub from: NodeId,
    pub plane: Plane,
    pub seq: u64,
    pub class: TrafficClass,
    pub deliver_at: Duration,
    pub msg: M,
}

struct NodeEntry<M> {
    alive: Arc<AtomicBool>,
    inbox_tx: clock::Sender<Envelope<M>>,
    egress: Arc<Link>,
}

/// Handle a worker keeps to its own node registration.
pub struct NodeHandle {
    pub id: NodeId,
    alive: Arc<AtomicBool>,
    egress: Arc<Link>,
}

impl NodeHandle {
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    /// This node's egress link (the checkpoint streamer's idle-gap gate).
    pub fn egress(&self) -> &Arc<Link> {
        &self.egress
    }
}

/// Receiving side of a node: one unified inbox over all QPs/planes.
pub struct Inbox<M> {
    id: NodeId,
    rx: clock::Receiver<Envelope<M>>,
    alive: Arc<AtomicBool>,
    clock: Clock,
}

impl<M> Inbox<M> {
    /// Receive the next message, honoring simulated delivery time: the
    /// call sleeps until the message's `deliver_at` before returning it.
    pub fn recv(&self, timeout: Duration) -> Result<Envelope<M>, QpError> {
        if !self.alive.load(Ordering::Acquire) {
            return Err(QpError::LocalDown(self.id));
        }
        let env = match self.rx.recv_timeout(timeout) {
            Ok(e) => e,
            Err(RecvTimeoutError::Timeout) => return Err(QpError::Timeout),
            Err(RecvTimeoutError::Disconnected) => return Err(QpError::LocalDown(self.id)),
        };
        if env.deliver_at > self.clock.now() {
            self.clock.sleep_until(env.deliver_at);
        }
        if !self.alive.load(Ordering::Acquire) {
            // Crashed while the message was "on the wire".
            return Err(QpError::LocalDown(self.id));
        }
        Ok(env)
    }

    /// Drain everything immediately deliverable without blocking.
    pub fn drain_ready(&self) -> Vec<Envelope<M>> {
        let mut out = Vec::new();
        while let Ok(env) = self.rx.try_recv() {
            if env.deliver_at > self.clock.now() {
                // Still in flight: honor its delivery time, then take it.
                self.clock.sleep_until(env.deliver_at);
            }
            out.push(env);
        }
        out
    }

    pub fn id(&self) -> NodeId {
        self.id
    }
}

/// Directed sender handle ("queue pair" toward one peer on one plane).
/// One-sided post semantics: `post` never blocks and never errors toward a
/// dead peer; `probe` is the NIC-level liveness check.
pub struct Qp<M> {
    pub local: NodeId,
    pub peer: NodeId,
    pub plane: Plane,
    fabric: Arc<Fabric<M>>,
    local_alive: Arc<AtomicBool>,
    egress: Arc<Link>,
    seq: AtomicU64,
}

impl<M: Send + 'static> Qp<M> {
    /// Post a message (one-sided write). Returns the work-request seq id.
    pub fn post(&self, msg: M, bytes: usize, class: TrafficClass) -> Result<u64, QpError> {
        if !self.local_alive.load(Ordering::Acquire) {
            return Err(QpError::LocalDown(self.local));
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let deliver_at = self.egress.reserve(bytes, class);
        self.fabric.deliver(
            Envelope { from: self.local, plane: self.plane, seq, class, deliver_at, msg },
            self.peer,
        );
        Ok(seq)
    }

    /// Zero-length write acked by the peer NIC (Appendix E): succeeds iff
    /// the peer node is alive and the path is not severed. Costs one RTT
    /// on success, the full `timeout` on failure.
    pub fn probe(&self, timeout: Duration) -> Result<Duration, QpError> {
        if !self.local_alive.load(Ordering::Acquire) {
            return Err(QpError::LocalDown(self.local));
        }
        let clock = self.fabric.clock();
        let rtt = 2 * self.egress.latency();
        if self.fabric.path_up(self.local, self.peer) {
            clock.sleep(rtt);
            // Re-check: the peer may have died while the probe was in flight.
            if self.fabric.path_up(self.local, self.peer) {
                return Ok(rtt);
            }
        }
        clock.sleep(timeout);
        Err(QpError::RetryExceeded(self.peer))
    }

    /// Non-blocking peer liveness as known to the RNIC *after* a completed
    /// probe — used by tests and the orchestrator's bookkeeping.
    pub fn peer_reachable(&self) -> bool {
        self.fabric.path_up(self.local, self.peer)
    }
}

/// The cluster interconnect. Generic over the message type `M` (the
/// cluster defines one message enum for all workers).
pub struct Fabric<M> {
    cfg: TransportConfig,
    clock: Clock,
    nodes: RwLock<HashMap<NodeId, NodeEntry<M>>>,
    severed: Mutex<HashSet<(NodeId, NodeId)>>,
}

impl<M: Send + 'static> Fabric<M> {
    /// A fabric on real (wall-clock) time.
    pub fn new(cfg: TransportConfig) -> Arc<Fabric<M>> {
        Self::with_clock(cfg, Clock::wall())
    }

    /// A fabric on an explicit clock (virtual for scenario runs).
    pub fn with_clock(cfg: TransportConfig, clock: Clock) -> Arc<Fabric<M>> {
        Arc::new(Fabric {
            cfg,
            clock,
            nodes: RwLock::new(HashMap::new()),
            severed: Mutex::new(HashSet::new()),
        })
    }

    /// The clock every link/inbox of this fabric runs on.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Register (or re-register, for a restarted worker) a node; returns
    /// its inbox and handle. Re-registration revives a killed id.
    pub fn register(self: &Arc<Self>, id: NodeId) -> (Inbox<M>, NodeHandle) {
        let (tx, rx) = clock::channel(&self.clock);
        let alive = Arc::new(AtomicBool::new(true));
        let egress =
            Arc::new(Link::new(self.cfg.bandwidth_bps, self.cfg.latency, self.clock.clone()));
        let entry = NodeEntry { alive: alive.clone(), inbox_tx: tx, egress: egress.clone() };
        self.nodes.write().unwrap().insert(id, entry);
        // A fresh registration also clears any severed links of a previous
        // incarnation.
        self.severed.lock().unwrap().retain(|&(a, b)| a != id && b != id);
        (
            Inbox { id, rx, alive: alive.clone(), clock: self.clock.clone() },
            NodeHandle { id, alive, egress },
        )
    }

    /// Create a QP from `local` toward `peer` on `plane`.
    pub fn qp(self: &Arc<Self>, local: NodeId, peer: NodeId, plane: Plane) -> Result<Qp<M>, QpError> {
        let nodes = self.nodes.read().unwrap();
        let l = nodes.get(&local).ok_or(QpError::Unknown(local))?;
        if !nodes.contains_key(&peer) {
            return Err(QpError::Unknown(peer));
        }
        Ok(Qp {
            local,
            peer,
            plane,
            fabric: self.clone(),
            local_alive: l.alive.clone(),
            egress: l.egress.clone(),
            seq: AtomicU64::new(0),
        })
    }

    fn deliver(&self, env: Envelope<M>, to: NodeId) {
        if !self.path_up(env.from, to) {
            return; // vanishes, like a write into a dead node
        }
        if let Some(entry) = self.nodes.read().unwrap().get(&to) {
            let _ = entry.inbox_tx.send(env);
        }
    }

    /// Fail-stop a node (§3.3). Its inbox stops accepting and its QPs go
    /// silent; peers find out via probes.
    pub fn kill(&self, id: NodeId) {
        if let Some(e) = self.nodes.read().unwrap().get(&id) {
            e.alive.store(false, Ordering::Release);
        }
    }

    /// Link failure between two nodes: both keep running but cannot reach
    /// each other (handled like fail-stop by the affected peers, §3.3).
    pub fn sever(&self, a: NodeId, b: NodeId) {
        self.severed.lock().unwrap().insert(key(a, b));
    }

    pub fn heal(&self, a: NodeId, b: NodeId) {
        self.severed.lock().unwrap().remove(&key(a, b));
    }

    pub fn is_alive(&self, id: NodeId) -> bool {
        self.nodes
            .read()
            .unwrap()
            .get(&id)
            .map(|e| e.alive.load(Ordering::Acquire))
            .unwrap_or(false)
    }

    fn path_up(&self, a: NodeId, b: NodeId) -> bool {
        self.is_alive(a)
            && self.is_alive(b)
            && !self.severed.lock().unwrap().contains(&key(a, b))
    }

    /// Egress link of a node (harnesses enable recording through this).
    pub fn egress_of(&self, id: NodeId) -> Option<Arc<Link>> {
        self.nodes.read().unwrap().get(&id).map(|e| e.egress.clone())
    }

    pub fn config(&self) -> &TransportConfig {
        &self.cfg
    }

    pub fn node_ids(&self) -> Vec<NodeId> {
        let mut ids: Vec<NodeId> = self.nodes.read().unwrap().keys().copied().collect();
        ids.sort();
        ids
    }
}

fn key(a: NodeId, b: NodeId) -> (NodeId, NodeId) {
    if a <= b {
        (a, b)
    } else {
        (b, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn test_cfg() -> TransportConfig {
        TransportConfig {
            latency: Duration::from_micros(100),
            bandwidth_bps: 1e9,
            worker_extra_init: Duration::ZERO,
        }
    }

    #[test]
    fn post_and_recv_roundtrip() {
        let fabric: Arc<Fabric<String>> = Fabric::new(test_cfg());
        let (inbox_b, _hb) = fabric.register(NodeId::Ew(0));
        let (_inbox_a, _ha) = fabric.register(NodeId::Aw(0));
        let qp = fabric.qp(NodeId::Aw(0), NodeId::Ew(0), Plane::Data).unwrap();
        let seq0 = qp.post("hello".into(), 64, TrafficClass::ExpertDispatch).unwrap();
        let seq1 = qp.post("world".into(), 64, TrafficClass::ExpertDispatch).unwrap();
        assert_eq!((seq0, seq1), (0, 1));
        let e1 = inbox_b.recv(Duration::from_secs(1)).unwrap();
        let e2 = inbox_b.recv(Duration::from_secs(1)).unwrap();
        assert_eq!(e1.msg, "hello");
        assert_eq!(e2.msg, "world");
        assert_eq!(e1.from, NodeId::Aw(0));
        assert_eq!(e1.plane, Plane::Data);
        assert!(e2.seq > e1.seq);
    }

    #[test]
    fn messages_to_dead_peer_vanish_but_post_succeeds() {
        let fabric: Arc<Fabric<u32>> = Fabric::new(test_cfg());
        let (inbox_b, _hb) = fabric.register(NodeId::Ew(1));
        let (_ia, _ha) = fabric.register(NodeId::Aw(1));
        let qp = fabric.qp(NodeId::Aw(1), NodeId::Ew(1), Plane::Data).unwrap();
        fabric.kill(NodeId::Ew(1));
        // One-sided post still succeeds...
        qp.post(7, 8, TrafficClass::ExpertDispatch).unwrap();
        // ...but the peer never sees it (and its inbox reports local-down).
        assert!(matches!(
            inbox_b.recv(Duration::from_millis(50)),
            Err(QpError::LocalDown(_))
        ));
    }

    #[test]
    fn probe_detects_dead_peer_and_costs_timeout() {
        let fabric: Arc<Fabric<u32>> = Fabric::new(test_cfg());
        let (_ib, _hb) = fabric.register(NodeId::Ew(2));
        let (_ia, _ha) = fabric.register(NodeId::Aw(2));
        let qp = fabric.qp(NodeId::Aw(2), NodeId::Ew(2), Plane::Control).unwrap();
        // Alive: succeeds within ~1 RTT.
        let rtt = qp.probe(Duration::from_millis(100)).unwrap();
        assert!(rtt <= Duration::from_millis(5));
        fabric.kill(NodeId::Ew(2));
        let t0 = Instant::now();
        let err = qp.probe(Duration::from_millis(30)).unwrap_err();
        assert_eq!(err, QpError::RetryExceeded(NodeId::Ew(2)));
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn probe_timeout_costs_only_virtual_time_on_a_virtual_fabric() {
        let clock = Clock::virtual_seeded(5);
        let _g = clock.register();
        let fabric: Arc<Fabric<u32>> = Fabric::with_clock(test_cfg(), clock.clone());
        let (_ib, _hb) = fabric.register(NodeId::Ew(2));
        let (_ia, _ha) = fabric.register(NodeId::Aw(2));
        let qp = fabric.qp(NodeId::Aw(2), NodeId::Ew(2), Plane::Control).unwrap();
        fabric.kill(NodeId::Ew(2));
        let wall0 = Instant::now();
        let t0 = clock.now();
        let err = qp.probe(Duration::from_secs(30)).unwrap_err();
        assert_eq!(err, QpError::RetryExceeded(NodeId::Ew(2)));
        assert!(clock.now() - t0 >= Duration::from_secs(30), "virtual cost");
        assert!(wall0.elapsed() < Duration::from_secs(1), "no real sleeping");
        clock.shutdown();
    }

    #[test]
    fn severed_link_isolates_pair_only() {
        let fabric: Arc<Fabric<u32>> = Fabric::new(test_cfg());
        let (inbox_e, _he) = fabric.register(NodeId::Ew(0));
        let (_ia0, _h0) = fabric.register(NodeId::Aw(0));
        let (_ia1, _h1) = fabric.register(NodeId::Aw(1));
        fabric.sever(NodeId::Aw(0), NodeId::Ew(0));
        let qp0 = fabric.qp(NodeId::Aw(0), NodeId::Ew(0), Plane::Data).unwrap();
        let qp1 = fabric.qp(NodeId::Aw(1), NodeId::Ew(0), Plane::Data).unwrap();
        assert!(!qp0.peer_reachable());
        assert!(qp1.peer_reachable());
        qp0.post(0, 8, TrafficClass::ExpertDispatch).unwrap();
        qp1.post(1, 8, TrafficClass::ExpertDispatch).unwrap();
        let got = inbox_e.recv(Duration::from_millis(200)).unwrap();
        assert_eq!(got.msg, 1); // only aw1's message arrives
        assert!(inbox_e.recv(Duration::from_millis(50)).is_err());
        // heal restores the path
        fabric.heal(NodeId::Aw(0), NodeId::Ew(0));
        assert!(qp0.peer_reachable());
    }

    #[test]
    fn reregistration_revives_node() {
        let fabric: Arc<Fabric<u32>> = Fabric::new(test_cfg());
        let (_i, _h) = fabric.register(NodeId::Aw(5));
        fabric.kill(NodeId::Aw(5));
        assert!(!fabric.is_alive(NodeId::Aw(5)));
        let (inbox2, _h2) = fabric.register(NodeId::Aw(5));
        assert!(fabric.is_alive(NodeId::Aw(5)));
        let (_ig, _hg) = fabric.register(NodeId::Gateway(0));
        let qp = fabric.qp(NodeId::Gateway(0), NodeId::Aw(5), Plane::Control).unwrap();
        qp.post(9, 8, TrafficClass::Admin).unwrap();
        assert_eq!(inbox2.recv(Duration::from_millis(200)).unwrap().msg, 9);
    }

    #[test]
    fn delivery_time_respects_bandwidth() {
        let mut cfg = test_cfg();
        cfg.bandwidth_bps = 1e6; // 1 MB/s
        cfg.latency = Duration::ZERO;
        let fabric: Arc<Fabric<u32>> = Fabric::new(cfg);
        let (inbox, _h) = fabric.register(NodeId::Store(0));
        let (_i2, _h2) = fabric.register(NodeId::Aw(0));
        let qp = fabric.qp(NodeId::Aw(0), NodeId::Store(0), Plane::Data).unwrap();
        let t0 = Instant::now();
        qp.post(0, 10_000, TrafficClass::Checkpoint).unwrap(); // 10 ms transfer
        inbox.recv(Duration::from_secs(1)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(9));
    }

    #[test]
    fn drain_ready_returns_everything_posted() {
        let fabric: Arc<Fabric<u32>> = Fabric::new(test_cfg());
        let (inbox, _h) = fabric.register(NodeId::Ew(0));
        let (_i2, _h2) = fabric.register(NodeId::Aw(0));
        let qp = fabric.qp(NodeId::Aw(0), NodeId::Ew(0), Plane::Data).unwrap();
        for i in 0..5 {
            qp.post(i, 16, TrafficClass::ExpertDispatch).unwrap();
        }
        std::thread::sleep(Duration::from_millis(2));
        let got = inbox.drain_ready();
        assert_eq!(got.len(), 5);
        assert!(got.windows(2).all(|w| w[0].seq < w[1].seq));
    }
}
