//! Simulated RDMA fabric (DESIGN.md §3).
//!
//! Models what the paper's datapath relies on, at the fidelity the
//! experiments need:
//!
//! - **Queue pairs per plane**: each AW-EW pair uses a *control* and a
//!   *data* plane (§4.1); here a [`Qp`] is a directed sender handle tagged
//!   with its plane, posting into the peer's inbox. One-sided semantics:
//!   `post()` never blocks on the peer and never fails toward a dead peer —
//!   the message simply vanishes, exactly like an RDMA write into a dead
//!   node. Failure *detection* is the job of probes and silence windows.
//! - **NIC serialization**: each node has one egress [`Link`] with a
//!   bandwidth/latency model; concurrent transfers serialize, producing the
//!   bursty utilization Fig. 8 measures. The checkpoint streamer asks the
//!   link whether it is idle before opportunistically flushing segments.
//! - **Hardware-style failure signaling**: [`Qp::probe`] models a
//!   zero-length RC write acked by the peer *NIC*: it succeeds iff the peer
//!   node is alive and the path is not severed, with an RTT cost; otherwise
//!   it costs the configured timeout and reports `RetryExceeded`
//!   (the `IBV_WC_RETRY_EXC_ERR` analogue, Appendix E).
//! - **Fault injection**: [`Fabric::kill`] (fail-stop node crash) and
//!   [`Fabric::sever`] (link failure isolating two peers, §3.3).

pub mod fabric;
pub mod link;

pub use fabric::{Envelope, Fabric, Inbox, NodeHandle, Qp, QpError};
pub use link::{Link, LinkStats, TrafficClass, TrafficEvent};

use std::fmt;

/// Logical node addresses in the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum NodeId {
    Aw(u32),
    Ew(u32),
    /// Checkpoint store replica `k` of `K` (its own node, §7.1).
    Store(u32),
    /// The *role* address of the active orchestrator. A promoted standby
    /// re-registers this id, swapping a fresh inbox under every existing
    /// QP (delivery resolves the receiver at post time).
    Orchestrator,
    /// Warm-standby orchestrator, mirroring state until promotion.
    OrchStandby,
    /// Gateway shard `n` of `N` (consistent-hash admission sharding).
    Gateway(u32),
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NodeId::Aw(i) => write!(f, "aw{i}"),
            NodeId::Ew(i) => write!(f, "ew{i}"),
            NodeId::Store(i) => write!(f, "store{i}"),
            NodeId::Orchestrator => write!(f, "orch"),
            NodeId::OrchStandby => write!(f, "orch-standby"),
            NodeId::Gateway(i) => write!(f, "gateway{i}"),
        }
    }
}

/// The two planes of §4.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Plane {
    Control,
    Data,
}
