//! Per-node egress link: bandwidth/latency model, busy-interval tracking,
//! traffic accounting, and (optionally) a raw event log for the Fig. 8
//! utilization trace.
//!
//! All timing flows through the owning fabric's [`Clock`], so the same
//! link model runs in real time (production-style runs, benches) or in
//! deterministic virtual time (the failure-scenario harness). Timestamps
//! are `Duration`s since the clock's epoch.

use crate::util::clock::Clock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// What a transfer carries — the accounting dimension for Fig. 8 / Fig. 12.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TrafficClass {
    /// AW -> EW token embeddings (scatter).
    ExpertDispatch,
    /// EW -> AW expert outputs (gather).
    ExpertReturn,
    /// AW -> checkpoint-store incremental KV segments (§6.1).
    Checkpoint,
    /// Checkpoint-store -> AW restoration writes (§6.2).
    Restore,
    /// Probes and self-healing metadata (control plane).
    Control,
    /// Orchestrator/admin messages.
    Admin,
}

impl TrafficClass {
    pub const ALL: [TrafficClass; 6] = [
        TrafficClass::ExpertDispatch,
        TrafficClass::ExpertReturn,
        TrafficClass::Checkpoint,
        TrafficClass::Restore,
        TrafficClass::Control,
        TrafficClass::Admin,
    ];

    fn index(self) -> usize {
        match self {
            TrafficClass::ExpertDispatch => 0,
            TrafficClass::ExpertReturn => 1,
            TrafficClass::Checkpoint => 2,
            TrafficClass::Restore => 3,
            TrafficClass::Control => 4,
            TrafficClass::Admin => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TrafficClass::ExpertDispatch => "expert_dispatch",
            TrafficClass::ExpertReturn => "expert_return",
            TrafficClass::Checkpoint => "checkpoint",
            TrafficClass::Restore => "restore",
            TrafficClass::Control => "control",
            TrafficClass::Admin => "admin",
        }
    }
}

/// One recorded transfer (recording enabled): times relative to the
/// clock's epoch, in microseconds.
#[derive(Debug, Clone, Copy)]
pub struct TrafficEvent {
    pub start_us: u64,
    pub end_us: u64,
    pub bytes: u64,
    pub class: TrafficClass,
}

#[derive(Debug, Default, Clone)]
pub struct LinkStats {
    /// Total bytes per class (see TrafficClass::index).
    pub bytes: [u64; 6],
    pub transfers: u64,
}

impl LinkStats {
    pub fn bytes_of(&self, c: TrafficClass) -> u64 {
        self.bytes[c.index()]
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }
}

/// Egress link of one node. Transfers serialize: each reservation starts
/// no earlier than the previous one finished (single NIC).
pub struct Link {
    bandwidth_bps: f64,
    latency: Duration,
    clock: Clock,
    busy_until: Mutex<Duration>,
    bytes: [AtomicU64; 6],
    transfers: AtomicU64,
    recording: Mutex<Option<Vec<TrafficEvent>>>,
}

impl Link {
    pub fn new(bandwidth_bps: f64, latency: Duration, clock: Clock) -> Link {
        assert!(bandwidth_bps > 0.0);
        let now = clock.now();
        Link {
            bandwidth_bps,
            latency,
            clock,
            busy_until: Mutex::new(now),
            bytes: Default::default(),
            transfers: AtomicU64::new(0),
            recording: Mutex::new(None),
        }
    }

    /// Reserve the link for `bytes` starting no earlier than now; returns
    /// the delivery time (serialization + propagation latency), as an
    /// offset from the clock's epoch.
    pub fn reserve(&self, bytes: usize, class: TrafficClass) -> Duration {
        let now = self.clock.now();
        let ser = Duration::from_secs_f64(bytes as f64 / self.bandwidth_bps);
        let (start, end) = {
            let mut busy = self.busy_until.lock().unwrap();
            let start = (*busy).max(now);
            let end = start + ser;
            *busy = end;
            (start, end)
        };
        self.bytes[class.index()].fetch_add(bytes as u64, Ordering::Relaxed);
        self.transfers.fetch_add(1, Ordering::Relaxed);
        if let Some(log) = self.recording.lock().unwrap().as_mut() {
            log.push(TrafficEvent {
                start_us: start.as_micros() as u64,
                end_us: end.as_micros() as u64,
                bytes: bytes as u64,
                class,
            });
        }
        end + self.latency
    }

    /// Is the link idle right now? The checkpoint streamer's opportunistic
    /// gate (§6.1): segments are flushed only into idle gaps.
    pub fn is_idle(&self) -> bool {
        *self.busy_until.lock().unwrap() <= self.clock.now()
    }

    /// Time until the link drains (zero if idle).
    pub fn busy_for(&self) -> Duration {
        let busy = *self.busy_until.lock().unwrap();
        busy.saturating_sub(self.clock.now())
    }

    pub fn stats(&self) -> LinkStats {
        LinkStats {
            bytes: std::array::from_fn(|i| self.bytes[i].load(Ordering::Relaxed)),
            transfers: self.transfers.load(Ordering::Relaxed),
        }
    }

    pub fn enable_recording(&self) {
        let mut rec = self.recording.lock().unwrap();
        if rec.is_none() {
            *rec = Some(Vec::new());
        }
    }

    pub fn take_recording(&self) -> Vec<TrafficEvent> {
        self.recording.lock().unwrap().take().unwrap_or_default()
    }

    pub fn latency(&self) -> Duration {
        self.latency
    }

    /// The clock this link's timestamps are relative to.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialization_delay_scales_with_bytes() {
        let clock = Clock::wall();
        let link = Link::new(1e6, Duration::ZERO, clock.clone()); // 1 MB/s
        let t0 = clock.now();
        let d1 = link.reserve(1000, TrafficClass::ExpertDispatch); // 1 ms
        let d2 = link.reserve(1000, TrafficClass::ExpertDispatch); // +1 ms
        assert!(d1.saturating_sub(t0) >= Duration::from_micros(900));
        assert!(d2.saturating_sub(d1) >= Duration::from_micros(900));
    }

    #[test]
    fn latency_is_added_after_serialization() {
        let clock = Clock::wall();
        let link = Link::new(1e9, Duration::from_millis(5), clock.clone());
        let t0 = clock.now();
        let d = link.reserve(8, TrafficClass::Control);
        assert!(d.saturating_sub(t0) >= Duration::from_millis(5));
    }

    #[test]
    fn idle_tracking() {
        let link = Link::new(1e3, Duration::ZERO, Clock::wall()); // 1 KB/s: slow
        assert!(link.is_idle());
        link.reserve(100, TrafficClass::Checkpoint); // 100 ms of busy
        assert!(!link.is_idle());
        assert!(link.busy_for() > Duration::from_millis(50));
    }

    #[test]
    fn idle_tracking_under_virtual_time() {
        let clock = Clock::virtual_seeded(1);
        let _g = clock.register();
        let link = Link::new(1e3, Duration::ZERO, clock.clone());
        link.reserve(100, TrafficClass::Checkpoint); // 100 virtual ms busy
        assert!(!link.is_idle());
        clock.sleep(Duration::from_millis(100));
        assert!(link.is_idle(), "virtual advance must drain the link");
        clock.shutdown();
    }

    #[test]
    fn per_class_accounting() {
        let link = Link::new(1e9, Duration::ZERO, Clock::wall());
        link.reserve(100, TrafficClass::ExpertDispatch);
        link.reserve(50, TrafficClass::Checkpoint);
        link.reserve(50, TrafficClass::Checkpoint);
        let s = link.stats();
        assert_eq!(s.bytes_of(TrafficClass::ExpertDispatch), 100);
        assert_eq!(s.bytes_of(TrafficClass::Checkpoint), 100);
        assert_eq!(s.total_bytes(), 200);
        assert_eq!(s.transfers, 3);
    }

    #[test]
    fn recording_captures_intervals() {
        let link = Link::new(1e6, Duration::ZERO, Clock::wall());
        link.enable_recording();
        link.reserve(500, TrafficClass::ExpertDispatch);
        link.reserve(500, TrafficClass::Checkpoint);
        let events = link.take_recording();
        assert_eq!(events.len(), 2);
        assert!(events[1].start_us >= events[0].end_us); // serialized
        assert_eq!(events[0].bytes, 500);
        // recording stops after take
        link.reserve(10, TrafficClass::Control);
        assert!(link.take_recording().is_empty());
    }
}
