//! Scratch arena: recycled tensor storage for the decode hot path.
//!
//! Every per-step buffer on the decode path — kernel outputs, batch
//! staging, attention scratch — is an exact-size f32 buffer whose
//! lifetime is one step. Allocating them fresh each step made the
//! allocator the hottest "kernel" in the profile; this module keeps a
//! process-wide pool of `Arc<Storage>` blocks keyed by element count, so
//! a steady-state step recycles the same allocations forever.
//!
//! Why process-wide and not thread-local: tensors cross threads (AW
//! thread → device thread → back; EW return rows → REFE). A per-thread
//! arena would leak from the producing thread and starve the consuming
//! one. The pool is a leaf mutex (never held across any other lock or
//! user code), and page grabs are rare relative to the float traffic
//! they carry.
//!
//! Recycling happens in `Tensor::drop`: when the last reference to a
//! recyclable storage dies, the whole `Arc<Storage>` (control block and
//! all) is parked here instead of being freed, so a warm steady state
//! performs literally zero heap allocations per step — the property
//! `rust/tests/alloc.rs` pins with a counting global allocator.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

/// One reference-counted storage block backing [`super::Tensor`] data.
/// `recyclable` is false for user-constructed tensors whose buffer was
/// handed to us (`Tensor::new`) and may be reclaimed via `into_data`.
pub(crate) struct Storage {
    pub(crate) data: Vec<f32>,
    pub(crate) recyclable: bool,
}

/// Exact-size pool of idle storage blocks. The crate hot path uses the
/// process-shared instance ([`warm`], [`shared_stats`]); tensors check
/// blocks in and out through the crate-internal take/recycle functions.
pub struct ScratchArena {
    /// len -> idle blocks of exactly that many floats.
    classes: BTreeMap<usize, Vec<Arc<Storage>>>,
    held_floats: usize,
    cap_floats: usize,
    hits: u64,
    misses: u64,
}

/// Default retention cap: 1<<24 floats = 64 MiB of recycled buffers.
pub const DEFAULT_CAP_FLOATS: usize = 1 << 24;

/// Per-size-class cap on idle blocks (bounds pathological churn).
const CLASS_CAP: usize = 64;

impl ScratchArena {
    pub fn new(cap_floats: usize) -> ScratchArena {
        ScratchArena {
            classes: BTreeMap::new(),
            held_floats: 0,
            cap_floats,
            hits: 0,
            misses: 0,
        }
    }

    /// (hits, misses) of `take` calls — bench/telemetry.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    fn take(&mut self, len: usize) -> Arc<Storage> {
        if let Some(list) = self.classes.get_mut(&len) {
            if let Some(st) = list.pop() {
                self.held_floats -= len;
                self.hits += 1;
                debug_assert_eq!(Arc::strong_count(&st), 1);
                return st;
            }
        }
        self.misses += 1;
        Arc::new(Storage { data: vec![0.0; len], recyclable: true })
    }

    fn put(&mut self, st: Arc<Storage>) {
        let len = st.data.len();
        if len == 0 || self.held_floats + len > self.cap_floats {
            return; // dropped: over cap (or degenerate)
        }
        let list = self.classes.entry(len).or_default();
        if list.len() >= CLASS_CAP {
            return;
        }
        self.held_floats += len;
        list.push(st);
    }
}

fn shared() -> &'static Mutex<ScratchArena> {
    static POOL: OnceLock<Mutex<ScratchArena>> = OnceLock::new();
    POOL.get_or_init(|| Mutex::new(ScratchArena::new(DEFAULT_CAP_FLOATS)))
}

/// Check out a storage block of exactly `len` floats with *unspecified*
/// contents (strong count 1). Callers must overwrite the region they
/// expose; [`take_zeroed`] is the safe default.
pub(crate) fn take(len: usize) -> Arc<Storage> {
    match shared().lock() {
        Ok(mut pool) => pool.take(len),
        // Poisoned (a test panicked mid-operation): degrade to fresh.
        Err(_) => Arc::new(Storage { data: vec![0.0; len], recyclable: true }),
    }
}

/// Check out a zero-filled storage block of exactly `len` floats.
pub(crate) fn take_zeroed(len: usize) -> Arc<Storage> {
    let mut st = take(len);
    if let Some(s) = Arc::get_mut(&mut st) {
        s.data.fill(0.0);
    }
    st
}

/// Park a storage block for reuse. Called from `Tensor::drop` when the
/// last reference to a recyclable storage dies; `st` must be the sole
/// strong reference (the caller *moves* its ref in — see
/// [`empty`] for why a clone would race).
pub(crate) fn recycle(st: Arc<Storage>) {
    debug_assert_eq!(Arc::strong_count(&st), 1, "recycled block must be sole-owned");
    if let Ok(mut pool) = shared().lock() {
        pool.put(st);
    }
}

/// Shared placeholder storage: `Tensor::drop` swaps this in so it can
/// *move* its sole reference into the pool. Parking a clone instead
/// would briefly leave the pool holding a block with two strong refs —
/// a racing `take` on another thread could then pop it, fail
/// `Arc::get_mut`, skip the zero-fill, and hand out stale floats.
pub(crate) fn empty() -> Arc<Storage> {
    static EMPTY: OnceLock<Arc<Storage>> = OnceLock::new();
    EMPTY
        .get_or_init(|| Arc::new(Storage { data: Vec::new(), recyclable: false }))
        .clone()
}

/// Shared-pool hit/miss counters (bench/telemetry; approximate under
/// concurrency).
pub fn shared_stats() -> (u64, u64) {
    match shared().lock() {
        Ok(pool) => pool.stats(),
        Err(_) => (0, 0),
    }
}

/// Pre-touch the shared pool (and the drop placeholder) so their own
/// spines are allocated before an allocation-counting region starts.
pub fn warm() {
    let _ = empty();
    let a = take(1);
    recycle(a);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_reuses_the_same_block() {
        let mut arena = ScratchArena::new(1024);
        let a = arena.take(16);
        let ptr = a.data.as_ptr();
        arena.put(a);
        let b = arena.take(16);
        assert_eq!(b.data.as_ptr(), ptr, "same block must come back");
        let (hits, misses) = arena.stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn size_classes_do_not_mix() {
        let mut arena = ScratchArena::new(1024);
        let a = arena.take(16);
        arena.put(a);
        let b = arena.take(32);
        assert_eq!(b.data.len(), 32);
        let (hits, misses) = arena.stats();
        assert_eq!((hits, misses), (0, 2));
    }

    #[test]
    fn cap_bounds_retention() {
        let mut arena = ScratchArena::new(8);
        arena.put(Arc::new(Storage { data: vec![0.0; 6], recyclable: true }));
        // 6 + 6 > 8: the second block is dropped, not parked.
        arena.put(Arc::new(Storage { data: vec![0.0; 6], recyclable: true }));
        let a = arena.take(6);
        let b = arena.take(6);
        let (hits, misses) = arena.stats();
        assert_eq!((hits, misses), (1, 1));
        drop((a, b));
    }

    #[test]
    fn shared_pool_round_trip() {
        warm();
        let st = take_zeroed(8);
        assert!(st.data.iter().all(|&x| x == 0.0));
        recycle(st);
    }
}
