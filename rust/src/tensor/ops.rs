//! The few host-side elementwise operations the coordinator performs.
//! Everything heavier runs inside the AOT-compiled XLA executables.

use super::Tensor;

/// y += x (elementwise, equal shapes). Residual adds on the AW hot path.
pub fn add_assign(y: &mut Tensor, x: &Tensor) {
    assert_eq!(y.shape(), x.shape(), "add_assign shape mismatch");
    for (a, b) in y.data_mut().iter_mut().zip(x.data()) {
        *a += b;
    }
}

/// y += w * x over a single row slice. MoE gate-weighted accumulation:
/// the AW combines expert outputs as `h += gate_e * expert_e(g)`.
pub fn axpy_row(y: &mut [f32], w: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len(), "axpy_row length mismatch");
    for (a, b) in y.iter_mut().zip(x) {
        *a += w * b;
    }
}

/// Argmax over a row (greedy sampling); ties resolve to the lowest index,
/// matching `jnp.argmax` so Rust generation equals the python oracle.
pub fn argmax(row: &[f32]) -> usize {
    assert!(!row.is_empty());
    let mut best = 0;
    let mut best_v = row[0];
    for (i, &v) in row.iter().enumerate().skip(1) {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

/// Top-k indices and values, descending by value; ties resolve to the
/// lowest index (stable, matching `jax.lax.top_k`). k <= row.len().
pub fn top_k(row: &[f32], k: usize) -> Vec<(usize, f32)> {
    assert!(k <= row.len());
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| {
        row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.into_iter().take(k).map(|i| (i, row[i])).collect()
}

/// Renormalize top-k gate values to sum to 1 (the Mixtral convention used
/// by the L2 oracle's `_moe_block`).
pub fn renormalize(gates: &mut [(usize, f32)]) {
    let sum: f32 = gates.iter().map(|(_, v)| v).sum();
    if sum > 0.0 {
        for (_, v) in gates.iter_mut() {
            *v /= sum;
        }
    }
}

/// Maximum absolute difference between two equal-length slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_assign_works() {
        let mut y = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let x = Tensor::new(vec![2, 2], vec![10., 20., 30., 40.]);
        add_assign(&mut y, &x);
        assert_eq!(y.data(), &[11., 22., 33., 44.]);
    }

    #[test]
    fn axpy() {
        let mut y = vec![1.0, 1.0];
        axpy_row(&mut y, 0.5, &[2.0, 4.0]);
        assert_eq!(y, vec![2.0, 3.0]);
    }

    #[test]
    fn argmax_ties_to_lowest_index() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn top_k_ordering_and_ties() {
        let row = [0.1, 0.4, 0.4, 0.05, 0.05];
        let top = top_k(&row, 2);
        assert_eq!(top[0].0, 1); // tie between 1 and 2 -> lowest index first
        assert_eq!(top[1].0, 2);
    }

    #[test]
    fn renormalize_sums_to_one() {
        let mut g = vec![(0usize, 0.3f32), (5, 0.1)];
        renormalize(&mut g);
        let sum: f32 = g.iter().map(|(_, v)| v).sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!((g[0].1 - 0.75).abs() < 1e-6);
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
    }
}
