//! Host-side f32 tensors: the coordinator's currency for token embeddings,
//! KV segments, and expert outputs. Deliberately simple — real math happens
//! in the AOT-compiled XLA executables; this type only carries data,
//! assembles batches, and applies the few elementwise combines the MoE
//! aggregation needs (residual adds, gate-weighted sums).

pub mod ops;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn nbytes(&self) -> usize {
        self.data.len() * 4
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Number of rows when viewed as [rows, row_len].
    pub fn rows(&self) -> usize {
        assert!(!self.shape.is_empty());
        self.shape[0]
    }

    /// Elements per leading row.
    pub fn row_len(&self) -> usize {
        assert!(!self.shape.is_empty());
        self.shape[1..].iter().product()
    }

    /// Borrow row `i` (viewing the tensor as [rows, row_len]).
    pub fn row(&self, i: usize) -> &[f32] {
        let rl = self.row_len();
        &self.data[i * rl..(i + 1) * rl]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let rl = self.row_len();
        &mut self.data[i * rl..(i + 1) * rl]
    }

    /// Copy row `i` out as an owned [1, row_len...] tensor.
    pub fn row_tensor(&self, i: usize) -> Tensor {
        let mut shape = self.shape.clone();
        shape[0] = 1;
        Tensor::new(shape, self.row(i).to_vec())
    }

    /// Stack rows (each [row_len]) into [rows.len(), row_len].
    pub fn from_rows(rows: &[&[f32]]) -> Tensor {
        assert!(!rows.is_empty());
        let rl = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * rl);
        for r in rows {
            assert_eq!(r.len(), rl, "ragged rows");
            data.extend_from_slice(r);
        }
        Tensor::new(vec![rows.len(), rl], data)
    }

    /// Take the first `n` leading rows as an owned tensor (un-padding).
    pub fn take_rows(&self, n: usize) -> Tensor {
        assert!(n <= self.rows());
        let rl = self.row_len();
        let mut shape = self.shape.clone();
        shape[0] = n;
        Tensor::new(shape, self.data[..n * rl].to_vec())
    }

    /// Pad with zero rows up to `n` leading rows (bucketing).
    pub fn pad_rows(&self, n: usize) -> Tensor {
        assert!(n >= self.rows());
        let rl = self.row_len();
        let mut data = self.data.clone();
        data.resize(n * rl, 0.0);
        let mut shape = self.shape.clone();
        shape[0] = n;
        Tensor::new(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_rows() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.row_len(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn pad_and_take_roundtrip() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let padded = t.pad_rows(4);
        assert_eq!(padded.shape(), &[4, 2]);
        assert_eq!(&padded.data()[4..], &[0.0; 4]);
        assert_eq!(padded.take_rows(2), t);
    }

    #[test]
    fn from_rows_stacks() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let t = Tensor::from_rows(&[&a, &b]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn multi_dim_rows() {
        // [T, kv, d] KV tensor: row() returns one token's segment.
        let t = Tensor::new(vec![2, 1, 4], (0..8).map(|x| x as f32).collect());
        assert_eq!(t.row_len(), 4);
        assert_eq!(t.row(1), &[4., 5., 6., 7.]);
        let r = t.row_tensor(1);
        assert_eq!(r.shape(), &[1, 1, 4]);
    }
}
