//! Host-side f32 tensors: the coordinator's currency for token embeddings,
//! KV segments, and expert outputs. Deliberately simple — real math happens
//! in the AOT-compiled XLA executables; this type only carries data,
//! assembles batches, and applies the few elementwise combines the MoE
//! aggregation needs (residual adds, gate-weighted sums).
//!
//! Storage is reference-counted with an (offset, len) window, so row
//! slicing ([`Tensor::row_tensor`], [`Tensor::view_rows`]) and `clone()`
//! never copy floats: a dispatch entry's token rows, an EW return's
//! output rows, and a device reply all share one allocation end to end
//! (DESIGN.md §10). Mutation goes through [`Tensor::data_mut`], which is
//! in-place on uniquely-owned storage and copy-on-write otherwise, so
//! shared views keep value semantics. Dropped storage is recycled
//! through the [`scratch`] arena: a warm steady-state decode step
//! performs zero heap allocations on the tensor path.

pub mod ops;
pub mod scratch;

use scratch::Storage;
use std::sync::Arc;

/// Maximum tensor rank (largest shape in the system is [B, S, kv, d]).
pub const MAX_RANK: usize = 4;

/// Inline shape (no heap allocation per tensor/view).
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct ShapeDims {
    dims: [usize; MAX_RANK],
    rank: u8,
}

impl ShapeDims {
    pub fn from_slice(s: &[usize]) -> ShapeDims {
        assert!(s.len() <= MAX_RANK, "tensor rank {} exceeds {MAX_RANK}", s.len());
        let mut dims = [0usize; MAX_RANK];
        dims[..s.len()].copy_from_slice(s);
        ShapeDims { dims, rank: s.len() as u8 }
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.dims[..self.rank as usize]
    }

    /// Element count (1 for rank 0).
    pub fn numel(&self) -> usize {
        self.as_slice().iter().product()
    }
}

impl std::fmt::Debug for ShapeDims {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl From<Vec<usize>> for ShapeDims {
    fn from(v: Vec<usize>) -> ShapeDims {
        ShapeDims::from_slice(&v)
    }
}

impl From<&[usize]> for ShapeDims {
    fn from(v: &[usize]) -> ShapeDims {
        ShapeDims::from_slice(v)
    }
}

impl<const N: usize> From<[usize; N]> for ShapeDims {
    fn from(v: [usize; N]) -> ShapeDims {
        ShapeDims::from_slice(&v)
    }
}

/// Dense row-major f32 tensor (possibly a window into shared storage).
#[derive(Clone)]
pub struct Tensor {
    shape: ShapeDims,
    storage: Arc<Storage>,
    /// Window into `storage.data`: elements [offset, offset + len).
    offset: usize,
    len: usize,
}

impl Drop for Tensor {
    fn drop(&mut self) {
        // Last reference to a recyclable storage: park the whole Arc in
        // the scratch arena instead of freeing it (zero-alloc steady
        // state). `strong_count == 1` means no other thread can reach
        // it; *moving* our ref out (a shared placeholder takes its
        // place) keeps that true while the pool holds it — parking a
        // clone would let a racing take() pop a block whose second ref
        // is still being dropped here.
        if self.storage.recyclable && Arc::strong_count(&self.storage) == 1 {
            let st = std::mem::replace(&mut self.storage, scratch::empty());
            scratch::recycle(st);
        }
    }
}

impl std::fmt::Debug for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tensor")
            .field("shape", &self.shape)
            .field("data", &self.data())
            .finish()
    }
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.shape() == other.shape() && self.data() == other.data()
    }
}

impl Tensor {
    pub fn new(shape: impl Into<ShapeDims>, data: Vec<f32>) -> Tensor {
        let shape = shape.into();
        assert_eq!(
            shape.numel(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        let len = data.len();
        Tensor {
            shape,
            storage: Arc::new(Storage { data, recyclable: true }),
            offset: 0,
            len,
        }
    }

    /// Zero-filled tensor from the scratch arena (recycled on drop).
    pub fn zeros(shape: impl Into<ShapeDims>) -> Tensor {
        let shape = shape.into();
        let n = shape.numel();
        Tensor { shape, storage: scratch::take_zeroed(n), offset: 0, len: n }
    }

    /// Tensor with *unspecified* contents from the scratch arena. Hot-path
    /// constructor for kernel outputs that overwrite every element; use
    /// [`Tensor::zeros`] unless the full write is obvious at the call site.
    pub fn uninit(shape: impl Into<ShapeDims>) -> Tensor {
        let shape = shape.into();
        let n = shape.numel();
        Tensor { shape, storage: scratch::take(n), offset: 0, len: n }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor::new([0usize; 0], vec![v])
    }

    pub fn shape(&self) -> &[usize] {
        self.shape.as_slice()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn data(&self) -> &[f32] {
        &self.storage.data[self.offset..self.offset + self.len]
    }

    /// Mutable access: in place when this is the sole owner, copy-on-write
    /// (into fresh scratch-arena storage) when the storage is shared.
    pub fn data_mut(&mut self) -> &mut [f32] {
        if Arc::get_mut(&mut self.storage).is_none() {
            let mut st = scratch::take(self.len);
            Arc::get_mut(&mut st)
                .expect("fresh scratch storage is unique")
                .data
                .copy_from_slice(self.data());
            self.storage = st;
            self.offset = 0;
        }
        let (off, len) = (self.offset, self.len);
        let st = Arc::get_mut(&mut self.storage).expect("unique after copy-on-write");
        &mut st.data[off..off + len]
    }

    /// Extract the underlying buffer; zero-copy when this tensor is the
    /// sole owner of a full (non-view) storage, a copy otherwise.
    pub fn into_data(mut self) -> Vec<f32> {
        if self.offset == 0 && self.len == self.storage.data.len() {
            if let Some(st) = Arc::get_mut(&mut self.storage) {
                return std::mem::take(&mut st.data);
            }
        }
        self.data().to_vec()
    }

    pub fn nbytes(&self) -> usize {
        self.len * 4
    }

    /// True when two tensors share one storage allocation (zero-copy
    /// discipline assertions, DESIGN.md §10).
    pub fn shares_storage(&self, other: &Tensor) -> bool {
        Arc::ptr_eq(&self.storage, &other.storage)
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: impl Into<ShapeDims>) -> Tensor {
        let shape = shape.into();
        assert_eq!(shape.numel(), self.len);
        self.shape = shape;
        self
    }

    /// Number of rows when viewed as [rows, row_len].
    pub fn rows(&self) -> usize {
        assert!(!self.shape().is_empty());
        self.shape()[0]
    }

    /// Elements per leading row.
    pub fn row_len(&self) -> usize {
        assert!(!self.shape().is_empty());
        self.shape()[1..].iter().product()
    }

    /// Borrow row `i` (viewing the tensor as [rows, row_len]).
    pub fn row(&self, i: usize) -> &[f32] {
        let rl = self.row_len();
        &self.data()[i * rl..(i + 1) * rl]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let rl = self.row_len();
        &mut self.data_mut()[i * rl..(i + 1) * rl]
    }

    /// Row `i` as a [1, row_len...] tensor — a zero-copy view sharing
    /// this tensor's storage.
    pub fn row_tensor(&self, i: usize) -> Tensor {
        self.view_rows(i, 1)
    }

    /// Rows [start, start + n) as a zero-copy view.
    pub fn view_rows(&self, start: usize, n: usize) -> Tensor {
        let rl = self.row_len();
        assert!(start + n <= self.rows());
        let mut dims = self.shape;
        dims.dims[0] = n;
        Tensor {
            shape: dims,
            storage: self.storage.clone(),
            offset: self.offset + start * rl,
            len: n * rl,
        }
    }

    /// Stack rows (each [row_len]) into [rows.len(), row_len].
    pub fn from_rows(rows: &[&[f32]]) -> Tensor {
        assert!(!rows.is_empty());
        let rl = rows[0].len();
        let mut t = Tensor::uninit([rows.len(), rl]);
        let data = t.data_mut();
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), rl, "ragged rows");
            data[i * rl..(i + 1) * rl].copy_from_slice(r);
        }
        t
    }

    /// Take the first `n` leading rows (un-padding) — a zero-copy view.
    pub fn take_rows(&self, n: usize) -> Tensor {
        assert!(n <= self.rows());
        self.view_rows(0, n)
    }

    /// Pad with zero rows up to `n` leading rows (bucketing).
    pub fn pad_rows(&self, n: usize) -> Tensor {
        assert!(n >= self.rows());
        let rl = self.row_len();
        let mut dims = self.shape;
        dims.dims[0] = n;
        let mut t = Tensor::zeros(dims);
        t.data_mut()[..self.len].copy_from_slice(self.data());
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_rows() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.row_len(), 3);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn pad_and_take_roundtrip() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let padded = t.pad_rows(4);
        assert_eq!(padded.shape(), &[4, 2]);
        assert_eq!(&padded.data()[4..], &[0.0; 4]);
        assert_eq!(padded.take_rows(2), t);
    }

    #[test]
    fn from_rows_stacks() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let t = Tensor::from_rows(&[&a, &b]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn multi_dim_rows() {
        // [T, kv, d] KV tensor: row() returns one token's segment.
        let t = Tensor::new(vec![2, 1, 4], (0..8).map(|x| x as f32).collect());
        assert_eq!(t.row_len(), 4);
        assert_eq!(t.row(1), &[4., 5., 6., 7.]);
        let r = t.row_tensor(1);
        assert_eq!(r.shape(), &[1, 1, 4]);
    }

    #[test]
    fn row_views_share_storage_and_cow_on_write() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let mut v = t.row_tensor(1);
        assert!(v.shares_storage(&t), "row view must not copy");
        assert_eq!(v.data(), &[3., 4.]);
        // Mutating the shared view copies, leaving the parent intact.
        v.data_mut()[0] = 9.0;
        assert!(!v.shares_storage(&t));
        assert_eq!(t.row(1), &[3., 4.]);
        assert_eq!(v.data(), &[9., 4.]);
    }

    #[test]
    fn clone_is_shallow_until_mutated() {
        let a = Tensor::new(vec![3], vec![1., 2., 3.]);
        let mut b = a.clone();
        assert!(b.shares_storage(&a));
        b.data_mut()[2] = 7.0;
        assert_eq!(a.data(), &[1., 2., 3.]);
        assert_eq!(b.data(), &[1., 2., 7.]);
    }

    #[test]
    fn unique_mutation_is_in_place() {
        let mut a = Tensor::zeros(vec![4]);
        let p = a.data().as_ptr();
        a.data_mut()[1] = 5.0;
        assert_eq!(a.data().as_ptr(), p, "sole owner must mutate in place");
        assert_eq!(a.data(), &[0., 5., 0., 0.]);
    }

    #[test]
    fn into_data_steals_unique_full_storage() {
        let a = Tensor::new(vec![2], vec![8., 9.]);
        assert_eq!(a.into_data(), vec![8., 9.]);
        // Views copy.
        let b = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        assert_eq!(b.row_tensor(0).into_data(), vec![1., 2.]);
        assert_eq!(b.data(), &[1., 2., 3., 4.]);
    }

    #[test]
    fn scratch_recycling_round_trip() {
        scratch::warm();
        // Unusual size: no other (parallel) test touches this class.
        let a = Tensor::zeros(vec![1237]);
        let p = a.data().as_ptr();
        drop(a);
        let b = Tensor::zeros(vec![1237]);
        assert_eq!(b.data().as_ptr(), p, "storage must be recycled by size");
        assert!(b.data().iter().all(|&x| x == 0.0), "recycled zeros stay zero");
    }
}
