//! Shared experiment machinery: the serving runner (any system, any
//! workload, optional fault injection) and CSV/result-file helpers.

use crate::baselines::megascale;
use crate::baselines::vllm::{VllmEngine, VllmKind, VllmOptions};
use crate::config::{Config, ResilienceConfig, WorkloadConfig, WorkloadKind};
use crate::coordinator::cluster::{Cluster, LaunchOptions};
use crate::coordinator::orchestrator::RecoveryMode;
use crate::metrics::RunAnalysis;
use crate::modelcfg::{weights::Weights, Manifest};
use crate::transport::link::{LinkStats, TrafficClass, TrafficEvent};
use crate::transport::NodeId;
use crate::workload::{self, Limits};
use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    Tarragon,
    Megascale,
    VllmTp,
    VllmPp,
}

impl SystemKind {
    pub fn name(self) -> &'static str {
        match self {
            SystemKind::Tarragon => "tarragon",
            SystemKind::Megascale => "megascale",
            SystemKind::VllmTp => "vllm-tp",
            SystemKind::VllmPp => "vllm-pp",
        }
    }

    pub fn parse(s: &str) -> Option<SystemKind> {
        Some(match s {
            "tarragon" => SystemKind::Tarragon,
            "megascale" => SystemKind::Megascale,
            "vllm-tp" => SystemKind::VllmTp,
            "vllm-pp" => SystemKind::VllmPp,
            _ => return None,
        })
    }
}

#[derive(Debug, Clone, Copy)]
pub enum FailureSpec {
    KillAw { at_secs: f64, idx: u32 },
    KillEw { at_secs: f64, idx: u32 },
}

#[derive(Clone)]
pub struct ServeSpec {
    pub system: SystemKind,
    pub wl_kind: WorkloadKind,
    pub rps: f64,
    pub duration_secs: f64,
    pub seed: u64,
    pub num_aws: usize,
    pub num_ews: usize,
    /// Override resilience (ablations); None = system default.
    pub resilience: Option<ResilienceConfig>,
    pub failure: Option<FailureSpec>,
    pub record_traffic: bool,
    pub drain_timeout: Duration,
    /// Fast worker bring-up for steady-state experiments (failure-free
    /// runs don't need the full simulated cold-start cost).
    pub fast_init: bool,
    /// Fraction of requests stamped with the shared system-prompt prefix
    /// (the prefix-caching workload axis); 0.0 = legacy stream.
    pub shared_prefix_ratio: f64,
}

impl ServeSpec {
    pub fn new(system: SystemKind, wl: WorkloadKind, rps: f64, duration: f64) -> ServeSpec {
        ServeSpec {
            system,
            wl_kind: wl,
            rps,
            duration_secs: duration,
            seed: 7,
            num_aws: 4,
            num_ews: 4,
            resilience: None,
            failure: None,
            record_traffic: false,
            drain_timeout: Duration::from_secs(120),
            fast_init: true,
            shared_prefix_ratio: 0.0,
        }
    }
}

pub struct ServeOutcome {
    pub analysis: RunAnalysis,
    pub submitted: usize,
    pub finished: usize,
    pub restarts: u64,
    pub aw_failures: u64,
    pub ew_failures: u64,
    /// Per-AW egress traffic recordings (if requested).
    pub traffic: Vec<(u32, Vec<TrafficEvent>)>,
    /// Per-AW egress link stats.
    pub link_stats: Vec<(u32, LinkStats)>,
}

pub fn artifacts() -> (Arc<Manifest>, Weights) {
    let dir = Manifest::default_dir();
    let manifest = Arc::new(
        Manifest::load(&dir).expect("artifacts not built — run `make artifacts` first"),
    );
    let weights = Weights::load(&manifest).expect("weights.bin");
    (manifest, weights)
}

/// Run one serving experiment to completion and collect the outcome.
pub fn run_serving(spec: &ServeSpec) -> ServeOutcome {
    let (manifest, weights) = artifacts();
    let wl = WorkloadConfig {
        kind: spec.wl_kind,
        rate_rps: spec.rps,
        num_requests: 0,
        duration_secs: spec.duration_secs,
        seed: spec.seed,
        hotspot_expert: None,
        shared_prefix_ratio: spec.shared_prefix_ratio,
    };
    let limits = Limits::from_model(&manifest.model, &manifest.buckets);
    let schedule = workload::generate(&wl, limits);

    match spec.system {
        SystemKind::VllmTp | SystemKind::VllmPp => {
            let kind = if spec.system == SystemKind::VllmTp { VllmKind::Tp } else { VllmKind::Pp };
            let report = VllmEngine::run(
                manifest,
                weights,
                schedule,
                VllmOptions {
                    kind,
                    worker_extra_init: if spec.fast_init {
                        Duration::from_millis(10)
                    } else {
                        Duration::from_millis(500)
                    },
                    drain_timeout: spec.drain_timeout,
                    ..Default::default()
                },
            );
            ServeOutcome {
                analysis: report.analysis,
                submitted: report.submitted,
                finished: report.finished,
                restarts: 0,
                aw_failures: 0,
                ew_failures: 0,
                traffic: Vec::new(),
                link_stats: Vec::new(),
            }
        }
        SystemKind::Tarragon | SystemKind::Megascale => {
            let mut cfg = Config::default();
            cfg.cluster.num_aws = spec.num_aws;
            cfg.cluster.num_ews = spec.num_ews;
            cfg.workload = wl;
            if spec.fast_init {
                cfg.transport.worker_extra_init = Duration::from_millis(10);
            }
            let mut opts = LaunchOptions {
                drain_timeout: spec.drain_timeout,
                record_traffic: spec.record_traffic,
                ..Default::default()
            };
            if spec.system == SystemKind::Megascale {
                cfg = megascale::megascale_config(cfg);
                opts.mode = RecoveryMode::CoarseRestart;
            }
            if let Some(res) = &spec.resilience {
                cfg.resilience = res.clone();
            }
            let cluster = Cluster::launch(cfg, manifest, weights, schedule, opts);
            if let Some(f) = spec.failure {
                let (at, action): (f64, Box<dyn FnOnce() + Send>) = match f {
                    FailureSpec::KillAw { at_secs, idx } => {
                        let c = cluster.spawner.clone();
                        (at_secs, Box::new(move || c.kill(NodeId::Aw(idx))))
                    }
                    FailureSpec::KillEw { at_secs, idx } => {
                        let c = cluster.spawner.clone();
                        (at_secs, Box::new(move || c.kill(NodeId::Ew(idx))))
                    }
                };
                std::thread::spawn(move || {
                    std::thread::sleep(Duration::from_secs_f64(at));
                    action();
                });
            }
            let budget = Duration::from_secs_f64(spec.duration_secs)
                + spec.drain_timeout
                + Duration::from_secs(60);
            cluster.wait_done(budget);
            let traffic: Vec<(u32, Vec<TrafficEvent>)> = if spec.record_traffic {
                cluster
                    .initial_aws
                    .iter()
                    .filter_map(|&i| {
                        cluster
                            .fabric
                            .egress_of(NodeId::Aw(i))
                            .map(|l| (i, l.take_recording()))
                    })
                    .collect()
            } else {
                Vec::new()
            };
            let link_stats: Vec<(u32, LinkStats)> = cluster
                .initial_aws
                .iter()
                .filter_map(|&i| {
                    cluster.fabric.egress_of(NodeId::Aw(i)).map(|l| (i, l.stats()))
                })
                .collect();
            let report = cluster.finish(0.25);
            ServeOutcome {
                analysis: report.analysis,
                submitted: report.submitted,
                finished: report.finished,
                restarts: report.restarts,
                aw_failures: report.aw_failures,
                ew_failures: report.ew_failures,
                traffic,
                link_stats,
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Result files
// ---------------------------------------------------------------------------

pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("results");
    std::fs::create_dir_all(&dir).expect("create results/");
    dir
}

pub fn write_csv(name: &str, header: &str, rows: &[String]) -> PathBuf {
    let path = results_dir().join(name);
    let mut f = std::fs::File::create(&path).expect("create csv");
    writeln!(f, "{header}").unwrap();
    for r in rows {
        writeln!(f, "{r}").unwrap();
    }
    println!("  wrote {}", path.display());
    path
}

/// Traffic class short label for CSV.
pub fn class_label(c: TrafficClass) -> &'static str {
    c.name()
}
