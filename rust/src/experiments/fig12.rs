//! Fig. 12: impact of restoration strategy at varying failure points.
//!
//! Compares, for an AW failure while decoding token `i`:
//! - *sequential replay*: rebuild the KV cache by re-running prefill and
//!   then decoding token-by-token up to `i` (no checkpoints);
//! - *parallel replay*: one prefill over prompt+generated tokens;
//! - *TARRAGON*: per-request restoration from the checkpoint store (§6.2).
//!
//! Metrics per strategy: restoration time, bytes moved (AW-EW traffic for
//! the replays, store→AW traffic for TARRAGON), and GPU recomputation
//! (device busy time). The replays execute for real on a monolithic
//! device (their AW-EW traffic volume follows the dispatch wire format);
//! TARRAGON's numbers come from a live cluster run with a real kill.

use crate::baselines::common as bcommon;
use crate::config::{Config, WorkloadKind};
use crate::coordinator::cluster::{Cluster, LaunchOptions};
use crate::experiments::common::{artifacts, write_csv};
use crate::kvcache::{KvPool, RequestKv};
use crate::modelcfg::Buckets;
use crate::proto::HDR_BYTES;
use crate::runtime::{Device, DeviceRole};
use crate::tensor::Tensor;
use crate::transport::link::TrafficClass;
use crate::transport::NodeId;
use crate::workload::Request;
use std::time::{Duration, Instant};

pub fn run(failure_points: &[usize]) {
    println!("Fig 12: restoration strategies vs failure point");
    let (manifest, weights) = artifacts();
    let m = manifest.model.clone();
    let pool = KvPool::for_model(&m);
    let prompt: Vec<u32> = (1..=8).collect();

    // Replay executor (one device, plays the role of the alternate AW).
    let device = Device::spawn(
        "fig12-replay",
        manifest.clone(),
        weights.clone(),
        DeviceRole::Monolithic.plan(&manifest),
        Duration::ZERO,
    )
    .expect("replay device");

    let mut rows = Vec::new();
    for &i in failure_points {
        // ---------------- sequential replay ----------------
        let busy0 = device.stats().unwrap().total_busy();
        let t0 = Instant::now();
        let mut kv = RequestKv::new(&m, &pool);
        let bucket = Buckets::fit(&manifest.buckets.prefill_t, prompt.len()).unwrap();
        let mut x = embed(&weights, m.hidden, &prompt, bucket);
        for layer in 0..m.layers {
            x = bcommon::local_prefill_layer(&device, &manifest, &mut kv, layer, &x, bucket, prompt.len())
                .unwrap();
        }
        kv.set_len(prompt.len());
        let mut asm = crate::kvcache::BatchAssembler::new(&m);
        let mut tok = 1u32;
        for _ in 0..i {
            let xd = embed(&weights, m.hidden, &[tok], 1);
            let mut out = xd.clone();
            for layer in 0..m.layers {
                let mut kvs = vec![&mut kv];
                out = bcommon::local_decode_layer(
                    &device, &manifest, &mut asm, &mut kvs, layer, &out, 1, 1,
                )
                .unwrap();
            }
            let len = kv.len() + 1;
            kv.set_len(len);
            tok = bcommon::lm_head_tokens(&device, &manifest, &[out.row(0)]).unwrap()[0];
        }
        let seq_time = t0.elapsed();
        let seq_busy = device.stats().unwrap().total_busy() - busy0;
        let seq_bytes = replay_traffic_bytes(&m, prompt.len(), i);

        // ---------------- parallel replay ----------------
        let total = prompt.len() + i;
        let (par_time, par_busy, par_ok) =
            if let Some(bucket) = Buckets::fit(&manifest.buckets.prefill_t, total) {
                let busy0 = device.stats().unwrap().total_busy();
                let t0 = Instant::now();
                let mut kv2 = RequestKv::new(&m, &pool);
                // prompt + i generated tokens (ids don't affect cost)
                let mut ids = prompt.clone();
                ids.extend((0..i as u32).map(|k| (k % 100) + 1));
                let mut x = embed(&weights, m.hidden, &ids, bucket);
                for layer in 0..m.layers {
                    x = bcommon::local_prefill_layer(
                        &device, &manifest, &mut kv2, layer, &x, bucket, total,
                    )
                    .unwrap();
                }
                kv2.set_len(total);
                (t0.elapsed(), device.stats().unwrap().total_busy() - busy0, true)
            } else {
                (Duration::ZERO, Duration::ZERO, false)
            };
        let par_bytes = seq_bytes; // paper: same AW-EW traffic as sequential

        // ---------------- TARRAGON restoration ----------------
        let (tar_time, tar_bytes) = tarragon_restore(&manifest, &weights, &prompt, i);
        let tar_busy = Duration::ZERO; // no replayed prefill/decode work

        println!(
            "  i={i:<4} seq: {:>8.1} ms / {:>8} B / {:>7.1} ms GPU | par: {:>7.1} ms / {:>6.1} ms GPU | tarragon: {:>6.1} ms / {:>7} B / ~0 GPU",
            seq_time.as_secs_f64() * 1e3,
            seq_bytes,
            seq_busy.as_secs_f64() * 1e3,
            if par_ok { par_time.as_secs_f64() * 1e3 } else { f64::NAN },
            par_busy.as_secs_f64() * 1e3,
            tar_time.as_secs_f64() * 1e3,
            tar_bytes,
        );
        rows.push(format!(
            "{i},sequential,{:.3},{seq_bytes},{:.3}",
            seq_time.as_secs_f64() * 1e3,
            seq_busy.as_secs_f64() * 1e3
        ));
        if par_ok {
            rows.push(format!(
                "{i},parallel,{:.3},{par_bytes},{:.3}",
                par_time.as_secs_f64() * 1e3,
                par_busy.as_secs_f64() * 1e3
            ));
        }
        rows.push(format!(
            "{i},tarragon,{:.3},{tar_bytes},{:.3}",
            tar_time.as_secs_f64() * 1e3,
            tar_busy.as_secs_f64() * 1e3
        ));
    }
    write_csv("fig12.csv", "failure_point,strategy,restore_ms,bytes,gpu_ms", &rows);
    device.shutdown();
}

/// AW-EW dispatch+return volume of replaying `p` prefill tokens and `i`
/// decode tokens (the wire format's actual sizes).
fn replay_traffic_bytes(m: &crate::modelcfg::ModelSpec, p: usize, i: usize) -> u64 {
    let per_row = 2 * m.hidden * 4 + 2 * 4 + HDR_BYTES / 4; // rows + slots + header share
    let rows = (p + i) * m.top_k * m.layers;
    (rows * per_row) as u64
}

fn embed(weights: &crate::modelcfg::weights::Weights, hidden: usize, ids: &[u32], bucket: usize) -> Tensor {
    let mut x = Tensor::zeros(vec![bucket, hidden]);
    for (i, &t) in ids.iter().enumerate() {
        x.row_mut(i).copy_from_slice(weights.embed_row(t as usize));
    }
    x
}

/// Live-cluster measurement: decode until token `i`, kill the owning AW,
/// measure (a) the token-stream gap (restoration latency as the user sees
/// it) and (b) the store's restore bytes.
fn tarragon_restore(
    manifest: &std::sync::Arc<crate::modelcfg::Manifest>,
    weights: &crate::modelcfg::weights::Weights,
    prompt: &[u32],
    i: usize,
) -> (Duration, u64) {
    let mut cfg = Config::default();
    cfg.cluster.num_aws = 2;
    cfg.cluster.num_ews = 2;
    cfg.transport.worker_extra_init = Duration::from_millis(10);
    let schedule = vec![Request {
        id: 0,
        arrival_s: 0.0,
        prompt: prompt.to_vec(),
        max_new_tokens: (i + 24).min(140),
    }];
    let cluster = Cluster::launch(
        cfg,
        manifest.clone(),
        weights.clone(),
        schedule,
        LaunchOptions::default(),
    );
    // Wait until the i-th token was emitted, then kill the owning AW (aw0
    // serves request 0 under round-robin).
    let deadline = Instant::now() + Duration::from_secs(120);
    while cluster.gw.generated_of(0).map_or(0, |g| g.len()) < i && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    cluster.kill_aw(0);
    cluster.wait_done(Duration::from_secs(180));
    let restore_bytes = cluster
        .fabric
        .egress_of(NodeId::Store(0))
        .map(|l| l.stats().bytes_of(TrafficClass::Restore))
        .unwrap_or(0);
    let report = cluster.finish(0.25);
    (
        Duration::from_secs_f64(report.analysis.max_token_gap_s),
        restore_bytes,
    )
}
