//! Fig. 10 + Fig. 11: the cost of failure resiliency when nothing fails.
//! Sweeps load (RPS) across the four systems and both workloads,
//! reporting TTFT (median/P95), TBT (median/P95), and output-token
//! throughput. One run per (system, workload, rate); fig11 shares the
//! same runs.

use crate::config::WorkloadKind;
use crate::experiments::common::{run_serving, write_csv, ServeSpec, SystemKind};

pub fn run(rates: &[f64], duration: f64, systems: &[SystemKind]) {
    println!("Fig 10/11: latency & throughput vs load ({duration}s per point)");
    let mut rows = Vec::new();
    for &wl in &[WorkloadKind::ShareGpt, WorkloadKind::Random] {
        let wl_name = match wl {
            WorkloadKind::ShareGpt => "sharegpt",
            WorkloadKind::Random => "random",
        };
        for &system in systems {
            for &rps in rates {
                let spec = ServeSpec::new(system, wl, rps, duration);
                let out = run_serving(&spec);
                let a = &out.analysis;
                let ttft = a.ttft();
                let tbt = a.tbt();
                println!(
                    "  {wl_name:<8} {:<9} {rps:>5.1} rps | TTFT med {:>8.1} p95 {:>8.1} ms | \
                     TBT med {:>7.1} p95 {:>7.1} ms | {:>6.0} tok/s | fin {}/{}",
                    system.name(),
                    ttft.median_ms,
                    ttft.p95_ms,
                    tbt.median_ms,
                    tbt.p95_ms,
                    a.throughput_tps,
                    out.finished,
                    out.submitted
                );
                rows.push(format!(
                    "{wl_name},{},{rps},{:.2},{:.2},{:.2},{:.2},{:.1},{},{}",
                    system.name(),
                    ttft.median_ms,
                    ttft.p95_ms,
                    tbt.median_ms,
                    tbt.p95_ms,
                    a.throughput_tps,
                    out.finished,
                    out.submitted
                ));
            }
        }
    }
    write_csv(
        "fig10_fig11.csv",
        "workload,system,rps,ttft_med_ms,ttft_p95_ms,tbt_med_ms,tbt_p95_ms,tokens_per_s,finished,submitted",
        &rows,
    );
}
