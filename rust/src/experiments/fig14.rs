//! Fig. 14 (Appendix D): shadow experts are free while inactive.
//! Three conditions on one EW device:
//!   1. "Single Expert"      — only the primary expert resident, serving.
//!   2. "Shadow Expt Loaded" — a shadow expert's weights resident but
//!                             *idle*; primary latency must be unchanged.
//!   3. "Concurrent Exec"    — both experts actively executing; per-call
//!                             completion latency inflates (kernel-level
//!                             interference; on our serial device model the
//!                             two streams time-share exactly like MPS
//!                             contention).

use crate::experiments::common::{artifacts, write_csv};
use crate::runtime::{roles, ArgValue, Device, DeviceRole};
use crate::tensor::Tensor;
use std::time::{Duration, Instant};

fn expert_args(x: &Tensor, expert: usize) -> Vec<ArgValue> {
    vec![
        ArgValue::f32(x.clone()),
        ArgValue::weight(format!("layer0.expert{expert}.w1")),
        ArgValue::weight(format!("layer0.expert{expert}.w3")),
        ArgValue::weight(format!("layer0.expert{expert}.w2")),
    ]
}

pub fn run(batch: usize, reps: usize) {
    let (manifest, weights) = artifacts();
    let m = manifest.model.clone();
    let b = crate::modelcfg::Buckets::fit(&manifest.buckets.expert_b, batch)
        .unwrap_or(*manifest.buckets.expert_b.last().unwrap());
    println!("Fig 14: shadow-expert interference (batch {b}, {reps} reps)");

    let device = Device::spawn(
        "fig14",
        manifest.clone(),
        weights,
        DeviceRole::Expert { experts: vec![0] }.plan(&manifest),
        Duration::ZERO,
    )
    .expect("device");
    let x = Tensor::zeros(vec![b, m.hidden]);
    let name = format!("expert_b{b}");

    let measure = |label: &str| -> f64 {
        let _ = device.execute(&name, expert_args(&x, 0));
        let t0 = Instant::now();
        for _ in 0..reps {
            device.execute(&name, expert_args(&x, 0)).expect("exec");
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!("  {label:<22} {:.4} ms/exec", per * 1e3);
        per
    };

    // 1. single expert
    let single = measure("Single Expert");

    // 2. shadow loaded but idle
    let shadow_w = roles::expert_weights(&manifest, 1);
    let upload = device.upload_weights(&shadow_w).expect("shadow upload");
    println!("  (shadow weights uploaded in {:.1} ms — the cold-load cost shadows avoid)",
             upload.as_secs_f64() * 1e3);
    let loaded = measure("Shadow Expt Loaded");

    // 3. concurrent execution of primary + shadow
    let dev2 = device.clone();
    let x2 = x.clone();
    let name2 = name.clone();
    let reps2 = reps;
    let t0 = Instant::now();
    let h = std::thread::spawn(move || {
        for _ in 0..reps2 {
            dev2.execute(&name2, expert_args(&x2, 1)).expect("exec");
        }
    });
    for _ in 0..reps {
        device.execute(&name, expert_args(&x, 0)).expect("exec");
    }
    h.join().unwrap();
    let concurrent = t0.elapsed().as_secs_f64() / reps as f64;
    println!("  {:<22} {:.4} ms/exec (both streams active)", "Concurrent Exec", concurrent * 1e3);

    let rows = vec![
        format!("single,{:.6}", single * 1e3),
        format!("shadow_loaded,{:.6}", loaded * 1e3),
        format!("concurrent,{:.6}", concurrent * 1e3),
    ];
    write_csv("fig14.csv", "condition,latency_ms", &rows);
    println!(
        "  shadow-idle overhead: {:+.1}%   concurrent interference: {:.2}x",
        (loaded / single - 1.0) * 100.0,
        concurrent / single
    );
    device.shutdown();
}
