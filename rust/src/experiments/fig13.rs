//! Fig. 13 (Appendix B): (a) per-expert batch-size distribution when a
//! large total batch is split by top-k gating; (b) single-expert latency
//! vs batch size — the "knee" that motivates layer-wise batching and the
//! min-batch threshold of §5.2.

use crate::coordinator::router::{self, ExpertGroups};
use crate::experiments::common::{artifacts, write_csv};
use crate::runtime::{ArgValue, Device, DeviceRole};
use crate::tensor::Tensor;
use crate::util::rng::Pcg;
use crate::util::stats;
use std::time::{Duration, Instant};

pub fn run(total_batch: usize) {
    let (manifest, weights) = artifacts();
    let m = manifest.model.clone();
    println!("Fig 13(a): per-expert batch sizes, total batch {total_batch}, top-{}", m.top_k);

    let device = Device::spawn(
        "fig13",
        manifest.clone(),
        weights,
        DeviceRole::Monolithic.plan(&manifest),
        Duration::ZERO,
    )
    .expect("device");

    // (a) route `total_batch` realistic activations through every layer's
    // gate; collect the per-expert batch sizes.
    let mut rng = Pcg::seeded(99);
    let mut sizes: Vec<f64> = Vec::new();
    let mut rows_a = Vec::new();
    let chunk = *manifest.buckets.router_b.last().unwrap();
    for layer in 0..m.layers {
        let mut remaining = total_batch;
        let mut layer_groups: ExpertGroups = ExpertGroups::default();
        while remaining > 0 {
            let n = remaining.min(chunk);
            let mut g = Tensor::zeros(vec![chunk, m.hidden]);
            for i in 0..n {
                for v in g.row_mut(i) {
                    *v = rng.normal() as f32;
                }
            }
            let probs = device
                .execute(
                    &format!("router_b{chunk}"),
                    vec![
                        ArgValue::f32(g),
                        ArgValue::weight(format!("layer{layer}.router")),
                    ],
                )
                .expect("router");
            let routes = router::select_top_k(&probs[0], n, m.top_k);
            for (e, rows) in ExpertGroups::from_routes(&routes).groups {
                layer_groups.groups.entry(e).or_default().extend(rows);
            }
            remaining -= n;
        }
        for (e, rows) in &layer_groups.groups {
            sizes.push(rows.len() as f64);
            rows_a.push(format!("{layer},{e},{}", rows.len()));
        }
    }
    write_csv("fig13a.csv", "layer,expert,batch_size", &rows_a);
    println!(
        "  per-expert batch: mean={:.1} median={:.1} max={:.0} (total {}, experts {})",
        stats::mean(&sizes),
        stats::median(&sizes),
        sizes.iter().cloned().fold(0.0, f64::max),
        total_batch,
        m.experts
    );

    // (b) expert latency vs batch size over the compiled buckets.
    println!("Fig 13(b): expert FFN latency vs batch size");
    let reps = 30;
    let mut rows_b = Vec::new();
    for &b in &manifest.buckets.expert_b {
        let x = Tensor::zeros(vec![b, m.hidden]);
        let args = || {
            vec![
                ArgValue::f32(x.clone()),
                ArgValue::weight("layer0.expert0.w1"),
                ArgValue::weight("layer0.expert0.w3"),
                ArgValue::weight("layer0.expert0.w2"),
            ]
        };
        let _ = device.execute(&format!("expert_b{b}"), args()); // warm
        let t0 = Instant::now();
        for _ in 0..reps {
            device.execute(&format!("expert_b{b}"), args()).expect("expert");
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        let per_token = per / b as f64;
        rows_b.push(format!("{b},{:.6},{:.8}", per * 1e3, per_token * 1e3));
        println!("    B={b:<4} latency={:.3} ms   per-token={:.5} ms", per * 1e3, per_token * 1e3);
    }
    write_csv("fig13b.csv", "batch,latency_ms,per_token_ms", &rows_b);
    device.shutdown();
}
