//! Experiment harnesses: one per table/figure in the paper's evaluation
//! (see DESIGN.md §4 for the index). Each harness regenerates the paper's
//! rows/series, prints a summary, and writes CSV under `results/`.

pub mod ckpt;
pub mod common;
pub mod fig10;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig4;
pub mod fig8;
pub mod fig9;
pub mod table1;

pub use common::{run_serving, ServeOutcome, ServeSpec, SystemKind};
