//! Fig. 9: end-to-end failover behavior — TBT and output-token-throughput
//! timelines around an injected fail-stop worker failure.
//!
//! Scenarios: `megascale` (coarse restart of the whole job), `aw`
//! (TARRAGON attention-worker failure: per-request restoration from the
//! checkpoint store), `ew` (TARRAGON expert-worker failure: shadow-expert
//! failover + background provisioning).

use crate::config::WorkloadKind;
use crate::experiments::common::{
    run_serving, write_csv, FailureSpec, ServeSpec, SystemKind,
};
use std::time::Duration;

pub fn run(scenario: &str, rps: f64, duration: f64, fail_at: f64, provision: bool) {
    println!("Fig 9({scenario}): failover timeline ({rps} RPS, fail at {fail_at}s)");
    let (system, failure) = match scenario {
        "megascale" => (
            SystemKind::Megascale,
            FailureSpec::KillEw { at_secs: fail_at, idx: 0 },
        ),
        "aw" => (SystemKind::Tarragon, FailureSpec::KillAw { at_secs: fail_at, idx: 0 }),
        "ew" => (SystemKind::Tarragon, FailureSpec::KillEw { at_secs: fail_at, idx: 0 }),
        other => {
            eprintln!("unknown scenario '{other}' (megascale|aw|ew)");
            return;
        }
    };
    let mut spec = ServeSpec::new(system, WorkloadKind::Random, rps, duration);
    spec.failure = Some(failure);
    if system == SystemKind::Tarragon && !provision {
        // Single-core testbed caveat (DESIGN.md §3): "background"
        // provisioning contends for the only CPU, so the self-healing
        // stall is measured with provisioning off; capacity stays
        // degraded until the operator re-adds a worker.
        let mut res = crate::config::ResilienceConfig::default();
        res.provisioning = false;
        spec.resilience = Some(res);
    }
    // Failure experiments pay the real worker bring-up cost.
    spec.fast_init = false;
    // The baseline needs a long drain to complete its restart + replay.
    spec.drain_timeout = Duration::from_secs(if system == SystemKind::Megascale { 240 } else { 90 });
    let out = run_serving(&spec);

    let a = &out.analysis;
    let rows: Vec<String> = a
        .throughput_series
        .iter()
        .zip(a.tbt_series.iter().chain(std::iter::repeat(&(0.0, f64::NAN))))
        .map(|((t, tps), (_, tbt))| format!("{t:.2},{tps:.1},{:.2}", if tbt.is_nan() { -1.0 } else { *tbt }))
        .collect();
    write_csv(
        &format!("fig9_{scenario}.csv"),
        "t_s,tokens_per_s,mean_tbt_ms",
        &rows,
    );

    // The stall: longest cluster-wide token gap that starts after the
    // failure injection (event-level precision).
    let (stall, stall_at) = a.max_gap_after(fail_at * 0.95);
    println!(
        "  tokens={} tps={:.0} submitted={} finished={} restarts={}",
        a.total_tokens, a.throughput_tps, out.submitted, out.finished, out.restarts
    );
    println!("  stall: {:.3}s starting at t={:.2}s (paper: megascale ~64s, tarragon 0.3-0.4s)", stall, stall_at);
    let summary = vec![format!(
        "{scenario},{:.4},{:.2},{},{}",
        stall, stall_at, out.restarts, a.total_tokens
    )];
    write_csv(
        &format!("fig9_{scenario}_stall.csv"),
        "scenario,stall_s,stall_at_s,restarts,total_tokens",
        &summary,
    );
}
