//! Table 1: profiled parameters `T_w, t_pre, t_dec, g_pre, g_dec` for the
//! monolithic (vLLM-like) and decoupled (MegaScale-like) deployments on
//! *this* testbed. These parameters feed the Fig. 4 cost-model curves.
//!
//! Method (mirrors the paper's §2.2.2 audit):
//! - `T_w`: wall time of worker (re)initialization — device thread start,
//!   PJRT client creation, artifact compilation, weight upload, plus the
//!   configured container/CUDA-context extra.
//! - `t_pre`: wall time of one prefill *layer* over a 96-token prompt
//!   (attention + gating + experts; decoupled adds one network RTT).
//! - `t_dec`: wall time of one decode layer for a batch-8 step, per
//!   token-step.
//! - `g_pre`/`g_dec`: device busy-time (GPU-time) per layer per token.

use crate::baselines::common as bcommon;
use crate::costmodel::Params;
use crate::experiments::common::{artifacts, results_dir, write_csv};
use crate::kvcache::{BatchAssembler, KvPool, RequestKv};
use crate::runtime::{Device, DeviceRole};
use crate::tensor::Tensor;
use crate::util::json::{num, obj, Json};
use std::time::{Duration, Instant};

pub struct Table1 {
    pub vllm: Params,
    pub megascale: Params,
}

pub fn run(extra_init: Duration) -> Table1 {
    let (manifest, weights) = artifacts();
    let m = manifest.model.clone();
    println!("Table 1: profiling on this testbed (model: {} layers, H={})", m.layers, m.hidden);

    // ---- T_w ---------------------------------------------------------
    let t0 = Instant::now();
    let mono = Device::spawn(
        "prof-mono",
        manifest.clone(),
        weights.clone(),
        DeviceRole::Monolithic.plan(&manifest),
        extra_init,
    )
    .expect("mono device");
    let tw_mono = t0.elapsed();

    let t0 = Instant::now();
    let aw_dev = Device::spawn(
        "prof-aw",
        manifest.clone(),
        weights.clone(),
        DeviceRole::Attention.plan(&manifest),
        extra_init,
    )
    .expect("aw device");
    let tw_aw = t0.elapsed();
    let t0 = Instant::now();
    let ew_dev = Device::spawn(
        "prof-ew",
        manifest.clone(),
        weights.clone(),
        DeviceRole::Expert { experts: (0..m.experts).collect() }.plan(&manifest),
        extra_init,
    )
    .expect("ew device");
    let tw_ew = t0.elapsed();
    // Decoupled T_w: a restart must bring back the failed worker; we report
    // the max of the two roles (the AW dominates).
    let tw_decoupled = tw_aw.max(tw_ew);
    ew_dev.shutdown();

    // ---- per-layer compute on the monolithic device --------------------
    let reps = 20;
    let p_len = 96;
    let bucket = p_len;
    let pool = KvPool::for_model(&m);
    let mut kv = RequestKv::new(&m, &pool);
    let x = Tensor::zeros(vec![bucket, m.hidden]);
    // warmup + measure prefill layer
    let _ = bcommon::local_prefill_layer(&mono, &manifest, &mut kv, 0, &x, bucket, p_len);
    let t0 = Instant::now();
    for _ in 0..reps {
        let _ =
            bcommon::local_prefill_layer(&mono, &manifest, &mut kv, 0, &x, bucket, p_len).unwrap();
    }
    let t_pre_mono = t0.elapsed() / reps;

    // decode layer, batch 8
    let b = 8;
    let mut kvs_store: Vec<RequestKv> = (0..b)
        .map(|_| {
            let mut kv = RequestKv::new(&m, &pool);
            kv.set_len(64);
            kv
        })
        .collect();
    let mut asm = BatchAssembler::new(&m);
    let xd = Tensor::zeros(vec![b, m.hidden]);
    let step = |asm: &mut BatchAssembler, kvs_store: &mut Vec<RequestKv>| {
        let mut kvs: Vec<&mut RequestKv> = kvs_store.iter_mut().collect();
        bcommon::local_decode_layer(&mono, &manifest, asm, &mut kvs, 0, &xd, b, b).unwrap()
    };
    let _ = step(&mut asm, &mut kvs_store);
    let t0 = Instant::now();
    for _ in 0..reps {
        let _ = step(&mut asm, &mut kvs_store);
        for kv in kvs_store.iter_mut() {
            kv.set_len(64); // keep the cache length fixed for comparability
        }
    }
    // per layer per *batched step*; per token-step below for g_dec.
    let t_dec_mono = t0.elapsed() / reps;

    // GPU-time from device counters.
    let stats = mono.stats().unwrap();
    let busy_pre = stats.busy_with_prefix("attn_prefill")
        + stats.busy_with_prefix("router_b96")
        + stats.busy_with_prefix("expert");
    // crude split: expert busy is shared between the two phases; the
    // per-phase attribution uses execution counts.
    let g_pre = busy_pre.as_secs_f64() / ((reps + 1) as f64 * p_len as f64);
    let busy_total = stats.total_busy();
    let g_dec = (busy_total - busy_pre).max(Duration::ZERO).as_secs_f64()
        / ((reps + 1) as f64 * b as f64);
    mono.shutdown();

    // ---- decoupled: add one RTT + EW-side batching to each layer -------
    let cfg = crate::config::Config::default();
    let rtt = 2.0 * cfg.transport.latency.as_secs_f64();
    let dispatch_bytes = (b * m.top_k * m.hidden * 4) as f64;
    let wire = 2.0 * dispatch_bytes / cfg.transport.bandwidth_bps;
    let t_pre_dec = t_pre_mono + Duration::from_secs_f64(rtt + wire * (p_len as f64 / b as f64));
    let t_dec_dec = t_dec_mono + Duration::from_secs_f64(rtt + wire);
    // Decoupled g_* are slightly lower per worker: expert compute is
    // consolidated on EWs (the MegaScale efficiency argument).
    let g_pre_dec = g_pre * 0.8;
    let g_dec_dec = g_dec * 0.85;
    aw_dev.shutdown();

    let vllm = Params {
        t_w: tw_mono,
        t_pre: t_pre_mono,
        t_dec: Duration::from_secs_f64(t_dec_mono.as_secs_f64() / b as f64),
        g_pre,
        g_dec,
    };
    let megascale = Params {
        t_w: tw_decoupled,
        t_pre: t_pre_dec,
        t_dec: Duration::from_secs_f64(t_dec_dec.as_secs_f64() / b as f64),
        g_pre: g_pre_dec,
        g_dec: g_dec_dec,
    };

    print_row("vLLM (monolithic)", &vllm);
    print_row("MegaScale (decoupled)", &megascale);
    println!(
        "  paper:   vLLM T_w=24s t_pre=1.68ms t_dec=0.58ms | MegaScale T_w=18.5s t_pre=2.18ms t_dec=0.85ms"
    );

    let rows = vec![fmt_csv("vllm", &vllm), fmt_csv("megascale", &megascale)];
    write_csv("table1.csv", "deployment,t_w_s,t_pre_ms,t_dec_ms,g_pre,g_dec", &rows);
    save_json(&vllm, &megascale);
    Table1 { vllm, megascale }
}

fn print_row(name: &str, p: &Params) {
    println!(
        "  {name:<24} T_w={:.2}s  t_pre={:.3}ms  t_dec={:.3}ms  g_pre={:.5}  g_dec={:.5}",
        p.t_w.as_secs_f64(),
        p.t_pre.as_secs_f64() * 1e3,
        p.t_dec.as_secs_f64() * 1e3,
        p.g_pre,
        p.g_dec
    );
}

fn fmt_csv(name: &str, p: &Params) -> String {
    format!(
        "{name},{:.4},{:.4},{:.4},{:.6},{:.6}",
        p.t_w.as_secs_f64(),
        p.t_pre.as_secs_f64() * 1e3,
        p.t_dec.as_secs_f64() * 1e3,
        p.g_pre,
        p.g_dec
    )
}

fn save_json(vllm: &Params, mega: &Params) {
    let to_json = |p: &Params| {
        obj(vec![
            ("t_w_s", num(p.t_w.as_secs_f64())),
            ("t_pre_s", num(p.t_pre.as_secs_f64())),
            ("t_dec_s", num(p.t_dec.as_secs_f64())),
            ("g_pre", num(p.g_pre)),
            ("g_dec", num(p.g_dec)),
        ])
    };
    let j = obj(vec![("vllm", to_json(vllm)), ("megascale", to_json(mega))]);
    std::fs::write(results_dir().join("table1.json"), j.to_string()).unwrap();
}

/// Load previously measured parameters (fig4 reuses them).
pub fn load() -> Option<Table1> {
    let text = std::fs::read_to_string(results_dir().join("table1.json")).ok()?;
    let j = Json::parse(&text).ok()?;
    let parse = |k: &str| -> Option<Params> {
        let p = j.get(k)?;
        Some(Params {
            t_w: Duration::from_secs_f64(p.get("t_w_s")?.as_f64()?),
            t_pre: Duration::from_secs_f64(p.get("t_pre_s")?.as_f64()?),
            t_dec: Duration::from_secs_f64(p.get("t_dec_s")?.as_f64()?),
            g_pre: p.get("g_pre")?.as_f64()?,
            g_dec: p.get("g_dec")?.as_f64()?,
        })
    };
    Some(Table1 { vllm: parse("vllm")?, megascale: parse("megascale")? })
}
