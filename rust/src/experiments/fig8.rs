//! Fig. 8: AW-EW traffic is bursty, leaving idle gaps the incremental
//! checkpointing fills. Runs TARRAGON with traffic recording on AW 0's
//! egress link and emits the transfer intervals (class-tagged), plus a
//! binned utilization series showing checkpoint writes landing in the
//! gaps between expert scatter/gather bursts.

use crate::config::WorkloadKind;
use crate::experiments::common::{run_serving, write_csv, ServeSpec, SystemKind};
use crate::transport::link::TrafficClass;
use crate::util::stats::Timeline;

pub fn run(rps: f64, duration: f64) {
    println!("Fig 8: traffic pattern with incremental checkpointing ({rps} RPS, {duration}s)");
    let mut spec = ServeSpec::new(SystemKind::Tarragon, WorkloadKind::Random, rps, duration);
    spec.record_traffic = true;
    let out = run_serving(&spec);

    let Some((aw, events)) = out.traffic.into_iter().next() else {
        println!("  no traffic recorded");
        return;
    };
    println!("  AW{aw}: {} transfers recorded", events.len());

    let rows: Vec<String> = events
        .iter()
        .map(|e| format!("{},{},{},{}", e.start_us, e.end_us, e.bytes, e.class.name()))
        .collect();
    write_csv("fig8_events.csv", "start_us,end_us,bytes,class", &rows);

    // Binned utilization split: expert traffic vs checkpoint traffic.
    let mut expert = Timeline::new(0.01);
    let mut ckpt = Timeline::new(0.01);
    for e in &events {
        let t = e.start_us as f64 / 1e6;
        match e.class {
            TrafficClass::ExpertDispatch | TrafficClass::ExpertReturn => {
                expert.push(t, e.bytes as f64)
            }
            TrafficClass::Checkpoint => ckpt.push(t, e.bytes as f64),
            _ => {}
        }
    }
    let er = expert.rate_series();
    let cr = ckpt.rate_series();
    let rows: Vec<String> = er
        .iter()
        .enumerate()
        .map(|(i, (t, _))| {
            let eb = expert_sum(&expert, i);
            let cb = cr.get(i).map(|_| ckpt_sum(&ckpt, i)).unwrap_or(0.0);
            format!("{t:.2},{eb:.0},{cb:.0}")
        })
        .collect();
    write_csv("fig8_utilization.csv", "t_s,expert_bytes_per_10ms,ckpt_bytes_per_10ms", &rows);

    // Headline: checkpoint bytes vs expert bytes and gap occupancy.
    let total_expert: u64 = out
        .link_stats
        .iter()
        .map(|(_, s)| {
            s.bytes_of(TrafficClass::ExpertDispatch) + s.bytes_of(TrafficClass::ExpertReturn)
        })
        .sum();
    let total_ckpt: u64 =
        out.link_stats.iter().map(|(_, s)| s.bytes_of(TrafficClass::Checkpoint)).sum();
    println!(
        "  expert traffic {} B, checkpoint traffic {} B ({:.1}% — Appendix C predicts ~12.5% of one-way)",
        total_expert,
        total_ckpt,
        100.0 * total_ckpt as f64 / total_expert.max(1) as f64
    );
    println!("  throughput: {:.0} tok/s over {} tokens", out.analysis.throughput_tps, out.analysis.total_tokens);
}

fn expert_sum(t: &Timeline, i: usize) -> f64 {
    t.mean_series().get(i).map(|(_, m)| if m.is_nan() { 0.0 } else { *m }).unwrap_or(0.0)
        * t.rate_series().get(i).map(|(_, r)| r * 0.01).unwrap_or(0.0)
}

fn ckpt_sum(t: &Timeline, i: usize) -> f64 {
    expert_sum(t, i)
}
