//! §7.4 "Overhead of different checkpointing schemes": end-to-end
//! throughput under (1) no checkpointing, (2) TARRAGON's asynchronous
//! incremental checkpointing (idle-gap interleaved), and (3)
//! Pause-Checkpoint-Resume at various intervals (the training-style
//! global snapshot). Paper: (1) 1148 tok/s ≈ (2) 1147 tok/s; (3) at
//! 8-token intervals drops 2.15x.

use crate::config::{ResilienceConfig, WorkloadKind};
use crate::experiments::common::{run_serving, write_csv, ServeSpec, SystemKind};

pub fn run(rps: f64, duration: f64, pause_intervals: &[usize]) {
    println!("§7.4 checkpointing schemes ({rps} RPS, {duration}s per scheme)");
    let mut rows = Vec::new();
    let mut baseline = None;

    let mut run_variant = |label: String, res: ResilienceConfig| {
        let mut spec = ServeSpec::new(SystemKind::Tarragon, WorkloadKind::Random, rps, duration);
        spec.resilience = Some(res);
        let out = run_serving(&spec);
        let tps = out.analysis.throughput_tps;
        (label, tps)
    };

    // (1) no checkpointing
    let mut res = ResilienceConfig::default();
    res.checkpointing = false;
    let (l, tps) = run_variant("no-ckpt".into(), res);
    baseline = baseline.or(Some(tps));
    println!("  {l:<16} {tps:>7.0} tok/s");
    rows.push(format!("{l},{tps:.1}"));

    // (2) TARRAGON async incremental
    let (l, tps) = run_variant("tarragon".into(), ResilienceConfig::default());
    println!(
        "  {l:<16} {tps:>7.0} tok/s ({:+.2}% vs no-ckpt)",
        (tps / baseline.unwrap() - 1.0) * 100.0
    );
    rows.push(format!("{l},{tps:.1}"));

    // (3) Pause-Checkpoint-Resume at intervals
    for &every in pause_intervals {
        let mut res = ResilienceConfig::default();
        res.checkpointing = false;
        res.pause_ckpt_every = every;
        let (_, tps) = run_variant(format!("pause-every-{every}"), res);
        println!(
            "  pause-every-{every:<4} {tps:>7.0} tok/s ({:.2}x slower than no-ckpt)",
            baseline.unwrap() / tps.max(1e-9)
        );
        rows.push(format!("pause-every-{every},{tps:.1}"));
    }
    write_csv("ckpt_overhead.csv", "scheme,tokens_per_s", &rows);
}
