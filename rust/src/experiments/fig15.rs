//! Fig. 15 (Appendix F) + §7.3 ablation: steady-state overhead of the
//! resilience components. Variants: full TARRAGON, Alt-1 (no
//! checkpointing), Alt-2 (+ no detection), Alt-3 (+ static ERT, no
//! shadows, no partial batches ≈ MegaScale). No failures injected; any
//! differences are pure overhead. The paper reports < 3% spread.

use crate::config::{ResilienceConfig, WorkloadKind};
use crate::experiments::common::{run_serving, write_csv, ServeSpec, SystemKind};

pub fn run(rates: &[f64], duration: f64) {
    println!("Fig 15: ablation of resilience components (no failures, {duration}s per point)");
    let variants = ["tarragon", "alt1", "alt2", "alt3"];
    let mut rows = Vec::new();
    for &wl in &[WorkloadKind::ShareGpt, WorkloadKind::Random] {
        let wl_name = match wl {
            WorkloadKind::ShareGpt => "sharegpt",
            WorkloadKind::Random => "random",
        };
        for &rps in rates {
            let mut base_tps = None;
            for v in variants {
                let mut spec = ServeSpec::new(SystemKind::Tarragon, wl, rps, duration);
                spec.resilience = Some(ResilienceConfig::variant(v).unwrap());
                let out = run_serving(&spec);
                let tps = out.analysis.throughput_tps;
                let rel = base_tps.get_or_insert(tps);
                println!(
                    "  {wl_name:<8} {v:<9} {rps:>5.1} rps | {tps:>7.0} tok/s ({:+.1}% vs tarragon)",
                    (tps / *rel - 1.0) * 100.0
                );
                rows.push(format!("{wl_name},{v},{rps},{tps:.1}"));
            }
        }
    }
    write_csv("fig15.csv", "workload,variant,rps,tokens_per_s", &rows);
}
