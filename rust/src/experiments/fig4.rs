//! Fig. 4: inference stall time (a–c) and re-execution cost (d–f) under a
//! single worker failure, as functions of the decoded-token index `i`, for
//! monolithic (MO), decoupled-AW, and decoupled-EW failures — the cost
//! model of §2.2.2 fed with the Table 1 parameters measured on this
//! testbed. The TARRAGON prediction is overlaid for comparison.

use crate::costmodel::{self, Deployment, FailureSite};
use crate::experiments::common::write_csv;
use crate::experiments::table1;
use std::time::Duration;

pub fn run(layers: usize, workers: usize) {
    let params = match table1::load() {
        Some(t) => t,
        None => {
            println!("(table1.json missing — profiling first)");
            table1::run(Duration::from_millis(500))
        }
    };
    println!("Fig 4: recovery-cost sweep (L={layers}, M={workers})");

    // Prompt lengths scaled from the paper's 128/512/1024 to our max_seq.
    let prompts = [24usize, 48, 96];
    let tokens: Vec<usize> =
        [1usize, 2, 4, 8, 16, 32, 64, 96, 128, 192, 256, 384, 512].to_vec();
    let l_mid = (layers / 2).max(1);

    let mut rows = Vec::new();
    for &p_len in &prompts {
        let dep = Deployment { layers, workers, prompt_len: p_len };
        for &i in &tokens {
            for (site, name, params) in [
                (FailureSite::Monolithic, "mo", &params.vllm),
                (FailureSite::DecoupledAw, "aw", &params.megascale),
                (FailureSite::DecoupledEw, "ew", &params.megascale),
            ] {
                let stall = costmodel::stall(params, &dep, site, i, l_mid);
                let gpu = costmodel::gpu_overhead(params, &dep, site, i, l_mid);
                let tarragon =
                    costmodel::tarragon_stall(Duration::from_millis(300), params, site);
                rows.push(format!(
                    "{p_len},{i},{name},{:.4},{:.6},{:.4}",
                    stall.as_secs_f64(),
                    gpu,
                    tarragon.as_secs_f64()
                ));
            }
        }
    }
    write_csv(
        "fig4.csv",
        "prompt_len,token_i,failure_site,stall_s,gpu_time,tarragon_stall_s",
        &rows,
    );

    // Print the paper's three observations as a summary audit.
    let dep = Deployment { layers, workers, prompt_len: 24 };
    let p = &params.megascale;
    let s64 = costmodel::stall(p, &dep, FailureSite::DecoupledAw, 64, l_mid);
    let s512 = costmodel::stall(p, &dep, FailureSite::DecoupledAw, 512, l_mid);
    let ew = costmodel::stall(p, &dep, FailureSite::DecoupledEw, 512, l_mid);
    println!("  AW stall @i=64: {:.2}s   @i=512: {:.2}s (grows with i)", s64.as_secs_f64(), s512.as_secs_f64());
    println!("  EW stall (constant): {:.2}s — T_w dominates", ew.as_secs_f64());
    let g_dec64 = costmodel::gpu_overhead(p, &dep, FailureSite::DecoupledAw, 64, l_mid);
    let dep128 = Deployment { layers, workers, prompt_len: 96 };
    let g_pref = dep128.prompt_len as f64 * layers as f64 * p.g_pre;
    println!(
        "  decode replay @i=64 vs 96-token prefill GPU cost: {:.1}x",
        (g_dec64 - dep.prompt_len as f64 * layers as f64 * p.g_pre).max(0.0) / g_pref
    );
}
