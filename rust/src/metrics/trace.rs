//! Span tracing: the request-scoped recovery-anatomy layer (DESIGN.md
//! §14).
//!
//! Each worker records [`Span`]s — gateway queueing, AW prefill/decode
//! steps, REFE dispatch rounds, EW expert batches, checkpoint
//! emit/commit, restore pull/install, detection windows, ERT remaps —
//! into a preallocated per-worker [`TraceRing`], overwrite-oldest on
//! overflow. Timestamps come from the cluster [`Clock`], so
//! virtual-clock runs produce deterministic traces.
//!
//! Invariants future PRs must preserve:
//! - **Gated**: workers hold `Option<TraceHandle>`; with `[trace]
//!   enabled = false` the option is `None` and the hot paths make no
//!   clock reads and no ring writes — runs are bitwise-identical to a
//!   build without this module.
//! - **Zero-alloc**: `TraceRing::push` writes into storage reserved at
//!   construction; recording a span in the steady-state decode loop
//!   performs no heap allocation (pinned by `rust/tests/alloc.rs`).

use crate::util::clock::Clock;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Track-id convention for [`Span::worker`] (the exporter's `tid`):
/// AWs use their index directly, EWs add this offset, and the gateway
/// uses [`GATEWAY_TID`] — distinct tracks per role in the trace UI.
pub const EW_TID_OFFSET: u32 = 100;
pub const GATEWAY_TID: u32 = 999;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Gateway: request accepted → dispatched to an AW.
    GatewayQueue,
    /// AW: one prefill pass (aux = prompt length).
    Prefill,
    /// AW: one steady-state decode step (aux = batch size).
    DecodeStep,
    /// REFE: one expert dispatch round trip (aux = round index).
    DispatchRound,
    /// EW: one expert FFN batch (aux = expert id).
    ExpertBatch,
    /// AW: checkpoint segment flush to the store (aux = queue depth).
    CkptEmit,
    /// AW: commit record pushed (aux = committed position).
    CkptCommit,
    /// AW: adoption → restore chunks requested from the store.
    RestorePull,
    /// AW: restore chunks received → KV installed, request active.
    RestoreInstall,
    /// REFE/EW: silence observed → peer death confirmed (aux = suspect).
    DetectionWindow,
    /// REFE: ERT failover remap of a dead EW (aux = dead EW index).
    ErtRemap,
}

impl SpanKind {
    pub const ALL: [SpanKind; 11] = [
        SpanKind::GatewayQueue,
        SpanKind::Prefill,
        SpanKind::DecodeStep,
        SpanKind::DispatchRound,
        SpanKind::ExpertBatch,
        SpanKind::CkptEmit,
        SpanKind::CkptCommit,
        SpanKind::RestorePull,
        SpanKind::RestoreInstall,
        SpanKind::DetectionWindow,
        SpanKind::ErtRemap,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::GatewayQueue => "gateway_queue",
            SpanKind::Prefill => "prefill",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::DispatchRound => "dispatch_round",
            SpanKind::ExpertBatch => "expert_batch",
            SpanKind::CkptEmit => "ckpt_emit",
            SpanKind::CkptCommit => "ckpt_commit",
            SpanKind::RestorePull => "restore_pull",
            SpanKind::RestoreInstall => "restore_install",
            SpanKind::DetectionWindow => "detection_window",
            SpanKind::ErtRemap => "ert_remap",
        }
    }

    /// Perfetto category for the kind (groups tracks in the UI).
    pub fn category(self) -> &'static str {
        match self {
            SpanKind::GatewayQueue => "gateway",
            SpanKind::Prefill | SpanKind::DecodeStep => "compute",
            SpanKind::DispatchRound | SpanKind::ExpertBatch => "expert",
            SpanKind::CkptEmit | SpanKind::CkptCommit => "checkpoint",
            SpanKind::RestorePull | SpanKind::RestoreInstall => "restore",
            SpanKind::DetectionWindow | SpanKind::ErtRemap => "failure",
        }
    }
}

/// One closed span. All-`Copy` so ring writes are plain stores.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub kind: SpanKind,
    /// Request id the span serves (0 for cluster-scoped spans).
    pub request: u64,
    /// Worker index that recorded the span.
    pub worker: u32,
    /// Kind-specific payload (batch size, expert id, suspect index…).
    pub aux: u64,
    /// Offsets from the tracer epoch.
    pub start: Duration,
    pub end: Duration,
}

/// Fixed-capacity span ring: storage is reserved once at construction,
/// and on overflow the oldest span is overwritten (`dropped` counts
/// overwrites). `push` never allocates.
pub struct TraceRing {
    spans: Vec<Span>,
    /// Next slot to overwrite once the ring is full.
    head: usize,
    dropped: u64,
}

impl TraceRing {
    pub fn new(capacity: usize) -> TraceRing {
        TraceRing { spans: Vec::with_capacity(capacity.max(1)), head: 0, dropped: 0 }
    }

    pub fn push(&mut self, span: Span) {
        if self.spans.len() < self.spans.capacity() {
            self.spans.push(span);
        } else {
            self.spans[self.head] = span;
            self.head = (self.head + 1) % self.spans.len();
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Spans overwritten by ring overflow.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Spans in record order (oldest first, unwrapping the ring).
    pub fn snapshot(&self) -> Vec<Span> {
        let mut out = Vec::with_capacity(self.spans.len());
        out.extend_from_slice(&self.spans[self.head..]);
        out.extend_from_slice(&self.spans[..self.head]);
        out
    }
}

/// Cluster-wide span sink: one preallocated [`TraceRing`] per worker,
/// all sharing one clock and a rebasable epoch (matching
/// `EventLog::rebase` so spans and events share a timeline).
pub struct Tracer {
    clock: Clock,
    epoch_nanos: AtomicU64,
    ring_capacity: usize,
    rings: Mutex<Vec<Arc<Mutex<TraceRing>>>>,
}

impl Tracer {
    pub fn new(clock: Clock, ring_capacity: usize) -> Arc<Tracer> {
        let epoch = clock.now();
        Arc::new(Tracer {
            clock,
            epoch_nanos: AtomicU64::new(epoch.as_nanos() as u64),
            ring_capacity,
            rings: Mutex::new(Vec::new()),
        })
    }

    /// Re-pin the epoch (called alongside `EventLog::rebase` after
    /// cluster bring-up).
    pub fn rebase(&self) {
        self.epoch_nanos.store(self.clock.now().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Register a worker: allocates its ring up front and hands back a
    /// recording handle. Allocation happens here, never on record.
    pub fn handle(self: &Arc<Self>, worker: u32) -> TraceHandle {
        let ring = Arc::new(Mutex::new(TraceRing::new(self.ring_capacity)));
        self.rings.lock().unwrap().push(ring.clone());
        TraceHandle { tracer: self.clone(), ring, worker }
    }

    fn now_rel(&self) -> Duration {
        let epoch = Duration::from_nanos(self.epoch_nanos.load(Ordering::Relaxed));
        self.clock.now().saturating_sub(epoch)
    }

    /// Total spans lost to ring overflow, over every worker.
    pub fn dropped(&self) -> u64 {
        let rings = self.rings.lock().unwrap();
        rings.iter().map(|r| r.lock().unwrap().dropped()).sum::<u64>()
    }

    /// Merge every worker's ring, ordered by span start (ties keep
    /// worker registration order) — the exporters' input.
    pub fn snapshot(&self) -> Vec<Span> {
        let rings = self.rings.lock().unwrap();
        let mut out: Vec<Span> = Vec::new();
        for r in rings.iter() {
            out.extend(r.lock().unwrap().snapshot());
        }
        out.sort_by_key(|s| s.start);
        out
    }
}

/// A worker's recording handle. Cheap to clone; `None` at the worker
/// when tracing is disabled, so disabled runs never read the clock.
#[derive(Clone)]
pub struct TraceHandle {
    tracer: Arc<Tracer>,
    ring: Arc<Mutex<TraceRing>>,
    worker: u32,
}

impl TraceHandle {
    /// Epoch-relative "now": capture before the work a span covers.
    pub fn start(&self) -> Duration {
        self.tracer.now_rel()
    }

    /// Close a span that began at `start` (from [`TraceHandle::start`])
    /// and ends now.
    pub fn record(&self, kind: SpanKind, request: u64, aux: u64, start: Duration) {
        let end = self.tracer.now_rel();
        self.record_span(kind, request, aux, start, end);
    }

    /// Record a fully-specified span (both endpoints known).
    pub fn record_span(
        &self,
        kind: SpanKind,
        request: u64,
        aux: u64,
        start: Duration,
        end: Duration,
    ) {
        let span = Span { kind, request, worker: self.worker, aux, start, end: end.max(start) };
        self.ring.lock().unwrap().push(span);
    }

    pub fn worker(&self) -> u32 {
        self.worker
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, start_us: u64) -> Span {
        Span {
            kind,
            request: 1,
            worker: 0,
            aux: 0,
            start: Duration::from_micros(start_us),
            end: Duration::from_micros(start_us + 10),
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_never_grows() {
        let mut r = TraceRing::new(3);
        let cap = r.spans.capacity();
        for i in 0..5 {
            r.push(span(SpanKind::DecodeStep, i * 100));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.spans.capacity(), cap, "ring storage must never grow");
        assert_eq!(r.dropped(), 2);
        let snap = r.snapshot();
        let starts: Vec<u64> = snap.iter().map(|s| s.start.as_micros() as u64).collect();
        assert_eq!(starts, vec![200, 300, 400], "oldest spans overwritten first");
    }

    #[test]
    fn tracer_merges_rings_in_start_order() {
        let clock = Clock::virtual_seeded(3);
        let g = clock.register();
        let tracer = Tracer::new(clock.clone(), 8);
        let h0 = tracer.handle(0);
        let h1 = tracer.handle(1);
        clock.sleep(Duration::from_millis(2));
        let t0 = h0.start();
        clock.sleep(Duration::from_millis(1));
        h1.record(SpanKind::ExpertBatch, 4, 2, h1.start());
        clock.sleep(Duration::from_millis(1));
        h0.record(SpanKind::DecodeStep, 4, 1, t0);
        let spans = tracer.snapshot();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].kind, SpanKind::DecodeStep, "earlier start sorts first");
        assert_eq!(spans[0].start, Duration::from_millis(2));
        assert_eq!(spans[0].end, Duration::from_millis(4));
        assert_eq!(spans[1].worker, 1);
        assert_eq!(tracer.dropped(), 0);
        drop(g);
        clock.shutdown();
    }

    #[test]
    fn rebase_repins_span_epoch() {
        let clock = Clock::virtual_seeded(4);
        let g = clock.register();
        let tracer = Tracer::new(clock.clone(), 4);
        let h = tracer.handle(0);
        clock.sleep(Duration::from_millis(50));
        tracer.rebase();
        clock.sleep(Duration::from_millis(3));
        h.record(SpanKind::Prefill, 1, 8, h.start());
        let spans = tracer.snapshot();
        assert_eq!(spans[0].start, Duration::from_millis(3));
        drop(g);
        clock.shutdown();
    }

    #[test]
    fn span_kind_names_are_unique_and_categorized() {
        let mut seen = std::collections::HashSet::new();
        for k in SpanKind::ALL {
            assert!(seen.insert(k.name()), "duplicate span name {}", k.name());
            assert!(!k.category().is_empty());
        }
    }
}
