//! Log-bucketed latency histogram.
//!
//! Benches summarize TTFT/TBT/restore-latency samples through this
//! instead of sorting full sample vectors: pushes are O(1) into a
//! fixed set of geometric buckets (~5% wide, so any quantile is
//! within one half-bucket ≈ 2.5% of the exact sample), and the
//! summary cost is independent of run length.

/// Geometric bucket growth factor (each bucket is 5% wider).
const GROWTH: f64 = 1.05;
/// Lower edge of bucket 0; anything at or below lands in bucket 0.
/// Samples are milliseconds in practice, so this is 1 ns.
const V0: f64 = 1e-3;
/// Fixed bucket count: covers V0 · 1.05^512 ≈ 7e7 ms on the top end.
const BUCKETS: usize = 512;

#[derive(Debug, Clone)]
pub struct LogHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            buckets: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn of(samples: &[f64]) -> LogHistogram {
        let mut h = LogHistogram::new();
        for &v in samples {
            h.push(v);
        }
        h
    }

    fn index(v: f64) -> usize {
        if v <= V0 {
            return 0;
        }
        let i = (v / V0).ln() / GROWTH.ln();
        (i as usize).min(BUCKETS - 1)
    }

    /// Record one sample; non-finite samples are ignored.
    pub fn push(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let v = v.max(0.0);
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean of the pushed samples (NaN when empty, like
    /// `stats::mean` — the JSON writer serializes that as `null`).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Exact maximum (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.max
    }

    /// Exact minimum (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.min
    }

    /// Approximate quantile (`q` in 0..=1): the geometric midpoint of
    /// the bucket holding the q-th sample, clamped to the exact
    /// observed [min, max]. NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                let rep = V0 * GROWTH.powf(i as f64 + 0.5);
                return rep.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Percentile convenience matching `stats::percentile` (`p` in
    /// 0..=100).
    pub fn percentile(&self, p: f64) -> f64 {
        self.quantile(p / 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_track_exact_values_within_bucket_width() {
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let h = LogHistogram::of(&samples);
        assert_eq!(h.count(), 1000);
        for (q, exact) in [(0.5, 500.0), (0.95, 950.0), (0.99, 990.0)] {
            let got = h.quantile(q);
            assert!(
                (got - exact).abs() / exact < 0.05,
                "q{q}: got {got}, exact {exact}"
            );
        }
        assert_eq!(h.quantile(1.0), 1000.0, "top quantile clamps to the exact max");
        assert_eq!(h.quantile(0.0), 1.0, "bottom quantile clamps to the exact min");
        assert!((h.mean() - 500.5).abs() < 1e-9, "mean is exact");
        assert_eq!(h.max(), 1000.0);
        assert_eq!(h.min(), 1.0);
        assert!((h.percentile(99.0) - h.quantile(0.99)).abs() < 1e-12);
    }

    #[test]
    fn empty_and_degenerate_samples() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert!(h.quantile(0.5).is_nan() && h.mean().is_nan() && h.max().is_nan());
        let mut h = LogHistogram::new();
        h.push(f64::NAN); // ignored
        h.push(0.0); // clamps into bucket 0
        h.push(42.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(1.0), 42.0);
    }
}
