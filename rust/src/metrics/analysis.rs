//! Turning the raw event log into the paper's metrics:
//! TTFT (submission -> first token), TBT (gap between consecutive tokens of
//! a request), output-token throughput, and per-window timelines.

use super::{Event, EventKind, EventLog};
use crate::util::stats::{self, Timeline};
use std::collections::HashMap;

/// Median/p95/mean over a latency sample, in milliseconds.
#[derive(Debug, Clone)]
pub struct LatencySummary {
    pub count: usize,
    pub median_ms: f64,
    pub p95_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    pub fn of(samples_ms: &[f64]) -> LatencySummary {
        LatencySummary {
            count: samples_ms.len(),
            median_ms: stats::median(samples_ms),
            p95_ms: stats::percentile(samples_ms, 95.0),
            mean_ms: stats::mean(samples_ms),
            max_ms: samples_ms.iter().copied().fold(f64::NAN, f64::max),
        }
    }
}

/// Full analysis of one run.
#[derive(Debug, Clone)]
pub struct RunAnalysis {
    pub ttft_ms: Vec<f64>,
    pub tbt_ms: Vec<f64>,
    pub total_tokens: usize,
    pub finished_requests: usize,
    pub submitted_requests: usize,
    pub duration_secs: f64,
    /// Output tokens per second over the whole run.
    pub throughput_tps: f64,
    /// (window_start_s, tokens/s) series.
    pub throughput_series: Vec<(f64, f64)>,
    /// (window_start_s, mean TBT ms) series.
    pub tbt_series: Vec<(f64, f64)>,
    /// Requests preempted under KV pressure or drains (DESIGN.md §9).
    pub preemptions: usize,
    /// Requests rejected at admission (oversized).
    pub rejections: usize,
    /// Longest gap between consecutive tokens *cluster-wide* (the paper's
    /// "stall": the visible freeze of the token stream, Fig. 9).
    pub max_token_gap_s: f64,
    /// Start time (s since epoch) of that longest gap.
    pub max_gap_start_s: f64,
    /// Sorted emission times of every token (cluster-wide), seconds.
    pub token_times: Vec<f64>,
}

impl RunAnalysis {
    /// Longest gap between consecutive tokens whose start is >= t0
    /// (failure-stall measurement: pass the injection time).
    pub fn max_gap_after(&self, t0: f64) -> (f64, f64) {
        let mut best = (0.0, 0.0);
        for w in self.token_times.windows(2) {
            if w[0] >= t0 && w[1] - w[0] > best.0 {
                best = (w[1] - w[0], w[0]);
            }
        }
        best
    }
}

impl RunAnalysis {
    pub fn from_log(log: &EventLog, window_secs: f64) -> RunAnalysis {
        Self::from_events(&log.snapshot(), window_secs)
    }

    pub fn from_events(events: &[Event], window_secs: f64) -> RunAnalysis {
        let secs = |at: std::time::Duration| at.as_secs_f64();
        let mut submitted: HashMap<u64, f64> = HashMap::new();
        let mut last_token: HashMap<u64, f64> = HashMap::new();
        let mut ttft = Vec::new();
        let mut tbt = Vec::new();
        let mut finished = 0usize;
        let mut total_tokens = 0usize;
        let mut preemptions = 0usize;
        let mut rejections = 0usize;
        let mut tp_timeline = Timeline::new(window_secs);
        let mut tbt_timeline = Timeline::new(window_secs);
        let mut token_times: Vec<f64> = Vec::new();
        let mut t_end: f64 = 0.0;

        for e in events {
            let t = secs(e.at);
            t_end = t_end.max(t);
            match e.kind {
                EventKind::Submitted => {
                    submitted.insert(e.request, t);
                }
                EventKind::Admitted | EventKind::Migrated => {}
                EventKind::Token => {
                    total_tokens += 1;
                    tp_timeline.push(t, 1.0);
                    token_times.push(t);
                    if e.token_index == 0 {
                        if let Some(&t0) = submitted.get(&e.request) {
                            ttft.push((t - t0) * 1e3);
                        }
                    } else if let Some(&tp) = last_token.get(&e.request) {
                        let gap_ms = (t - tp) * 1e3;
                        tbt.push(gap_ms);
                        tbt_timeline.push(t, gap_ms);
                    }
                    last_token.insert(e.request, t);
                }
                EventKind::Finished => finished += 1,
                EventKind::Preempted => preemptions += 1,
                EventKind::Rejected => rejections += 1,
                // Cluster reconfiguration markers, not request lifecycle.
                EventKind::ScaleOut | EventKind::ScaleIn | EventKind::ShadowPromoted => {}
            }
        }

        // Cluster-wide token-stream gap (stall detection).
        token_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut max_gap = 0.0f64;
        let mut max_gap_start = 0.0f64;
        for w in token_times.windows(2) {
            let gap = w[1] - w[0];
            if gap > max_gap {
                max_gap = gap;
                max_gap_start = w[0];
            }
        }

        let duration = t_end.max(1e-9);
        RunAnalysis {
            token_times: token_times.clone(),
            throughput_tps: total_tokens as f64 / duration,
            ttft_ms: ttft,
            tbt_ms: tbt,
            total_tokens,
            finished_requests: finished,
            submitted_requests: submitted.len(),
            preemptions,
            rejections,
            duration_secs: duration,
            throughput_series: tp_timeline.rate_series(),
            tbt_series: tbt_timeline.mean_series(),
            max_token_gap_s: max_gap,
            max_gap_start_s: max_gap_start,
        }
    }

    pub fn ttft(&self) -> LatencySummary {
        LatencySummary::of(&self.ttft_ms)
    }

    pub fn tbt(&self) -> LatencySummary {
        LatencySummary::of(&self.tbt_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EventLog;
    use std::time::Duration;

    fn ev(t_ms: u64, kind: EventKind, req: u64, tok: u32) -> Event {
        Event {
            at: Duration::from_millis(t_ms),
            kind,
            request: req,
            token_index: tok,
            worker: 0,
        }
    }

    #[test]
    fn ttft_tbt_and_stall() {
        let events = vec![
            ev(0, EventKind::Submitted, 1, 0),
            ev(100, EventKind::Token, 1, 0),  // TTFT = 100ms
            ev(150, EventKind::Token, 1, 1),  // TBT 50
            ev(200, EventKind::Token, 1, 2),  // TBT 50
            ev(900, EventKind::Token, 1, 3),  // TBT 700 (stall)
            ev(950, EventKind::Finished, 1, 0),
        ];
        let a = RunAnalysis::from_events(&events, 0.5);
        assert_eq!(a.ttft_ms.len(), 1);
        assert!((a.ttft_ms[0] - 100.0).abs() < 1.0);
        assert_eq!(a.tbt_ms.len(), 3);
        assert!((a.max_token_gap_s - 0.7).abs() < 0.01);
        assert!((a.max_gap_start_s - 0.2).abs() < 0.01);
        assert_eq!(a.total_tokens, 4);
        assert_eq!(a.finished_requests, 1);
        let tbt = a.tbt();
        assert!((tbt.median_ms - 50.0).abs() < 1.0);
    }

    #[test]
    fn multi_request_interleaving() {
        let events = vec![
            ev(0, EventKind::Submitted, 1, 0),
            ev(10, EventKind::Submitted, 2, 0),
            ev(50, EventKind::Token, 1, 0),
            ev(60, EventKind::Token, 2, 0),
            ev(70, EventKind::Token, 1, 1), // TBT(1) = 20
            ev(90, EventKind::Token, 2, 1), // TBT(2) = 30
        ];
        let a = RunAnalysis::from_events(&events, 1.0);
        assert_eq!(a.ttft_ms.len(), 2);
        assert_eq!(a.tbt_ms.len(), 2);
        assert!((a.tbt_ms[0] - 20.0).abs() < 1e-9 && (a.tbt_ms[1] - 30.0).abs() < 1e-9);
        // Cluster-wide gaps are between consecutive tokens of any request:
        // 50,60,70,90 ms -> max gap 20 ms.
        assert!((a.max_token_gap_s - 0.02).abs() < 0.001);
        let (g, t) = a.max_gap_after(0.065);
        assert!((g - 0.02).abs() < 1e-9 && (t - 0.07).abs() < 1e-9);
    }

    #[test]
    fn counts_preemptions_and_rejections() {
        let events = vec![
            ev(0, EventKind::Submitted, 1, 0),
            ev(5, EventKind::Rejected, 1, 0),
            ev(10, EventKind::Submitted, 2, 0),
            ev(50, EventKind::Token, 2, 0),
            ev(60, EventKind::Preempted, 2, 0),
            ev(90, EventKind::Migrated, 2, 0),
            ev(120, EventKind::Token, 2, 1),
            ev(121, EventKind::Finished, 2, 0),
        ];
        let a = RunAnalysis::from_events(&events, 1.0);
        assert_eq!(a.preemptions, 1);
        assert_eq!(a.rejections, 1);
        assert_eq!(a.finished_requests, 1);
        assert_eq!(a.total_tokens, 2);
    }

    #[test]
    fn empty_log() {
        let log = EventLog::new();
        let a = RunAnalysis::from_log(&log, 1.0);
        assert_eq!(a.total_tokens, 0);
        assert!(a.ttft().median_ms.is_nan());
    }
}
