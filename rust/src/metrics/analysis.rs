//! Turning the raw event log into the paper's metrics:
//! TTFT (submission -> first token), TBT (gap between consecutive tokens of
//! a request), output-token throughput, and per-window timelines.

use super::{Event, EventKind, EventLog};
use crate::util::stats::{self, Timeline};
use std::collections::HashMap;

/// Median/p95/mean over a latency sample, in milliseconds.
#[derive(Debug, Clone)]
pub struct LatencySummary {
    pub count: usize,
    pub median_ms: f64,
    pub p95_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    pub fn of(samples_ms: &[f64]) -> LatencySummary {
        LatencySummary {
            count: samples_ms.len(),
            median_ms: stats::median(samples_ms),
            p95_ms: stats::percentile(samples_ms, 95.0),
            mean_ms: stats::mean(samples_ms),
            max_ms: samples_ms.iter().copied().fold(f64::NAN, f64::max),
        }
    }
}

/// Full analysis of one run.
#[derive(Debug, Clone)]
pub struct RunAnalysis {
    pub ttft_ms: Vec<f64>,
    pub tbt_ms: Vec<f64>,
    pub total_tokens: usize,
    pub finished_requests: usize,
    pub submitted_requests: usize,
    pub duration_secs: f64,
    /// Output tokens per second over the whole run.
    pub throughput_tps: f64,
    /// (window_start_s, tokens/s) series.
    pub throughput_series: Vec<(f64, f64)>,
    /// (window_start_s, mean TBT ms) series.
    pub tbt_series: Vec<(f64, f64)>,
    /// Requests preempted under KV pressure or drains (DESIGN.md §9).
    pub preemptions: usize,
    /// Requests rejected at admission (oversized).
    pub rejections: usize,
    /// Longest gap between consecutive tokens *cluster-wide* (the paper's
    /// "stall": the visible freeze of the token stream, Fig. 9).
    pub max_token_gap_s: f64,
    /// Start time (s since epoch) of that longest gap.
    pub max_gap_start_s: f64,
    /// Sorted emission times of every token (cluster-wide), seconds.
    pub token_times: Vec<f64>,
}

impl RunAnalysis {
    /// Longest gap between consecutive tokens whose start is >= t0
    /// (failure-stall measurement: pass the injection time).
    pub fn max_gap_after(&self, t0: f64) -> (f64, f64) {
        let mut best = (0.0, 0.0);
        for w in self.token_times.windows(2) {
            if w[0] >= t0 && w[1] - w[0] > best.0 {
                best = (w[1] - w[0], w[0]);
            }
        }
        best
    }
}

impl RunAnalysis {
    pub fn from_log(log: &EventLog, window_secs: f64) -> RunAnalysis {
        Self::from_events(&log.snapshot(), window_secs)
    }

    pub fn from_events(events: &[Event], window_secs: f64) -> RunAnalysis {
        let secs = |at: std::time::Duration| at.as_secs_f64();
        let mut submitted: HashMap<u64, f64> = HashMap::new();
        let mut last_token: HashMap<u64, f64> = HashMap::new();
        let mut ttft = Vec::new();
        let mut tbt = Vec::new();
        let mut finished = 0usize;
        let mut total_tokens = 0usize;
        let mut preemptions = 0usize;
        let mut rejections = 0usize;
        let mut tp_timeline = Timeline::new(window_secs);
        let mut tbt_timeline = Timeline::new(window_secs);
        let mut token_times: Vec<f64> = Vec::new();
        let mut t_end: f64 = 0.0;

        for e in events {
            let t = secs(e.at);
            t_end = t_end.max(t);
            match e.kind {
                EventKind::Submitted => {
                    submitted.insert(e.request, t);
                }
                EventKind::Admitted | EventKind::Migrated => {}
                EventKind::Token => {
                    total_tokens += 1;
                    tp_timeline.push(t, 1.0);
                    token_times.push(t);
                    if e.token_index == 0 {
                        if let Some(&t0) = submitted.get(&e.request) {
                            ttft.push((t - t0) * 1e3);
                        }
                    } else if let Some(&tp) = last_token.get(&e.request) {
                        let gap_ms = (t - tp) * 1e3;
                        tbt.push(gap_ms);
                        tbt_timeline.push(t, gap_ms);
                    }
                    last_token.insert(e.request, t);
                }
                EventKind::Finished => finished += 1,
                EventKind::Preempted => preemptions += 1,
                EventKind::Rejected => rejections += 1,
                // Cluster reconfiguration markers, not request lifecycle.
                EventKind::ScaleOut | EventKind::ScaleIn | EventKind::ShadowPromoted => {}
                // Failure-lifecycle markers: consumed by RecoveryReport,
                // not by the base latency/throughput metrics.
                EventKind::Detected
                | EventKind::Rerouted
                | EventKind::Adopted
                | EventKind::RestoreStarted
                | EventKind::Restored
                | EventKind::StoreFailover
                | EventKind::GatewayFailover
                | EventKind::OrchPromoted => {}
            }
        }

        // Cluster-wide token-stream gap (stall detection).
        token_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut max_gap = 0.0f64;
        let mut max_gap_start = 0.0f64;
        for w in token_times.windows(2) {
            let gap = w[1] - w[0];
            if gap > max_gap {
                max_gap = gap;
                max_gap_start = w[0];
            }
        }

        let duration = t_end.max(1e-9);
        RunAnalysis {
            token_times: token_times.clone(),
            throughput_tps: total_tokens as f64 / duration,
            ttft_ms: ttft,
            tbt_ms: tbt,
            total_tokens,
            finished_requests: finished,
            submitted_requests: submitted.len(),
            preemptions,
            rejections,
            duration_secs: duration,
            throughput_series: tp_timeline.rate_series(),
            tbt_series: tbt_timeline.mean_series(),
            max_token_gap_s: max_gap,
            max_gap_start_s: max_gap_start,
        }
    }

    pub fn ttft(&self) -> LatencySummary {
        LatencySummary::of(&self.ttft_ms)
    }

    pub fn tbt(&self) -> LatencySummary {
        LatencySummary::of(&self.tbt_ms)
    }
}

/// Which role the failed node played in a recovery incident.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureClass {
    Aw,
    Ew,
    /// Checkpoint-store replica (DESIGN.md §15).
    Store,
    /// Gateway shard.
    Gateway,
    /// The active orchestrator (standby promotion).
    Orch,
}

impl FailureClass {
    pub fn name(self) -> &'static str {
        match self {
            FailureClass::Aw => "aw",
            FailureClass::Ew => "ew",
            FailureClass::Store => "store",
            FailureClass::Gateway => "gateway",
            FailureClass::Orch => "orch",
        }
    }

    /// The `Detected` event's `token_index` encoding of this class.
    pub fn code(self) -> u32 {
        match self {
            FailureClass::Aw => 0,
            FailureClass::Ew => 1,
            FailureClass::Store => 2,
            FailureClass::Gateway => 3,
            FailureClass::Orch => 4,
        }
    }

    fn decode(code: u32) -> FailureClass {
        match code {
            1 => FailureClass::Ew,
            2 => FailureClass::Store,
            3 => FailureClass::Gateway,
            4 => FailureClass::Orch,
            _ => FailureClass::Aw,
        }
    }
}

/// One victim request's stall, decomposed into recovery phases
/// (the in-repo analog of the paper's Fig. 9 anatomy). All phases are
/// clamped non-negative; a phase the recovery path did not exercise
/// (e.g. restore for a resubmit-from-prompt) is 0.
#[derive(Debug, Clone)]
pub struct VictimStall {
    pub request: u64,
    /// Last progress (token, or submission) → death confirmed.
    pub detect_s: f64,
    /// Death confirmed → first reroute action (replay / adopt / resubmit).
    pub reroute_s: f64,
    /// Checkpoint pull requested → checkpoint installed.
    pub restore_s: f64,
    /// Last recovery action → first post-recovery token.
    pub recompute_s: f64,
    /// Last pre-fault progress → first post-fault token (the visible
    /// per-request stall; `detect + reroute + restore` when no token
    /// follows).
    pub total_stall_s: f64,
}

/// One confirmed worker death and the per-request stalls it induced.
#[derive(Debug, Clone)]
pub struct Incident {
    pub class: FailureClass,
    pub worker: u32,
    /// Seconds since the log epoch at which the death was confirmed
    /// (earliest `Detected` event for this worker).
    pub t_detect_s: f64,
    pub victims: Vec<VictimStall>,
}

/// Stall attribution for every fault in a run, computed purely from the
/// failure-lifecycle events in the log (DESIGN.md §14).
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    pub incidents: Vec<Incident>,
}

/// Duplicate `Detected` events for the same (class, worker) inside this
/// window collapse into one incident: the REFE-local detection and the
/// orchestrator's confirmation of the same death both record.
const DETECT_MERGE_WINDOW_S: f64 = 0.2;

impl RecoveryReport {
    pub fn from_log(log: &EventLog) -> RecoveryReport {
        Self::from_events(&log.snapshot())
    }

    pub fn from_events(events: &[Event]) -> RecoveryReport {
        let mut events: Vec<Event> = events.to_vec();
        events.sort_by(|a, b| a.at.cmp(&b.at));
        let secs = |at: std::time::Duration| at.as_secs_f64();

        // Per-request progress history.
        let mut submitted: HashMap<u64, f64> = HashMap::new();
        let mut tokens: HashMap<u64, Vec<f64>> = HashMap::new();
        for e in &events {
            let t = secs(e.at);
            match e.kind {
                EventKind::Submitted => {
                    submitted.entry(e.request).or_insert(t);
                }
                EventKind::Token => tokens.entry(e.request).or_default().push(t),
                _ => {}
            }
        }

        // Confirmed deaths, merged across duplicate detections.
        let mut heads: Vec<(FailureClass, u32, f64)> = Vec::new();
        for e in &events {
            if e.kind != EventKind::Detected {
                continue;
            }
            let class = FailureClass::decode(e.token_index);
            let t = secs(e.at);
            let dup = heads
                .iter()
                .any(|&(c, w, t0)| c == class && w == e.worker && t - t0 < DETECT_MERGE_WINDOW_S);
            if !dup {
                heads.push((class, e.worker, t));
            }
        }

        let mut incidents = Vec::with_capacity(heads.len());
        for (i, &(class, worker, t_detect)) in heads.iter().enumerate() {
            // Attribution window: up to the next confirmed death of the
            // same class (or the end of the run).
            let window_end = heads
                .iter()
                .skip(i + 1)
                .filter(|&&(c, _, _)| c == class)
                .map(|&(_, _, t)| t)
                .fold(f64::INFINITY, f64::min);
            let in_window = |t: f64| t >= t_detect && t < window_end;

            // Victim set.
            let mut victims: Vec<u64> = Vec::new();
            match class {
                FailureClass::Ew => {
                    // Every request whose token stream straddles the
                    // death stalled on the reroute.
                    for (&req, toks) in &tokens {
                        if toks.iter().any(|&t| t < t_detect) && toks.iter().any(|&t| in_window(t))
                        {
                            victims.push(req);
                        }
                    }
                    victims.sort_unstable();
                }
                // AW deaths and control-plane failovers (store replica,
                // gateway shard, orchestrator) all surface per-request
                // recovery actions in the window; an incident with no
                // such actions (e.g. a survivable store kill, a planned
                // orch promotion) simply has no victims.
                _ => {
                    for e in &events {
                        let recovery = matches!(
                            e.kind,
                            EventKind::Adopted
                                | EventKind::Migrated
                                | EventKind::RestoreStarted
                                | EventKind::Restored
                        );
                        if recovery && in_window(secs(e.at)) && !victims.contains(&e.request) {
                            victims.push(e.request);
                        }
                    }
                }
            }

            let stalls = victims
                .iter()
                .map(|&req| {
                    let toks = tokens.get(&req).map(Vec::as_slice).unwrap_or(&[]);
                    let t_stall_start = toks
                        .iter()
                        .rev()
                        .find(|&&t| t < t_detect)
                        .copied()
                        .or_else(|| submitted.get(&req).copied())
                        .unwrap_or(t_detect);
                    let detect_s = (t_detect - t_stall_start).max(0.0);

                    // First reroute action for this victim.
                    let t_reroute = events
                        .iter()
                        .filter(|e| match class {
                            FailureClass::Ew => {
                                e.kind == EventKind::Rerouted && e.request == worker as u64
                            }
                            _ => {
                                matches!(e.kind, EventKind::Adopted | EventKind::Migrated)
                                    && e.request == req
                            }
                        })
                        .map(|e| secs(e.at))
                        .find(|&t| in_window(t));

                    // Checkpoint restore, when the path exercised one.
                    let t_pull = events
                        .iter()
                        .filter(|e| e.kind == EventKind::RestoreStarted && e.request == req)
                        .map(|e| secs(e.at))
                        .find(|&t| in_window(t));
                    let t_installed = events
                        .iter()
                        .filter(|e| e.kind == EventKind::Restored && e.request == req)
                        .map(|e| secs(e.at))
                        .find(|&t| t_pull.is_some_and(|p| t >= p) && in_window(t));
                    let restore_s = match (t_pull, t_installed) {
                        (Some(p), Some(r)) => (r - p).max(0.0),
                        _ => 0.0,
                    };

                    let reroute_s = t_reroute.map(|t| (t - t_detect).max(0.0)).unwrap_or(0.0);
                    let t_rec_end = [Some(t_detect), t_reroute, t_pull, t_installed]
                        .into_iter()
                        .flatten()
                        .fold(t_detect, f64::max);
                    let t_next = toks.iter().copied().find(|&t| t >= t_detect);
                    let recompute_s =
                        t_next.map(|t| (t - t_rec_end).max(0.0)).unwrap_or(0.0);
                    let total_stall_s = t_next
                        .map(|t| (t - t_stall_start).max(0.0))
                        .unwrap_or(detect_s + reroute_s + restore_s);
                    VictimStall {
                        request: req,
                        detect_s,
                        reroute_s,
                        restore_s,
                        recompute_s,
                        total_stall_s,
                    }
                })
                .collect();

            incidents.push(Incident { class, worker, t_detect_s: t_detect, victims: stalls });
        }
        RecoveryReport { incidents }
    }

    pub fn is_empty(&self) -> bool {
        self.incidents.is_empty()
    }

    /// Worst per-victim detect phase over every incident (0 if none).
    pub fn max_detect_s(&self) -> f64 {
        self.victims().map(|v| v.detect_s).fold(0.0, f64::max)
    }

    /// Worst per-victim total stall over every incident (0 if none).
    pub fn max_total_stall_s(&self) -> f64 {
        self.victims().map(|v| v.total_stall_s).fold(0.0, f64::max)
    }

    pub fn victims(&self) -> impl Iterator<Item = &VictimStall> {
        self.incidents.iter().flat_map(|i| i.victims.iter())
    }

    /// Compact one-incident-per-line rendering for assertion messages.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for i in &self.incidents {
            let _ = writeln!(
                out,
                "incident {}{} detected at {:.4}s ({} victims)",
                i.class.name(),
                i.worker,
                i.t_detect_s,
                i.victims.len()
            );
            for v in &i.victims {
                let _ = writeln!(
                    out,
                    "  req={} detect={:.4}s reroute={:.4}s restore={:.4}s \
                     recompute={:.4}s total={:.4}s",
                    v.request, v.detect_s, v.reroute_s, v.restore_s, v.recompute_s, v.total_stall_s
                );
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EventLog;
    use std::time::Duration;

    fn ev(t_ms: u64, kind: EventKind, req: u64, tok: u32) -> Event {
        Event {
            at: Duration::from_millis(t_ms),
            kind,
            request: req,
            token_index: tok,
            worker: 0,
        }
    }

    #[test]
    fn ttft_tbt_and_stall() {
        let events = vec![
            ev(0, EventKind::Submitted, 1, 0),
            ev(100, EventKind::Token, 1, 0),  // TTFT = 100ms
            ev(150, EventKind::Token, 1, 1),  // TBT 50
            ev(200, EventKind::Token, 1, 2),  // TBT 50
            ev(900, EventKind::Token, 1, 3),  // TBT 700 (stall)
            ev(950, EventKind::Finished, 1, 0),
        ];
        let a = RunAnalysis::from_events(&events, 0.5);
        assert_eq!(a.ttft_ms.len(), 1);
        assert!((a.ttft_ms[0] - 100.0).abs() < 1.0);
        assert_eq!(a.tbt_ms.len(), 3);
        assert!((a.max_token_gap_s - 0.7).abs() < 0.01);
        assert!((a.max_gap_start_s - 0.2).abs() < 0.01);
        assert_eq!(a.total_tokens, 4);
        assert_eq!(a.finished_requests, 1);
        let tbt = a.tbt();
        assert!((tbt.median_ms - 50.0).abs() < 1.0);
    }

    #[test]
    fn multi_request_interleaving() {
        let events = vec![
            ev(0, EventKind::Submitted, 1, 0),
            ev(10, EventKind::Submitted, 2, 0),
            ev(50, EventKind::Token, 1, 0),
            ev(60, EventKind::Token, 2, 0),
            ev(70, EventKind::Token, 1, 1), // TBT(1) = 20
            ev(90, EventKind::Token, 2, 1), // TBT(2) = 30
        ];
        let a = RunAnalysis::from_events(&events, 1.0);
        assert_eq!(a.ttft_ms.len(), 2);
        assert_eq!(a.tbt_ms.len(), 2);
        assert!((a.tbt_ms[0] - 20.0).abs() < 1e-9 && (a.tbt_ms[1] - 30.0).abs() < 1e-9);
        // Cluster-wide gaps are between consecutive tokens of any request:
        // 50,60,70,90 ms -> max gap 20 ms.
        assert!((a.max_token_gap_s - 0.02).abs() < 0.001);
        let (g, t) = a.max_gap_after(0.065);
        assert!((g - 0.02).abs() < 1e-9 && (t - 0.07).abs() < 1e-9);
    }

    #[test]
    fn counts_preemptions_and_rejections() {
        let events = vec![
            ev(0, EventKind::Submitted, 1, 0),
            ev(5, EventKind::Rejected, 1, 0),
            ev(10, EventKind::Submitted, 2, 0),
            ev(50, EventKind::Token, 2, 0),
            ev(60, EventKind::Preempted, 2, 0),
            ev(90, EventKind::Migrated, 2, 0),
            ev(120, EventKind::Token, 2, 1),
            ev(121, EventKind::Finished, 2, 0),
        ];
        let a = RunAnalysis::from_events(&events, 1.0);
        assert_eq!(a.preemptions, 1);
        assert_eq!(a.rejections, 1);
        assert_eq!(a.finished_requests, 1);
        assert_eq!(a.total_tokens, 2);
    }

    #[test]
    fn empty_log() {
        let log = EventLog::new();
        let a = RunAnalysis::from_log(&log, 1.0);
        assert_eq!(a.total_tokens, 0);
        assert!(a.ttft().median_ms.is_nan());
    }

    #[test]
    fn max_gap_after_edge_cases() {
        let events = vec![
            ev(0, EventKind::Submitted, 1, 0),
            ev(100, EventKind::Token, 1, 0),
            ev(150, EventKind::Token, 1, 1),
            ev(400, EventKind::Token, 1, 2),
        ];
        let a = RunAnalysis::from_events(&events, 1.0);
        // t0 past the last token: no gap starts after it.
        assert_eq!(a.max_gap_after(0.5), (0.0, 0.0));
        // t0 exactly on a token time: the gap starting there counts.
        let (g, t) = a.max_gap_after(0.15);
        assert!((g - 0.25).abs() < 1e-9 && (t - 0.15).abs() < 1e-9);
        // Single-token run: windows(2) is empty, no gap.
        let one = vec![ev(0, EventKind::Submitted, 1, 0), ev(10, EventKind::Token, 1, 0)];
        let a1 = RunAnalysis::from_events(&one, 1.0);
        assert_eq!(a1.max_gap_after(0.0), (0.0, 0.0));
        // Empty run.
        let a0 = RunAnalysis::from_events(&[], 1.0);
        assert_eq!(a0.max_gap_after(0.0), (0.0, 0.0));
    }

    #[test]
    fn from_events_accepts_every_event_kind() {
        let events: Vec<Event> =
            EventKind::ALL.iter().enumerate().map(|(i, &k)| ev(i as u64, k, 1, 0)).collect();
        let a = RunAnalysis::from_events(&events, 1.0);
        assert_eq!(a.total_tokens, 1);
        // And the recovery decomposition tolerates the same stew.
        let _ = RecoveryReport::from_events(&events);
    }

    #[test]
    fn recovery_report_decomposes_an_aw_adoption() {
        // Hand-built lifecycle: tokens flow, AW 0 dies at t=100ms, death
        // confirmed at 130ms, adopted at 150ms, restore 160→200ms, first
        // post-fault token at 240ms.
        let mut events = vec![
            ev(0, EventKind::Submitted, 7, 0),
            ev(50, EventKind::Token, 7, 0),
            ev(100, EventKind::Token, 7, 1),
        ];
        events.push(Event {
            at: Duration::from_millis(130),
            kind: EventKind::Detected,
            request: 0,
            token_index: 0, // AW class
            worker: 0,
        });
        events.push(ev(150, EventKind::Adopted, 7, 0));
        events.push(ev(160, EventKind::RestoreStarted, 7, 0));
        events.push(ev(200, EventKind::Restored, 7, 0));
        events.push(ev(240, EventKind::Token, 7, 2));
        let r = RecoveryReport::from_events(&events);
        assert_eq!(r.incidents.len(), 1);
        let i = &r.incidents[0];
        assert_eq!(i.class, FailureClass::Aw);
        assert_eq!(i.worker, 0);
        assert_eq!(i.victims.len(), 1);
        let v = &i.victims[0];
        assert_eq!(v.request, 7);
        assert!((v.detect_s - 0.030).abs() < 1e-9, "detect {}", v.detect_s);
        assert!((v.reroute_s - 0.020).abs() < 1e-9, "reroute {}", v.reroute_s);
        assert!((v.restore_s - 0.040).abs() < 1e-9, "restore {}", v.restore_s);
        assert!((v.recompute_s - 0.040).abs() < 1e-9, "recompute {}", v.recompute_s);
        assert!((v.total_stall_s - 0.140).abs() < 1e-9, "total {}", v.total_stall_s);
        assert!((r.max_total_stall_s() - 0.140).abs() < 1e-9);
        assert!((r.max_detect_s() - 0.030).abs() < 1e-9);
        assert!(r.render().contains("req=7"));
    }

    #[test]
    fn recovery_report_merges_duplicate_detections_and_handles_ew_reroutes() {
        // EW 2 dies: the REFE detects at 60ms and replays at 62ms; the
        // orchestrator confirms the same death at 75ms (merged). Request
        // 1 straddles the death, request 9 finished long before it.
        let det = |t_ms: u64, class: u32, worker: u32| Event {
            at: Duration::from_millis(t_ms),
            kind: EventKind::Detected,
            request: 0,
            token_index: class,
            worker,
        };
        let events = vec![
            ev(0, EventKind::Submitted, 1, 0),
            ev(0, EventKind::Submitted, 9, 0),
            ev(10, EventKind::Token, 9, 0),
            ev(11, EventKind::Finished, 9, 0),
            ev(50, EventKind::Token, 1, 0),
            det(60, 1, 2),
            Event {
                at: Duration::from_millis(62),
                kind: EventKind::Rerouted,
                request: 2, // failed EW index
                token_index: 0,
                worker: 0,
            },
            det(75, 1, 2), // duplicate confirmation, merged away
            ev(90, EventKind::Token, 1, 1),
        ];
        let r = RecoveryReport::from_events(&events);
        assert_eq!(r.incidents.len(), 1, "duplicate detections must merge:\n{}", r.render());
        let i = &r.incidents[0];
        assert_eq!(i.class, FailureClass::Ew);
        assert_eq!(i.worker, 2);
        // Request 9 finished before the fault: not a victim.
        assert_eq!(i.victims.len(), 1);
        let v = &i.victims[0];
        assert_eq!(v.request, 1);
        assert!((v.detect_s - 0.010).abs() < 1e-9);
        assert!((v.reroute_s - 0.002).abs() < 1e-9);
        assert_eq!(v.restore_s, 0.0, "EW reroute exercises no checkpoint restore");
        assert!((v.recompute_s - 0.028).abs() < 1e-9);
        assert!((v.total_stall_s - 0.040).abs() < 1e-9);
    }

    #[test]
    fn recovery_report_attributes_control_plane_classes() {
        let det = |t_ms: u64, class: u32, worker: u32| Event {
            at: Duration::from_millis(t_ms),
            kind: EventKind::Detected,
            request: 0,
            token_index: class,
            worker,
        };
        // A store-replica death (class 2) stalls request 3 through a
        // re-driven restore; a later orchestrator failover (class 4) has
        // no per-request fallout.
        let events = vec![
            ev(0, EventKind::Submitted, 3, 0),
            ev(50, EventKind::Token, 3, 0),
            det(60, FailureClass::Store.code(), 0),
            ev(70, EventKind::RestoreStarted, 3, 0),
            ev(90, EventKind::Restored, 3, 0),
            ev(120, EventKind::Token, 3, 1),
            det(400, FailureClass::Orch.code(), 0),
        ];
        let r = RecoveryReport::from_events(&events);
        assert_eq!(r.incidents.len(), 2, "{}", r.render());
        let store = &r.incidents[0];
        assert_eq!(store.class, FailureClass::Store);
        assert_eq!(store.class.name(), "store");
        assert_eq!(store.victims.len(), 1);
        let v = &store.victims[0];
        assert_eq!(v.request, 3);
        assert!((v.restore_s - 0.020).abs() < 1e-9, "restore {}", v.restore_s);
        assert!((v.total_stall_s - 0.070).abs() < 1e-9, "total {}", v.total_stall_s);
        let orch = &r.incidents[1];
        assert_eq!(orch.class, FailureClass::Orch);
        assert!(orch.victims.is_empty(), "planned promotion has no victims");
        // Gateway class decodes too.
        let g = RecoveryReport::from_events(&[
            ev(0, EventKind::Submitted, 1, 0),
            det(10, FailureClass::Gateway.code(), 1),
        ]);
        assert_eq!(g.incidents[0].class, FailureClass::Gateway);
        assert_eq!(g.incidents[0].worker, 1);
    }

    #[test]
    fn recovery_report_is_empty_without_detections() {
        let events = vec![
            ev(0, EventKind::Submitted, 1, 0),
            ev(50, EventKind::Token, 1, 0),
            ev(90, EventKind::Migrated, 1, 0), // planned drain, no death
        ];
        assert!(RecoveryReport::from_events(&events).is_empty());
    }
}
