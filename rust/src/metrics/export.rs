//! Exporters: Chrome/Perfetto trace-event JSON from a span log, and a
//! Prometheus text-exposition snapshot of a [`ClusterReport`]
//! (DESIGN.md §14).
//!
//! Both are plain-text formats emitted through the in-repo `util::json`
//! builders (no serde), so any scenario or chaos run can dump an
//! artifact that standard tooling (ui.perfetto.dev, promtool) loads
//! directly.

use super::trace::Span;
use crate::coordinator::cluster::ClusterReport;
use crate::util::json::{arr, num, obj, s, Json};
use std::fmt::Write as _;

/// Chrome trace-event JSON (the Perfetto/`chrome://tracing` format):
/// one complete-duration ("ph":"X") event per span, microsecond
/// timestamps, `pid` 0 and `tid` = worker index so each worker renders
/// as its own track.
pub fn perfetto_json(spans: &[Span]) -> Json {
    let events: Vec<Json> = spans
        .iter()
        .map(|sp| {
            let ts_us = sp.start.as_secs_f64() * 1e6;
            let dur_us = (sp.end.saturating_sub(sp.start)).as_secs_f64() * 1e6;
            obj(vec![
                ("name", s(sp.kind.name())),
                ("cat", s(sp.kind.category())),
                ("ph", s("X")),
                ("ts", num(ts_us)),
                ("dur", num(dur_us)),
                ("pid", num(0.0)),
                ("tid", num(sp.worker as f64)),
                (
                    "args",
                    obj(vec![
                        ("request", num(sp.request as f64)),
                        ("aux", num(sp.aux as f64)),
                    ]),
                ),
            ])
        })
        .collect();
    obj(vec![("traceEvents", arr(events)), ("displayTimeUnit", s("ms"))])
}

fn metric(out: &mut String, name: &str, help: &str, kind: &str, value: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {value}");
}

/// Prometheus text exposition of a finished run's [`ClusterReport`]:
/// request lifecycle counters, failure/recovery counters, elastic
/// scaling counters, KV prefix-sharing stats, and the headline latency
/// summaries. Empty-sample summaries expose as `NaN`, which the
/// exposition format permits.
pub fn prometheus_text(r: &ClusterReport) -> String {
    let mut out = String::with_capacity(4096);
    let a = &r.analysis;
    metric(
        &mut out,
        "tarragon_requests_submitted_total",
        "Requests submitted to the gateway.",
        "counter",
        r.submitted as f64,
    );
    metric(
        &mut out,
        "tarragon_requests_finished_total",
        "Requests that generated their full output.",
        "counter",
        r.finished as f64,
    );
    metric(
        &mut out,
        "tarragon_requests_rejected_total",
        "Requests rejected at admission (oversized).",
        "counter",
        r.rejected as f64,
    );
    metric(
        &mut out,
        "tarragon_preemptions_total",
        "Requests preempted under KV pressure or planned drains.",
        "counter",
        r.preemptions as f64,
    );
    metric(
        &mut out,
        "tarragon_aw_failures_total",
        "Attention-worker deaths confirmed by the orchestrator.",
        "counter",
        r.aw_failures as f64,
    );
    metric(
        &mut out,
        "tarragon_ew_failures_total",
        "Expert-worker deaths confirmed by the orchestrator.",
        "counter",
        r.ew_failures as f64,
    );
    metric(
        &mut out,
        "tarragon_coarse_restarts_total",
        "Full cluster restarts (baseline recovery mode).",
        "counter",
        r.restarts as f64,
    );
    metric(
        &mut out,
        "tarragon_scale_outs_total",
        "Fresh EWs provisioned by elastic scaling.",
        "counter",
        r.scale_outs as f64,
    );
    metric(
        &mut out,
        "tarragon_scale_ins_total",
        "EWs retired by elastic scaling.",
        "counter",
        r.scale_ins as f64,
    );
    metric(
        &mut out,
        "tarragon_shadow_promotions_total",
        "Shadow replicas promoted to primary.",
        "counter",
        r.shadow_promotions as f64,
    );
    metric(
        &mut out,
        "tarragon_scale_rejected_total",
        "Scale-in refusals (last-replica guard, liveness checks).",
        "counter",
        r.scale_rejected as f64,
    );
    metric(
        &mut out,
        "tarragon_store_failovers_total",
        "Checkpoint-store replica deaths survived by fan-out replication.",
        "counter",
        r.store_failovers as f64,
    );
    metric(
        &mut out,
        "tarragon_gateway_failovers_total",
        "Gateway shard deaths survived by consistent-hash re-admission.",
        "counter",
        r.gateway_failovers as f64,
    );
    metric(
        &mut out,
        "tarragon_orch_promotions_total",
        "Standby orchestrator promotions (planned or failover).",
        "counter",
        r.orch_promotions as f64,
    );
    metric(
        &mut out,
        "tarragon_store_replica_lag",
        "Accepted-commit spread (max - min) across live store replicas \
         at run end (0 when replicas agree or K = 1).",
        "gauge",
        r.store_replica_lag as f64,
    );
    metric(
        &mut out,
        "tarragon_kv_prefix_hits_total",
        "Prefill/restore pages satisfied by prefix sharing.",
        "counter",
        r.sharing.prefix_hits as f64,
    );
    metric(
        &mut out,
        "tarragon_kv_cow_breaks_total",
        "Copy-on-write privatizations of shared KV pages.",
        "counter",
        r.sharing.cow_breaks as f64,
    );
    metric(
        &mut out,
        "tarragon_kv_pages_shared_peak",
        "Peak number of KV pages concurrently shared.",
        "gauge",
        r.sharing.pages_shared as f64,
    );
    metric(
        &mut out,
        "tarragon_refe_pool_misses_total",
        "REFE scratch-pool misses (dispatches that allocated; 0 in \
         steady state — the zero-alloc decode gauge).",
        "counter",
        r.pool_misses as f64,
    );
    metric(
        &mut out,
        "tarragon_tokens_total",
        "Output tokens emitted cluster-wide.",
        "counter",
        a.total_tokens as f64,
    );
    metric(
        &mut out,
        "tarragon_throughput_tokens_per_second",
        "Output tokens per second over the whole run.",
        "gauge",
        a.throughput_tps,
    );
    metric(
        &mut out,
        "tarragon_ttft_median_milliseconds",
        "Median time to first token.",
        "gauge",
        a.ttft().median_ms,
    );
    metric(
        &mut out,
        "tarragon_ttft_p95_milliseconds",
        "95th-percentile time to first token.",
        "gauge",
        a.ttft().p95_ms,
    );
    metric(
        &mut out,
        "tarragon_tbt_median_milliseconds",
        "Median gap between consecutive tokens of a request.",
        "gauge",
        a.tbt().median_ms,
    );
    metric(
        &mut out,
        "tarragon_tbt_p95_milliseconds",
        "95th-percentile gap between consecutive tokens of a request.",
        "gauge",
        a.tbt().p95_ms,
    );
    metric(
        &mut out,
        "tarragon_max_token_gap_seconds",
        "Longest cluster-wide token-stream stall.",
        "gauge",
        a.max_token_gap_s,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::trace::SpanKind;
    use crate::metrics::{RunAnalysis, SharingStats};
    use std::time::Duration;

    fn span(kind: SpanKind, start_ms: u64, dur_ms: u64, worker: u32) -> Span {
        Span {
            kind,
            request: 5,
            worker,
            aux: 2,
            start: Duration::from_millis(start_ms),
            end: Duration::from_millis(start_ms + dur_ms),
        }
    }

    #[test]
    fn perfetto_export_round_trips_through_the_parser() {
        let spans = vec![
            span(SpanKind::DecodeStep, 10, 2, 0),
            span(SpanKind::RestoreInstall, 40, 8, 1),
        ];
        let text = perfetto_json(&spans).to_string();
        let doc = Json::parse(&text).expect("exported trace must be valid JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let e = &events[1];
        assert_eq!(e.get("name").unwrap().as_str(), Some("restore_install"));
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("ts").unwrap().as_f64(), Some(40_000.0));
        assert_eq!(e.get("dur").unwrap().as_f64(), Some(8_000.0));
        assert_eq!(e.get("tid").unwrap().as_u64(), Some(1));
        assert_eq!(e.get("args").unwrap().get("request").unwrap().as_u64(), Some(5));
    }

    #[test]
    fn prometheus_text_exposes_the_report() {
        let r = ClusterReport {
            analysis: RunAnalysis::from_events(&[], 1.0),
            submitted: 4,
            finished: 3,
            aw_failures: 1,
            ew_failures: 2,
            restarts: 0,
            preemptions: 5,
            rejected: 1,
            scale_outs: 1,
            scale_ins: 0,
            shadow_promotions: 1,
            scale_rejected: 0,
            store_failovers: 1,
            gateway_failovers: 2,
            orch_promotions: 1,
            store_replica_lag: 3,
            sharing: SharingStats { prefix_hits: 7, cow_breaks: 1, pages_shared: 3 },
            pool_misses: 2,
        };
        let text = prometheus_text(&r);
        assert!(text.contains("tarragon_requests_submitted_total 4"));
        assert!(text.contains("tarragon_aw_failures_total 1"));
        assert!(text.contains("tarragon_ew_failures_total 2"));
        assert!(text.contains("tarragon_store_failovers_total 1"));
        assert!(text.contains("tarragon_gateway_failovers_total 2"));
        assert!(text.contains("tarragon_orch_promotions_total 1"));
        assert!(text.contains("tarragon_store_replica_lag 3"));
        assert!(text.contains("tarragon_kv_prefix_hits_total 7"));
        assert!(text.contains("tarragon_refe_pool_misses_total 2"));
        // Empty-sample latency summaries are NaN — legal in the
        // exposition format.
        assert!(text.contains("tarragon_ttft_median_milliseconds NaN"));
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line:?}"
            );
        }
    }
}
