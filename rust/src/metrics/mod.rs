//! Metrics: the per-token event log every experiment harness consumes.
//!
//! The gateway records one event per emitted token (plus request lifecycle
//! events); analysis turns the log into TTFT/TBT distributions, throughput
//! timelines (Fig. 9), and latency-vs-load curves (Fig. 10/11).

pub mod analysis;

use std::sync::Mutex;
use std::time::{Duration, Instant};

pub use analysis::{LatencySummary, RunAnalysis};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Request submitted to the gateway.
    Submitted,
    /// Request admitted to an AW (prefill begins).
    Admitted,
    /// One output token emitted (first token => TTFT sample).
    Token,
    /// Request finished (generated max tokens).
    Finished,
    /// Request was migrated to another AW by failure recovery.
    Migrated,
}

#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub at: Instant,
    pub kind: EventKind,
    pub request: u64,
    /// Token index within the request (for Token events).
    pub token_index: u32,
    /// Worker index involved (AW for Token/Admitted/Migrated).
    pub worker: u32,
}

/// Thread-safe append-only event log with a fixed epoch.
pub struct EventLog {
    epoch: Instant,
    events: Mutex<Vec<Event>>,
}

impl Default for EventLog {
    fn default() -> Self {
        Self::new()
    }
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog { epoch: Instant::now(), events: Mutex::new(Vec::new()) }
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    pub fn record(&self, kind: EventKind, request: u64, token_index: u32, worker: u32) {
        self.events.lock().unwrap().push(Event {
            at: Instant::now(),
            kind,
            request,
            token_index,
            worker,
        });
    }

    pub fn snapshot(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Seconds since the log's epoch for an event time.
    pub fn secs(&self, at: Instant) -> f64 {
        at.duration_since(self.epoch).as_secs_f64()
    }
}

/// Convenience: duration as milliseconds f64.
pub fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let log = EventLog::new();
        log.record(EventKind::Submitted, 1, 0, 0);
        log.record(EventKind::Token, 1, 0, 2);
        let snap = log.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[1].kind, EventKind::Token);
        assert_eq!(snap[1].worker, 2);
        assert!(log.secs(snap[1].at) >= log.secs(snap[0].at));
    }
}
