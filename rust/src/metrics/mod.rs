//! Metrics: the per-token event log every experiment harness consumes.
//!
//! The gateway records one event per emitted token (plus request lifecycle
//! events); analysis turns the log into TTFT/TBT distributions, throughput
//! timelines (Fig. 9), and latency-vs-load curves (Fig. 10/11).
//!
//! Event timestamps are offsets from the log's creation, read through a
//! [`Clock`] — under the scenario harness's virtual clock an event log is
//! fully deterministic, and [`EventLog::render`] produces the canonical
//! text form the determinism tests compare byte-for-byte.

pub mod analysis;
pub mod export;
pub mod hist;
pub mod trace;

use crate::util::clock::Clock;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

pub use analysis::{FailureClass, LatencySummary, RecoveryReport, RunAnalysis};
pub use hist::LogHistogram;

/// KV prefix-sharing counters (DESIGN.md §13), summed over all AW
/// arenas by [`crate::coordinator::cluster::Spawner::sharing_totals`].
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SharingStats {
    /// Prefill/restore pages satisfied by a refcount bump on an already-
    /// sealed identical page (no recompute write-back, no fresh page).
    pub prefix_hits: u64,
    /// Copy-on-write privatizations: writes that landed on a page with
    /// refs > 1 and paid one page copy.
    pub cow_breaks: u64,
    /// Peak number of pages concurrently shared (refs > 1).
    pub pages_shared: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Request submitted to the gateway.
    Submitted,
    /// Request admitted to an AW (prefill begins).
    Admitted,
    /// One output token emitted (first token => TTFT sample).
    Token,
    /// Request finished (generated max tokens).
    Finished,
    /// Request was migrated to another AW (failure recovery, preemption
    /// re-admission, or a planned drain).
    Migrated,
    /// Request was rejected at admission (oversized prompt / KV
    /// footprint); a stream-level error is surfaced instead of output.
    Rejected,
    /// Request was preempted under KV pressure or a drain: checkpoint
    /// flushed, pages evicted, parked for re-admission.
    Preempted,
    /// Expert tier grew: a fresh EW was provisioned (`request` = expert
    /// id + 1 it hosts, or 0 for a universal shadow; `worker` = new EW).
    ScaleOut,
    /// Expert tier shrank: an EW was retired after remapping its
    /// primaries onto the remaining candidates (`worker` = retired EW).
    ScaleIn,
    /// A hot expert's shadow replica became primary — warm scale-out,
    /// no weight upload (`request` = expert id, `worker` = promoted EW).
    ShadowPromoted,
    /// A worker death was confirmed (failure-lifecycle; DESIGN.md §14).
    /// `worker` = failed node index; `token_index` encodes the class
    /// (0 = AW, 1 = EW, 2 = store replica, 3 = gateway shard,
    /// 4 = orchestrator); `request` = 0 (cluster-scoped).
    Detected,
    /// A REFE replayed in-flight expert rows around a dead EW
    /// (`request` = failed EW index, `worker` = rerouting AW).
    Rerouted,
    /// An orphaned committed request was assigned to a surviving AW
    /// (`worker` = adopting AW).
    Adopted,
    /// The adopting AW asked the store for the request's checkpoint
    /// (`worker` = adopting AW).
    RestoreStarted,
    /// The checkpoint was installed and the request rejoined the active
    /// decode set (`worker` = adopting AW).
    Restored,
    /// A checkpoint-store replica failed; survivors keep serving
    /// (`worker` = dead replica index; DESIGN.md §15).
    StoreFailover,
    /// A gateway shard failed; its requests re-admitted through the
    /// surviving shards (`worker` = dead shard index).
    GatewayFailover,
    /// The standby orchestrator took over the role address (`worker` = 0;
    /// `token_index` = 1 for a planned promotion, 0 for failover).
    OrchPromoted,
}

impl EventKind {
    /// Every variant, in declaration order — the drift-guard tests walk
    /// this to prove `name`/`parse` and every consumer stay exhaustive.
    pub const ALL: [EventKind; 18] = [
        EventKind::Submitted,
        EventKind::Admitted,
        EventKind::Token,
        EventKind::Finished,
        EventKind::Migrated,
        EventKind::Rejected,
        EventKind::Preempted,
        EventKind::ScaleOut,
        EventKind::ScaleIn,
        EventKind::ShadowPromoted,
        EventKind::Detected,
        EventKind::Rerouted,
        EventKind::Adopted,
        EventKind::RestoreStarted,
        EventKind::Restored,
        EventKind::StoreFailover,
        EventKind::GatewayFailover,
        EventKind::OrchPromoted,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EventKind::Submitted => "submitted",
            EventKind::Admitted => "admitted",
            EventKind::Token => "token",
            EventKind::Finished => "finished",
            EventKind::Migrated => "migrated",
            EventKind::Rejected => "rejected",
            EventKind::Preempted => "preempted",
            EventKind::ScaleOut => "scale_out",
            EventKind::ScaleIn => "scale_in",
            EventKind::ShadowPromoted => "shadow_promoted",
            EventKind::Detected => "detected",
            EventKind::Rerouted => "rerouted",
            EventKind::Adopted => "adopted",
            EventKind::RestoreStarted => "restore_started",
            EventKind::Restored => "restored",
            EventKind::StoreFailover => "store_failover",
            EventKind::GatewayFailover => "gateway_failover",
            EventKind::OrchPromoted => "orch_promoted",
        }
    }

    /// Inverse of [`EventKind::name`]; `None` for unknown names.
    pub fn parse(name: &str) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.name() == name)
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Offset from the log's epoch (its creation instant).
    pub at: Duration,
    pub kind: EventKind,
    pub request: u64,
    /// Token index within the request (for Token events).
    pub token_index: u32,
    /// Worker index involved (AW for Token/Admitted/Migrated).
    pub worker: u32,
}

/// Fixed growth quantum for the event vector: once the pre-sized
/// capacity is exhausted, `record` reserves exactly this many more
/// slots, so a long soak run pays small constant-size reallocations
/// under the lock instead of doubling ever-larger buffers.
pub const EVENT_GROW_CHUNK: usize = 1024;

/// Thread-safe append-only event log with a rebasable epoch.
pub struct EventLog {
    clock: Clock,
    /// Clock reading (nanos) at log creation or the last [`rebase`];
    /// `Event::at` is relative to this. Atomic so the epoch can be
    /// re-pinned after cluster bring-up without blocking recorders.
    ///
    /// [`rebase`]: EventLog::rebase
    start_nanos: AtomicU64,
    events: Mutex<Vec<Event>>,
}

impl Default for EventLog {
    fn default() -> Self {
        Self::new()
    }
}

impl EventLog {
    /// A wall-clock log whose epoch is "now".
    pub fn new() -> EventLog {
        Self::with_clock(Clock::wall())
    }

    /// A log timestamped by an explicit clock; the epoch is the clock's
    /// current reading (so bring-up before log creation is excluded).
    pub fn with_clock(clock: Clock) -> EventLog {
        Self::with_clock_capacity(clock, 0)
    }

    /// Like [`with_clock`], pre-sizing the event vector so steady-state
    /// recording never reallocates until `capacity` events are logged
    /// (`trace.event_capacity` in config).
    ///
    /// [`with_clock`]: EventLog::with_clock
    pub fn with_clock_capacity(clock: Clock, capacity: usize) -> EventLog {
        let start = clock.now();
        EventLog {
            clock,
            start_nanos: AtomicU64::new(start.as_nanos() as u64),
            events: Mutex::new(Vec::with_capacity(capacity)),
        }
    }

    /// Re-pin the epoch to the clock's current reading. Called once
    /// after cluster bring-up so event timestamps exclude worker
    /// provisioning time; recording before a rebase is allowed (the
    /// events keep their old offsets).
    pub fn rebase(&self) {
        self.start_nanos.store(self.clock.now().as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn record(&self, kind: EventKind, request: u64, token_index: u32, worker: u32) {
        let start = Duration::from_nanos(self.start_nanos.load(Ordering::Relaxed));
        let at = self.clock.now().saturating_sub(start);
        self.record_at(at, kind, request, token_index, worker);
    }

    /// Record an event at an explicit epoch offset, bypassing the clock.
    /// The macro-simulator uses this to stamp events with exact actor
    /// times. Callers should append in nondecreasing `at` order (a DES
    /// pops its queue in time order, so this is natural); consumers that
    /// need strict ordering (`RecoveryReport`) sort defensively anyway.
    pub fn record_at(
        &self,
        at: Duration,
        kind: EventKind,
        request: u64,
        token_index: u32,
        worker: u32,
    ) {
        let mut events = self.events.lock().unwrap();
        if events.len() == events.capacity() {
            events.reserve_exact(EVENT_GROW_CHUNK);
        }
        events.push(Event { at, kind, request, token_index, worker });
    }

    /// Current capacity of the event vector (growth-policy tests).
    pub fn capacity(&self) -> usize {
        self.events.lock().unwrap().capacity()
    }

    pub fn snapshot(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Seconds since the log's epoch for an event time.
    pub fn secs(&self, at: Duration) -> f64 {
        at.as_secs_f64()
    }

    /// Canonical text rendering: one line per event, in record order, with
    /// nanosecond timestamps. Two identical runs produce byte-identical
    /// renderings — the determinism tests' comparison format.
    pub fn render(&self) -> String {
        let events = self.snapshot();
        let mut out = String::with_capacity(events.len() * 48);
        for e in &events {
            let _ = writeln!(
                out,
                "{:012} {} req={} idx={} worker={}",
                e.at.as_nanos(),
                e.kind.name(),
                e.request,
                e.token_index,
                e.worker
            );
        }
        out
    }
}

/// Convenience: duration as milliseconds f64.
pub fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let log = EventLog::new();
        log.record(EventKind::Submitted, 1, 0, 0);
        log.record(EventKind::Token, 1, 0, 2);
        let snap = log.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[1].kind, EventKind::Token);
        assert_eq!(snap[1].worker, 2);
        assert!(log.secs(snap[1].at) >= log.secs(snap[0].at));
    }

    #[test]
    fn event_capacity_is_reserved_and_grows_in_chunks() {
        let log = EventLog::with_clock_capacity(Clock::wall(), 8);
        assert!(log.capacity() >= 8, "configured capacity must be pre-reserved");
        let base = log.capacity();
        for _ in 0..base {
            log.record(EventKind::Token, 1, 0, 0);
        }
        assert_eq!(log.capacity(), base, "recording within capacity must not grow");
        log.record(EventKind::Token, 1, 0, 0);
        assert_eq!(
            log.capacity(),
            base + EVENT_GROW_CHUNK,
            "overflow must grow by one fixed chunk, not by doubling"
        );
    }

    #[test]
    fn rebase_repins_the_epoch() {
        let clock = Clock::virtual_seeded(7);
        let _g = clock.register();
        let log = EventLog::with_clock(clock.clone());
        clock.sleep(Duration::from_millis(40));
        log.rebase();
        clock.sleep(Duration::from_millis(3));
        log.record(EventKind::Token, 1, 0, 0);
        assert_eq!(log.snapshot()[0].at, Duration::from_millis(3));
        clock.shutdown();
    }

    #[test]
    fn event_kind_names_round_trip_and_render_covers_every_variant() {
        let mut seen = std::collections::HashSet::new();
        for k in EventKind::ALL {
            assert!(seen.insert(k.name()), "duplicate event name {}", k.name());
            assert_eq!(EventKind::parse(k.name()), Some(k), "name round-trip for {}", k.name());
        }
        assert_eq!(EventKind::parse("bogus"), None);
        // Render one event of every kind: each line carries its name.
        let log = EventLog::new();
        for k in EventKind::ALL {
            log.record(k, 1, 0, 0);
        }
        let render = log.render();
        assert_eq!(render.lines().count(), EventKind::ALL.len());
        for (line, k) in render.lines().zip(EventKind::ALL) {
            assert!(line.contains(k.name()), "render line {line:?} missing {}", k.name());
        }
    }

    #[test]
    fn virtual_clock_timestamps_are_exact() {
        let clock = Clock::virtual_seeded(1);
        let _g = clock.register();
        clock.sleep(Duration::from_millis(5)); // pre-log time is excluded
        let log = EventLog::with_clock(clock.clone());
        clock.sleep(Duration::from_millis(250));
        log.record(EventKind::Token, 3, 0, 1);
        let snap = log.snapshot();
        assert_eq!(snap[0].at, Duration::from_millis(250));
        assert_eq!(
            log.render(),
            format!("{:012} token req=3 idx=0 worker=1\n", 250_000_000u64)
        );
        clock.shutdown();
    }
}
