//! Time as a service: the `Clock` every time consumer in the cluster
//! reads, sleeps, and waits through.
//!
//! Two implementations behind one handle:
//!
//! - [`Clock::wall`] — thin wrappers over `Instant::now` /
//!   `thread::sleep` / `mpsc::recv_timeout`. Zero behavior change for
//!   production-style runs, benches, and the experiment harnesses.
//! - [`Clock::virtual_seeded`] — a discrete-event scheduler. Threads
//!   register as participants; every blocking operation (sleep, channel
//!   recv) yields a cooperative *run token*, and at most one participant
//!   executes at a time. When every participant is blocked, the clock
//!   jumps straight to the earliest deadline — a multi-second failure
//!   scenario (probe timeouts, silence windows, restart storms) replays
//!   in milliseconds of wall time with **zero real sleeping**, and the
//!   interleaving of same-instant wakeups is chosen by a seeded,
//!   deterministic pick, so a scenario replays byte-identically for a
//!   given seed.
//!
//! Timestamps are `Duration`s since the clock's epoch (an `Instant`
//! cannot be fabricated, so virtual time needs its own representation).
//!
//! Rules for virtual-clock participants (enforced by panics where
//! possible):
//!
//! 1. Register (`clock.register()`) as the *first* statement of the
//!    thread body and hold the guard until the thread exits. Locals
//!    declared after the guard drop before it, so channel-disconnect
//!    notifications fire while the thread still holds the run token —
//!    deterministically.
//! 2. Never block except through the clock: `clock.sleep*`, or
//!    `recv*` on a [`Receiver`] created by [`channel`]. A raw
//!    `thread::sleep`/`recv_timeout` freezes virtual time for everyone.
//! 3. [`Clock::shutdown`] switches the clock to free-running teardown
//!    mode (sleeps return immediately, recvs fall back to real blocking)
//!    so `join`-based cleanup works after a run completes.
//!
//! A third implementation, [`Clock::manual`], serves the macro-sim
//! (DESIGN.md §16): a single-threaded discrete-event loop owns the
//! timeline and *sets* it explicitly as it drains an [`EventQueue`].
//! There are no participants and no blocking — `sleep` advances the
//! clock directly — so one thread can play the role of thousands of
//! workers while unmodified clock consumers (`EventLog`, policy code)
//! observe simulated time through the same handle.

use std::collections::{BTreeMap, BinaryHeap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, RecvError, RecvTimeoutError, TryRecvError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Clock handle
// ---------------------------------------------------------------------------

/// Shared handle to a time source. Cloning is cheap; all clones observe
/// the same timeline.
#[derive(Clone)]
pub enum Clock {
    Wall(WallClock),
    Virtual(Arc<VirtualClock>),
    /// Explicitly-set simulated time for single-threaded discrete-event
    /// loops (the macro-sim). No scheduling, no blocking: `sleep`
    /// advances the timeline in place.
    Manual(Arc<ManualClock>),
}

/// Real time relative to a fixed epoch.
#[derive(Clone, Copy)]
pub struct WallClock {
    epoch: Instant,
}

impl Default for Clock {
    fn default() -> Self {
        Clock::wall()
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Clock::Wall(_) => write!(f, "Clock::Wall"),
            Clock::Virtual(_) => write!(f, "Clock::Virtual"),
            Clock::Manual(_) => write!(f, "Clock::Manual"),
        }
    }
}

impl Clock {
    pub fn wall() -> Clock {
        Clock::Wall(WallClock { epoch: Instant::now() })
    }

    /// A virtual clock starting at t=0. `seed` drives the deterministic
    /// pick among waiters that become runnable at the same instant.
    pub fn virtual_seeded(seed: u64) -> Clock {
        Clock::Virtual(VirtualClock::new(seed))
    }

    /// A manually-stepped clock starting at t=0 (macro-sim event loops).
    pub fn manual() -> Clock {
        Clock::Manual(Arc::new(ManualClock::new()))
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual(_))
    }

    /// Time since the clock's epoch.
    pub fn now(&self) -> Duration {
        match self {
            Clock::Wall(w) => w.epoch.elapsed(),
            Clock::Virtual(v) => v.now(),
            Clock::Manual(m) => m.now(),
        }
    }

    pub fn sleep(&self, d: Duration) {
        if d.is_zero() {
            return;
        }
        match self {
            Clock::Wall(_) => std::thread::sleep(d),
            Clock::Virtual(v) => {
                let t = v.now() + d;
                v.sleep_until(t);
            }
            Clock::Manual(m) => m.advance(d),
        }
    }

    /// Sleep until the clock reads `t` (no-op if already past).
    pub fn sleep_until(&self, t: Duration) {
        match self {
            Clock::Wall(w) => {
                let now = w.epoch.elapsed();
                if t > now {
                    std::thread::sleep(t - now);
                }
            }
            Clock::Virtual(v) => v.sleep_until(t),
            Clock::Manual(m) => m.set(t),
        }
    }

    /// Register the calling thread as a scheduler participant. No-op
    /// under wall and manual time. The returned guard must live for the
    /// thread's whole life (drop order: declare it first).
    pub fn register(&self) -> ClockGuard {
        match self {
            Clock::Wall(_) | Clock::Manual(_) => ClockGuard { clock: None, tid: 0 },
            Clock::Virtual(v) => {
                let tid = v.register();
                ClockGuard { clock: Some(v.clone()), tid }
            }
        }
    }

    /// Enter free-running teardown mode (virtual only): all participants
    /// are released, sleeps return immediately, recvs block for real.
    pub fn shutdown(&self) {
        if let Clock::Virtual(v) = self {
            v.shutdown();
        }
    }
}

/// RAII participant registration (see [`Clock::register`]).
pub struct ClockGuard {
    clock: Option<Arc<VirtualClock>>,
    tid: u64,
}

impl Drop for ClockGuard {
    fn drop(&mut self) {
        if let Some(c) = self.clock.take() {
            c.deregister(self.tid);
        }
    }
}

/// Spawn a named thread that registers with `clock` as its first act.
/// Under a virtual clock, time is barred from advancing between this call
/// and the child's registration, so thread birth cannot race the
/// timeline — the single sanctioned way to create clock participants.
pub fn spawn_participant<F>(
    clock: &Clock,
    name: impl Into<String>,
    f: F,
) -> std::io::Result<std::thread::JoinHandle<()>>
where
    F: FnOnce() + Send + 'static,
{
    if let Clock::Virtual(v) = clock {
        v.announce_birth();
    }
    let child_clock = clock.clone();
    let result = std::thread::Builder::new().name(name.into()).spawn(move || {
        let _guard = child_clock.register();
        f();
    });
    if result.is_err() {
        if let Clock::Virtual(v) = clock {
            v.birth_cancelled();
        }
    }
    result
}

// ---------------------------------------------------------------------------
// Clock-aware channels
// ---------------------------------------------------------------------------

static NEXT_CHAN_ID: AtomicU64 = AtomicU64::new(1);

/// Create a channel whose receiver blocks through `clock`. Under a wall
/// clock this is exactly an `mpsc` channel; under a virtual clock every
/// send wakes the blocked receiver deterministically.
pub fn channel<T>(clock: &Clock) -> (Sender<T>, Receiver<T>) {
    let (tx, rx) = mpsc::channel();
    let id = NEXT_CHAN_ID.fetch_add(1, Ordering::Relaxed);
    (
        Sender { tx: Some(tx), clock: clock.clone(), id },
        Receiver { rx, clock: clock.clone(), id },
    )
}

pub struct Sender<T> {
    /// `Option` so `Drop` can release the inner sender *before* waking
    /// the receiver (which must then observe the disconnect).
    tx: Option<mpsc::Sender<T>>,
    clock: Clock,
    id: u64,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Sender { tx: self.tx.clone(), clock: self.clock.clone(), id: self.id }
    }
}

impl<T> Sender<T> {
    pub fn send(&self, v: T) -> Result<(), mpsc::SendError<T>> {
        self.tx.as_ref().expect("sender alive").send(v)?;
        if let Clock::Virtual(vc) = &self.clock {
            vc.chan_event(self.id);
        }
        Ok(())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Clock::Virtual(vc) = &self.clock {
            vc.chan_event(self.id);
        }
    }
}

pub struct Receiver<T> {
    rx: mpsc::Receiver<T>,
    clock: Clock,
    id: u64,
}

impl<T> Receiver<T> {
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        self.rx.try_recv()
    }

    pub fn recv(&self) -> Result<T, RecvError> {
        match &self.clock {
            // Manual clocks are single-threaded event loops; a blocking
            // recv there degenerates to the plain channel semantics.
            Clock::Wall(_) | Clock::Manual(_) => self.rx.recv(),
            Clock::Virtual(v) => match v.recv_loop(&self.rx, self.id, None) {
                Ok(x) => Ok(x),
                Err(_) => Err(RecvError),
            },
        }
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        match &self.clock {
            Clock::Wall(_) | Clock::Manual(_) => self.rx.recv_timeout(timeout),
            Clock::Virtual(v) => {
                let deadline = v.now() + timeout;
                v.recv_loop(&self.rx, self.id, Some(deadline))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The virtual clock
// ---------------------------------------------------------------------------

thread_local! {
    static VC_TID: std::cell::Cell<u64> = const { std::cell::Cell::new(u64::MAX) };
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum WaitKind {
    /// Pure timer: only a time advance can make it runnable.
    Sleep,
    /// Blocked on a channel: a send/disconnect on that channel (or the
    /// deadline) makes it runnable.
    Recv(u64),
}

struct Waiting {
    kind: WaitKind,
    deadline: Option<Duration>,
    ready: bool,
}

struct ThreadState {
    /// Deterministic ordering key: (thread name, per-name incarnation).
    /// Numeric tids are assigned in mutex-lock order, which is OS-racy
    /// when several threads register concurrently; names are not — every
    /// participant thread carries a stable, unique name, and respawns of
    /// the same name are serialized by cluster logic, so the incarnation
    /// counter is deterministic too.
    key: (String, u64),
    /// `None` while the thread holds the run token.
    waiting: Option<Waiting>,
}

struct VcState {
    now: Duration,
    next_tid: u64,
    threads: BTreeMap<u64, ThreadState>,
    name_counts: std::collections::HashMap<String, u64>,
    /// Threads announced via [`spawn_participant`] that have not yet
    /// registered. While nonzero, time must not advance (the newborn's
    /// registration instant would otherwise race the timeline).
    births_pending: u64,
    running: Option<u64>,
    shutdown: bool,
    seed: u64,
    /// Scheduling decisions so far — mixed into the seeded pick so the
    /// ordering varies over the run yet replays exactly.
    decisions: u64,
}

/// Discrete-event time with deterministic cooperative scheduling. See
/// module docs.
pub struct VirtualClock {
    state: Mutex<VcState>,
    cv: Condvar,
}

impl VirtualClock {
    pub fn new(seed: u64) -> Arc<VirtualClock> {
        Arc::new(VirtualClock {
            state: Mutex::new(VcState {
                now: Duration::ZERO,
                next_tid: 1,
                threads: BTreeMap::new(),
                name_counts: std::collections::HashMap::new(),
                births_pending: 0,
                running: None,
                shutdown: false,
                seed,
                decisions: 0,
            }),
            cv: Condvar::new(),
        })
    }

    pub fn now(&self) -> Duration {
        self.state.lock().unwrap().now
    }

    fn announce_birth(&self) {
        self.state.lock().unwrap().births_pending += 1;
    }

    fn birth_cancelled(&self) {
        let mut st = self.state.lock().unwrap();
        st.births_pending = st.births_pending.saturating_sub(1);
        if st.running.is_none() && !st.shutdown {
            self.schedule(&mut st);
        }
        self.cv.notify_all();
    }

    fn register(self: &Arc<Self>) -> u64 {
        let name = std::thread::current().name().unwrap_or("anon").to_string();
        let mut st = self.state.lock().unwrap();
        st.births_pending = st.births_pending.saturating_sub(1);
        let tid = st.next_tid;
        st.next_tid += 1;
        let incarnation = {
            let c = st.name_counts.entry(name.clone()).or_insert(0);
            let v = *c;
            *c += 1;
            v
        };
        let key = (name, incarnation);
        VC_TID.with(|c| c.set(tid));
        if st.shutdown {
            st.threads.insert(tid, ThreadState { key, waiting: None });
            return tid;
        }
        // Born ready: granted as soon as the current runner yields.
        let now = st.now;
        st.threads.insert(
            tid,
            ThreadState {
                key,
                waiting: Some(Waiting { kind: WaitKind::Sleep, deadline: Some(now), ready: true }),
            },
        );
        self.schedule(&mut st);
        self.wait_for_grant(st, tid);
        tid
    }

    fn deregister(&self, tid: u64) {
        let mut st = self.state.lock().unwrap();
        VC_TID.with(|c| {
            if c.get() == tid {
                c.set(u64::MAX);
            }
        });
        st.threads.remove(&tid);
        if st.running == Some(tid) {
            st.running = None;
        }
        if !st.shutdown {
            self.schedule(&mut st);
        }
        self.cv.notify_all();
    }

    fn shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        st.running = None;
        for t in st.threads.values_mut() {
            t.waiting = None;
        }
        self.cv.notify_all();
    }

    fn current_tid(&self) -> u64 {
        let tid = VC_TID.with(|c| c.get());
        assert!(
            tid != u64::MAX,
            "virtual-clock blocking call from a thread that never registered \
             (every participant must hold a ClockGuard)"
        );
        tid
    }

    fn sleep_until(&self, t: Duration) {
        loop {
            {
                let st = self.state.lock().unwrap();
                if st.shutdown || st.now >= t {
                    return;
                }
            }
            self.wait(WaitKind::Sleep, Some(t));
        }
    }

    /// Yield the run token and block until granted again (deadline due,
    /// or — for `Recv` waits — a channel event).
    fn wait(&self, kind: WaitKind, deadline: Option<Duration>) {
        let tid = self.current_tid();
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return;
        }
        assert!(
            st.threads.contains_key(&tid),
            "virtual-clock wait from a deregistered thread"
        );
        let ready = deadline.is_some_and(|d| d <= st.now);
        st.threads.get_mut(&tid).unwrap().waiting = Some(Waiting { kind, deadline, ready });
        if st.running == Some(tid) {
            st.running = None;
        }
        self.schedule(&mut st);
        self.wait_for_grant(st, tid);
    }

    fn wait_for_grant(&self, mut st: MutexGuard<'_, VcState>, tid: u64) {
        loop {
            if st.shutdown {
                if let Some(t) = st.threads.get_mut(&tid) {
                    t.waiting = None;
                }
                return;
            }
            if st.running == Some(tid) {
                return;
            }
            st = self.cv.wait(st).unwrap();
            if st.running.is_none() && !st.shutdown {
                self.schedule(&mut st);
            }
        }
    }

    /// A message (or disconnect) happened on channel `id`: mark its
    /// blocked receiver runnable.
    fn chan_event(&self, id: u64) {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            self.cv.notify_all();
            return;
        }
        let mut any = false;
        for t in st.threads.values_mut() {
            if let Some(w) = &mut t.waiting {
                if w.kind == WaitKind::Recv(id) && !w.ready {
                    w.ready = true;
                    any = true;
                }
            }
        }
        // The sender normally holds the run token and the receiver gets
        // picked when it yields; schedule directly only if nobody runs
        // (e.g. a disconnect during thread teardown).
        if any && st.running.is_none() {
            self.schedule(&mut st);
        }
    }

    fn recv_loop<T>(
        &self,
        rx: &mpsc::Receiver<T>,
        chan: u64,
        deadline: Option<Duration>,
    ) -> Result<T, RecvTimeoutError> {
        loop {
            match rx.try_recv() {
                Ok(v) => return Ok(v),
                Err(TryRecvError::Disconnected) => return Err(RecvTimeoutError::Disconnected),
                Err(TryRecvError::Empty) => {}
            }
            {
                let st = self.state.lock().unwrap();
                if st.shutdown {
                    drop(st);
                    return match deadline {
                        // Teardown: block for real; senders run freely now.
                        None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
                        Some(_) => {
                            // Poll loops spin here during teardown; a tiny
                            // real sleep keeps them polite until joined.
                            std::thread::sleep(Duration::from_micros(100));
                            Err(RecvTimeoutError::Timeout)
                        }
                    };
                }
                if let Some(dl) = deadline {
                    if dl <= st.now {
                        return Err(RecvTimeoutError::Timeout);
                    }
                }
            }
            self.wait(WaitKind::Recv(chan), deadline);
        }
    }

    /// Core scheduling step; call with the state lock held and nobody
    /// running. Grants the token to one runnable waiter, advancing time
    /// if nothing is runnable yet.
    fn schedule(&self, st: &mut VcState) {
        if st.shutdown || st.running.is_some() {
            return;
        }
        // If an announced thread is still on its way to register, hold the
        // whole scheduler (grants *and* time) until it arrives: granting
        // from a partially-registered ready set would make the decision
        // sequence depend on OS thread-start timing.
        if st.births_pending > 0 {
            return;
        }
        let ready = Self::ready_by_key(st);
        if !ready.is_empty() {
            let pick = ready[self.pick_index(st, ready.len())];
            self.grant(st, pick);
            return;
        }
        // Jump to the earliest deadline.
        let min_dl = st
            .threads
            .values()
            .filter_map(|t| t.waiting.as_ref().and_then(|w| w.deadline))
            .min();
        match min_dl {
            Some(dl) => {
                if dl > st.now {
                    st.now = dl;
                }
                let now = st.now;
                for t in st.threads.values_mut() {
                    if let Some(w) = t.waiting.as_mut() {
                        if w.deadline.is_some_and(|d| d <= now) {
                            w.ready = true;
                        }
                    }
                }
                let due = Self::ready_by_key(st);
                let pick = due[self.pick_index(st, due.len())];
                self.grant(st, pick);
            }
            None => {
                if st.threads.is_empty() {
                    return;
                }
                panic!(
                    "virtual clock deadlock: {} participant(s) blocked forever \
                     (a thread blocked outside the clock, or a channel wait \
                     has no sender left to wake it)",
                    st.threads.len()
                );
            }
        }
    }

    /// Runnable waiters in deterministic (name, incarnation) order.
    fn ready_by_key(st: &VcState) -> Vec<u64> {
        let mut ready: Vec<(&(String, u64), u64)> = st
            .threads
            .iter()
            .filter(|(_, t)| t.waiting.as_ref().is_some_and(|w| w.ready))
            .map(|(&id, t)| (&t.key, id))
            .collect();
        ready.sort();
        ready.into_iter().map(|(_, id)| id).collect()
    }

    fn grant(&self, st: &mut VcState, tid: u64) {
        st.threads.get_mut(&tid).unwrap().waiting = None;
        st.running = Some(tid);
        self.cv.notify_all();
    }

    /// Seeded deterministic pick among `n` simultaneously runnable
    /// waiters (splitmix64 of seed ^ decision counter).
    fn pick_index(&self, st: &mut VcState, n: usize) -> usize {
        st.decisions = st.decisions.wrapping_add(1);
        if n == 1 {
            return 0;
        }
        let mut x = st.seed ^ st.decisions.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        x ^= x >> 33;
        x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        x ^= x >> 33;
        (x % n as u64) as usize
    }
}

// ---------------------------------------------------------------------------
// Manual clock + discrete-event primitives (macro-sim, DESIGN.md §16)
// ---------------------------------------------------------------------------

/// Simulated time owned by a single-threaded event loop. Monotone by
/// construction: `set` never moves backwards (a stale `sleep_until` is a
/// no-op, matching the other clocks).
pub struct ManualClock {
    nanos: AtomicU64,
}

impl Default for ManualClock {
    fn default() -> Self {
        ManualClock::new()
    }
}

impl ManualClock {
    pub fn new() -> ManualClock {
        ManualClock { nanos: AtomicU64::new(0) }
    }

    pub fn now(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::Relaxed))
    }

    /// Advance to `t` (no-op if already past — time never rewinds).
    pub fn set(&self, t: Duration) {
        let t = t.as_nanos() as u64;
        self.nanos.fetch_max(t, Ordering::Relaxed);
    }

    pub fn advance(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Deterministic discrete-event queue: events pop in (time, insertion
/// sequence) order, so same-instant events drain in the exact order they
/// were scheduled — no `Ord` requirement on the payload, no tie-break
/// ambiguity between runs.
pub struct EventQueue<E> {
    heap: BinaryHeap<QueueEntry<E>>,
    seq: u64,
}

struct QueueEntry<E> {
    at: Duration,
    seq: u64,
    event: E,
}

impl<E> PartialEq for QueueEntry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for QueueEntry<E> {}
impl<E> PartialOrd for QueueEntry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for QueueEntry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        (other.at, other.seq).cmp(&(self.at, self.seq))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute sim time `at`.
    pub fn push(&mut self, at: Duration, event: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(QueueEntry { at, seq, event });
    }

    /// Earliest pending deadline, if any.
    pub fn peek_at(&self) -> Option<Duration> {
        self.heap.peek().map(|e| e.at)
    }

    /// Pop the earliest event as `(at, event)`.
    pub fn pop(&mut self) -> Option<(Duration, E)> {
        self.heap.pop().map(|e| (e.at, e.event))
    }
}

/// A recurring deadline with an explicit "never fired" state.
///
/// The naive pattern `now.saturating_sub(last) >= every` with `last`
/// initialized to `Duration::ZERO` treats the epoch as a real previous
/// firing: a worker provisioned at t=500ms fires its very first check
/// immediately instead of one interval after birth. `Periodic` arms on
/// the first `due` call (returning `false`) and fires every `every`
/// thereafter, which is identical for t=0 workers and correct for
/// late-provisioned ones.
#[derive(Debug, Clone, Copy)]
pub struct Periodic {
    every: Duration,
    /// `None` until the first `due` call arms it — "never happened" is a
    /// real state, not an epoch timestamp.
    last: Option<Duration>,
}

impl Periodic {
    pub fn new(every: Duration) -> Periodic {
        Periodic { every, last: None }
    }

    /// True when a full interval has elapsed since the last firing (or
    /// since arming). Firing re-arms at `now`.
    pub fn due(&mut self, now: Duration) -> bool {
        match self.last {
            None => {
                self.last = Some(now);
                false
            }
            Some(last) if now.saturating_sub(last) >= self.every => {
                self.last = Some(now);
                true
            }
            Some(_) => false,
        }
    }

    /// Forget the last firing (next `due` re-arms without firing).
    pub fn reset(&mut self) {
        self.last = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn wall_clock_now_advances() {
        let c = Clock::wall();
        let a = c.now();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.now() > a);
        let _g = c.register(); // no-op
    }

    #[test]
    fn virtual_sleep_advances_without_real_time() {
        let c = Clock::virtual_seeded(1);
        let _g = c.register();
        let t0 = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(t0.elapsed() < Duration::from_millis(500), "slept for real");
        assert_eq!(c.now(), Duration::from_secs(3600));
        c.shutdown();
    }

    #[test]
    fn virtual_channel_roundtrip_with_delays() {
        let c = Clock::virtual_seeded(2);
        let _g = c.register();
        let (tx, rx) = channel::<u32>(&c);
        let c2 = c.clone();
        let h = spawn_participant(&c, "vc-sender", move || {
            c2.sleep(Duration::from_millis(250));
            tx.send(7).unwrap();
            c2.sleep(Duration::from_millis(250));
            tx.send(8).unwrap();
        })
        .unwrap();
        // Main blocks; time advances to the sender's deadline.
        assert_eq!(rx.recv().unwrap(), 7);
        assert!(c.now() >= Duration::from_millis(250));
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)).unwrap(), 8);
        assert!(c.now() >= Duration::from_millis(500));
        // Sender gone -> disconnect, not deadlock.
        assert!(rx.recv().is_err());
        c.shutdown();
        h.join().unwrap();
    }

    #[test]
    fn virtual_recv_timeout_fires_at_the_deadline() {
        let c = Clock::virtual_seeded(3);
        let _g = c.register();
        let (_tx, rx) = channel::<u32>(&c);
        let t0 = c.now();
        assert!(matches!(
            rx.recv_timeout(Duration::from_millis(40)),
            Err(RecvTimeoutError::Timeout)
        ));
        assert_eq!(c.now() - t0, Duration::from_millis(40));
        c.shutdown();
    }

    #[test]
    fn same_seed_same_wake_order() {
        fn order(seed: u64) -> Vec<usize> {
            let c = Clock::virtual_seeded(seed);
            let g = c.register();
            let log = Arc::new(Mutex::new(Vec::new()));
            let done = Arc::new(AtomicUsize::new(0));
            let mut joins = Vec::new();
            for i in 0..4usize {
                let c2 = c.clone();
                let log2 = log.clone();
                let done2 = done.clone();
                // Deterministic names => deterministic scheduler keys; time
                // cannot advance until every announced birth registers.
                joins.push(
                    spawn_participant(&c, format!("sleeper-{i}"), move || {
                        // All four become due at the same instant.
                        c2.sleep_until(Duration::from_millis(10));
                        log2.lock().unwrap().push(i);
                        done2.fetch_add(1, Ordering::SeqCst);
                    })
                    .unwrap(),
                );
            }
            while done.load(Ordering::SeqCst) < 4 {
                c.sleep(Duration::from_millis(5));
            }
            c.shutdown();
            drop(g);
            for j in joins {
                j.join().unwrap();
            }
            let order = log.lock().unwrap().clone();
            drop(log);
            order
        }
        assert_eq!(order(42), order(42), "same seed must replay identically");
        // Different seeds are allowed to interleave differently; the set
        // of woken threads is identical either way.
        let mut a = order(1);
        let mut b = order(2);
        a.sort();
        b.sort();
        assert_eq!(a, vec![0, 1, 2, 3]);
        assert_eq!(b, vec![0, 1, 2, 3]);
    }

    #[test]
    fn manual_clock_sets_and_never_rewinds() {
        let c = Clock::manual();
        assert_eq!(c.now(), Duration::ZERO);
        c.sleep(Duration::from_millis(5));
        assert_eq!(c.now(), Duration::from_millis(5));
        c.sleep_until(Duration::from_millis(3)); // stale: no-op
        assert_eq!(c.now(), Duration::from_millis(5));
        c.sleep_until(Duration::from_secs(2));
        assert_eq!(c.now(), Duration::from_secs(2));
        let _g = c.register(); // no-op, like wall
        c.shutdown(); // no-op
    }

    #[test]
    fn event_queue_pops_in_time_then_insertion_order() {
        let mut q = EventQueue::new();
        q.push(Duration::from_millis(10), "b");
        q.push(Duration::from_millis(5), "a");
        q.push(Duration::from_millis(10), "c"); // same instant as "b"
        assert_eq!(q.peek_at(), Some(Duration::from_millis(5)));
        assert_eq!(q.pop(), Some((Duration::from_millis(5), "a")));
        // Ties drain in scheduling order, not payload order.
        assert_eq!(q.pop(), Some((Duration::from_millis(10), "b")));
        assert_eq!(q.pop(), Some((Duration::from_millis(10), "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn periodic_arms_without_firing_then_fires_per_interval() {
        let every = Duration::from_millis(10);
        let mut p = Periodic::new(every);
        // A worker born at t=500ms must NOT fire immediately (the old
        // epoch-sentinel bug): first call arms only.
        let birth = Duration::from_millis(500);
        assert!(!p.due(birth));
        assert!(!p.due(birth + Duration::from_millis(9)));
        assert!(p.due(birth + Duration::from_millis(10)));
        // Re-armed at the firing instant.
        assert!(!p.due(birth + Duration::from_millis(19)));
        assert!(p.due(birth + Duration::from_millis(20)));
        p.reset();
        assert!(!p.due(birth + Duration::from_millis(40)));
    }

    #[test]
    fn shutdown_releases_everything() {
        let c = Clock::virtual_seeded(9);
        let g = c.register();
        let c2 = c.clone();
        let h = spawn_participant(&c, "vc-long-sleeper", move || {
            c2.sleep(Duration::from_secs(100000));
        })
        .unwrap();
        c.sleep(Duration::from_millis(1));
        c.shutdown();
        drop(g);
        h.join().unwrap(); // returns promptly despite the huge sleep
    }
}
