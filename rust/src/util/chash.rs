//! Rendezvous (highest-random-weight) hashing for gateway sharding
//! (DESIGN.md §15).
//!
//! Every party that knows a key and the live shard set independently
//! computes the same owner, with no coordination and no ring state: the
//! owner of `key` is the live shard with the highest `mix(key, shard)`
//! weight. Removing a shard reassigns only the keys it owned (each key's
//! weights against the surviving shards are unchanged), and adding a
//! shard steals only the keys whose weight against the newcomer beats
//! their current maximum — the minimal-disruption property the
//! gateway-failover path depends on: survivors keep their requests, so a
//! gateway death never reshuffles healthy streams.

/// SplitMix64 finalizer: a cheap, well-distributed 64-bit mix.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Rendezvous weight of `key` against `shard`.
pub fn weight(key: u64, shard: u32) -> u64 {
    mix(key ^ mix(shard as u64 ^ 0xa076_1d64_78bd_642f))
}

/// The live shard that owns `key`: highest weight, ties broken toward the
/// lowest shard id (deterministic for every caller). Returns `None` for
/// an empty shard set.
pub fn owner(key: u64, shards: &[u32]) -> Option<u32> {
    shards
        .iter()
        .copied()
        .max_by_key(|&s| (weight(key, s), std::cmp::Reverse(s)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_is_deterministic_and_in_set() {
        let shards = [0, 1, 2, 3];
        for key in 0..256u64 {
            let a = owner(key, &shards).unwrap();
            let b = owner(key, &shards).unwrap();
            assert_eq!(a, b);
            assert!(shards.contains(&a));
        }
        assert_eq!(owner(7, &[]), None);
        assert_eq!(owner(7, &[5]), Some(5));
    }

    #[test]
    fn removal_only_remaps_the_dead_shards_keys() {
        let full = [0u32, 1, 2, 3];
        let survivors = [0u32, 1, 3];
        for key in 0..512u64 {
            let before = owner(key, &full).unwrap();
            let after = owner(key, &survivors).unwrap();
            if before != 2 {
                assert_eq!(before, after, "key {key} moved off a surviving shard");
            } else {
                assert!(survivors.contains(&after));
            }
        }
    }

    #[test]
    fn addition_only_steals_keys_for_the_new_shard() {
        let old = [0u32, 1];
        let new = [0u32, 1, 2];
        for key in 0..512u64 {
            let before = owner(key, &old).unwrap();
            let after = owner(key, &new).unwrap();
            assert!(after == before || after == 2, "key {key} moved between old shards");
        }
    }

    #[test]
    fn spread_is_roughly_balanced() {
        let shards = [0u32, 1, 2, 3];
        let mut counts = [0usize; 4];
        for key in 0..4096u64 {
            counts[owner(key, &shards).unwrap() as usize] += 1;
        }
        for &c in &counts {
            // Expect ~1024 per shard; allow a generous band.
            assert!((700..1400).contains(&c), "unbalanced spread: {counts:?}");
        }
    }
}
