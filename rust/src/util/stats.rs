//! Summary statistics and time-series helpers used by the metrics layer
//! and every experiment harness: percentiles, online mean/variance,
//! fixed-width histograms, and timeline binning (for the Fig. 8/9 series).

/// Percentile over a sample (linear interpolation on a sorted copy, the
/// numpy default). `p` in [0,100].
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

pub fn median(samples: &[f64]) -> f64 {
    percentile(samples, 50.0)
}

pub fn mean(samples: &[f64]) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    samples.iter().sum::<f64>() / samples.len() as f64
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-width histogram over [lo, hi); out-of-range values clamp to the
/// edge bins, so counts are never lost.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    width: f64,
    pub bins: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, width: (hi - lo) / nbins as f64, bins: vec![0; nbins] }
    }

    pub fn push(&mut self, x: f64) {
        let idx = ((x - self.lo) / self.width).floor();
        let idx = (idx.max(0.0) as usize).min(self.bins.len() - 1);
        self.bins[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }

    /// (bin_center, count) pairs for CSV emission.
    pub fn series(&self) -> Vec<(f64, u64)> {
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + (i as f64 + 0.5) * self.width, c))
            .collect()
    }
}

/// Bin (timestamp, value) events into fixed windows; reports per-window
/// aggregates. Timestamps in seconds. Used for TBT / throughput timelines.
#[derive(Debug, Clone)]
pub struct Timeline {
    window: f64,
    sums: Vec<f64>,
    counts: Vec<u64>,
}

impl Timeline {
    pub fn new(window_secs: f64) -> Self {
        assert!(window_secs > 0.0);
        Timeline { window: window_secs, sums: Vec::new(), counts: Vec::new() }
    }

    pub fn push(&mut self, t_secs: f64, value: f64) {
        if t_secs < 0.0 {
            return;
        }
        let idx = (t_secs / self.window) as usize;
        if idx >= self.sums.len() {
            self.sums.resize(idx + 1, 0.0);
            self.counts.resize(idx + 1, 0);
        }
        self.sums[idx] += value;
        self.counts[idx] += 1;
    }

    /// Per-window event rate (count / window) as (window_start, rate).
    pub fn rate_series(&self) -> Vec<(f64, f64)> {
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as f64 * self.window, c as f64 / self.window))
            .collect()
    }

    /// Per-window mean value as (window_start, mean); empty windows NaN.
    pub fn mean_series(&self) -> Vec<(f64, f64)> {
        self.sums
            .iter()
            .zip(&self.counts)
            .enumerate()
            .map(|(i, (&s, &c))| {
                let m = if c == 0 { f64::NAN } else { s / c as f64 };
                (i as f64 * self.window, m)
            })
            .collect()
    }

    pub fn num_windows(&self) -> usize {
        self.counts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((median(&xs) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 95.0) - 95.05).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert_eq!(o.min(), 1.0);
        assert_eq!(o.max(), 9.0);
        let batch_var = xs.iter().map(|x| (x - mean(&xs)).powi(2)).sum::<f64>()
            / (xs.len() - 1) as f64;
        assert!((o.var() - batch_var).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps_edges() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0);
        h.push(0.5);
        h.push(9.99);
        h.push(100.0);
        assert_eq!(h.total(), 4);
        assert_eq!(h.bins[0], 2);
        assert_eq!(h.bins[9], 2);
    }

    #[test]
    fn timeline_binning() {
        let mut t = Timeline::new(1.0);
        t.push(0.1, 10.0);
        t.push(0.9, 20.0);
        t.push(2.5, 30.0);
        let rates = t.rate_series();
        assert_eq!(rates.len(), 3);
        assert_eq!(rates[0].1, 2.0);
        assert_eq!(rates[1].1, 0.0);
        assert_eq!(rates[2].1, 1.0);
        let means = t.mean_series();
        assert_eq!(means[0].1, 15.0);
        assert!(means[1].1.is_nan());
        assert_eq!(means[2].1, 30.0);
    }
}
