//! Deterministic PRNG (PCG-XSH-RR 64/32) plus the distributions the
//! workload generators need (uniform, normal, lognormal, exponential,
//! Poisson-process inter-arrival times).
//!
//! In-repo replacement for the unavailable `rand`/`rand_distr` crates;
//! determinism matters more here than raw speed: every experiment harness
//! seeds its own stream so runs are reproducible.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output. Reference: O'Neill 2014.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: single-stream generator.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) (hi exclusive; requires hi > lo).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        let span = hi - lo;
        // Lemire-style rejection-free for our (non-crypto) purposes.
        lo + (self.next_u64() % span)
    }

    /// Uniform usize in [lo, hi).
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Pick a uniformly random element index for a slice of length `n`.
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        self.range(0, n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with the underlying normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate (mean 1/rate) — Poisson-process
    /// inter-arrival times.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-12).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg::seeded(42);
        let mut b = Pcg::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range_bounds() {
        let mut rng = Pcg::seeded(1);
        for _ in 0..10_000 {
            let v = rng.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg::seeded(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seeded(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg::seeded(4);
        let rate = 4.0;
        let n = 100_000;
        let mean = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seeded(5);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
