//! Minimal HTTP/1.0 server for the orchestrator's admin/control endpoints
//! (the paper's orchestrator is "a C++ control plane service exposing HTTP
//! endpoints for configuration and failure monitoring").
//!
//! One thread per connection, GET only, handler returns (status, body).
//! This is an *admin* plane: low traffic, human/scripted clients — never on
//! the request path.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

pub type Handler = Arc<dyn Fn(&str) -> (u16, String) + Send + Sync>;

pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind to 127.0.0.1:port (port 0 = ephemeral) and serve `handler`
    /// (path -> (status, body)) on a background thread.
    pub fn start(port: u16, handler: Handler) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("http-admin".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let h = handler.clone();
                            // Admin traffic is rare; thread-per-conn is fine.
                            std::thread::spawn(move || handle_conn(stream, h));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(HttpServer { addr, stop, handle: Some(handle) })
    }

    pub fn url(&self, path: &str) -> String {
        format!("http://{}{}", self.addr, path)
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, handler: Handler) {
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(2)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut request_line = String::new();
    if reader.read_line(&mut request_line).is_err() {
        return;
    }
    // Drain headers (we don't use them).
    let mut line = String::new();
    while reader.read_line(&mut line).is_ok() {
        if line == "\r\n" || line == "\n" || line.is_empty() {
            break;
        }
        line.clear();
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("/");
    let (status, body) = if method == "GET" {
        handler(path)
    } else {
        (405, "method not allowed\n".to_string())
    };
    respond(stream, status, &body);
}

fn respond(mut stream: TcpStream, status: u16, body: &str) {
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Error",
    };
    let resp = format!(
        "HTTP/1.0 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(resp.as_bytes());
}

/// Minimal GET client for tests and admin scripts.
pub fn get(addr: std::net::SocketAddr, path: &str) -> std::io::Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(2)))?;
    write!(stream, "GET {path} HTTP/1.0\r\nHost: localhost\r\n\r\n")?;
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let mut line = String::new();
    while reader.read_line(&mut line)? > 0 {
        if line == "\r\n" || line == "\n" {
            break;
        }
        line.clear();
    }
    let mut body = String::new();
    use std::io::Read;
    reader.read_to_string(&mut body)?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_and_routes() {
        let server = HttpServer::start(
            0,
            Arc::new(|path: &str| match path {
                "/health" => (200, "{\"ok\":true}".to_string()),
                p if p.starts_with("/workers") => (200, "[]".to_string()),
                _ => (404, "nope".to_string()),
            }),
        )
        .unwrap();
        let (code, body) = get(server.addr, "/health").unwrap();
        assert_eq!((code, body.as_str()), (200, "{\"ok\":true}"));
        let (code, _) = get(server.addr, "/missing").unwrap();
        assert_eq!(code, 404);
        let (code, _) = get(server.addr, "/workers/all").unwrap();
        assert_eq!(code, 200);
    }

    #[test]
    fn shuts_down_on_drop() {
        let addr;
        {
            let server =
                HttpServer::start(0, Arc::new(|_: &str| (200, String::new()))).unwrap();
            addr = server.addr;
            let (code, _) = get(addr, "/").unwrap();
            assert_eq!(code, 200);
        }
        // After drop the listener thread exits; connection should fail
        // (immediately or after the accept loop notices).
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(get(addr, "/").is_err() || get(addr, "/").is_err());
    }
}
