//! TOML-subset parser for cluster configuration files.
//!
//! Supports the subset the config system uses (and nothing more):
//! `[section]` and `[section.sub]` tables, `key = value` with string,
//! integer, float, boolean, and homogeneous primitive arrays; `#` comments.
//! Values land in a flat `section.key -> Value` map; the typed layer in
//! `crate::config` does the schema checking.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// Parse into a flat map keyed `section.key` (or just `key` at top level).
pub fn parse(text: &str) -> Result<BTreeMap<String, Value>, TomlError> {
    let mut map = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| err("expected ']'"))?;
            let name = name.trim();
            if name.is_empty()
                || !name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-')
            {
                return Err(err("invalid section name"));
            }
            section = name.to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| err("expected 'key = value'"))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        map.insert(full, value);
    }
    Ok(map)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, String> {
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("nested quote".into());
        }
        return Ok(Value::Str(inner.replace("\\n", "\n").replace("\\t", "\t")));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            split_top_level(inner).iter().map(|s| parse_value(s.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{text}'"))
}

/// Split an array body on commas (strings may contain commas).
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let text = r#"
# cluster layout
[cluster]
num_aws = 8
num_ews = 8          # experts spread evenly
decode_batch = 8

[resilience]
checkpointing = true
probe_interval_ms = 10
shadow_factor = 1.5

[workload]
kind = "sharegpt"
rates = [30, 40, 50]
"#;
        let m = parse(text).unwrap();
        assert_eq!(m["cluster.num_aws"].as_i64(), Some(8));
        assert_eq!(m["resilience.checkpointing"].as_bool(), Some(true));
        assert_eq!(m["resilience.shadow_factor"].as_f64(), Some(1.5));
        assert_eq!(m["workload.kind"].as_str(), Some("sharegpt"));
        let rates = m["workload.rates"].as_arr().unwrap();
        assert_eq!(rates.len(), 3);
        assert_eq!(rates[1].as_i64(), Some(40));
    }

    #[test]
    fn top_level_keys_and_strings_with_hashes() {
        let m = parse("name = \"run #4\"\n").unwrap();
        assert_eq!(m["name"].as_str(), Some("run #4"));
    }

    #[test]
    fn int_coerces_to_f64_not_reverse() {
        let m = parse("a = 3\nb = 3.5\n").unwrap();
        assert_eq!(m["a"].as_f64(), Some(3.0));
        assert_eq!(m["b"].as_i64(), None);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("[ok]\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("x = [1, 2").is_err());
        assert!(parse("[unclosed\n").is_err());
    }

    #[test]
    fn string_array() {
        let m = parse(r#"xs = ["a", "b,c", "d"]"#).unwrap();
        let xs = m["xs"].as_arr().unwrap();
        assert_eq!(xs[1].as_str(), Some("b,c"));
        assert_eq!(xs.len(), 3);
    }

    #[test]
    fn dotted_sections() {
        let m = parse("[a.b]\nc = 1\n").unwrap();
        assert_eq!(m["a.b.c"].as_i64(), Some(1));
    }
}
