//! Small self-contained substrates.
//!
//! This build environment is offline with a fixed vendored crate set (see
//! DESIGN.md §2), so the usual ecosystem crates (serde, rand, clap, ...)
//! are replaced by the minimal, tested implementations in this module.

pub mod chash;
pub mod cli;
pub mod clock;
pub mod http;
pub mod json;
pub mod rng;
pub mod stats;
pub mod toml;

use std::time::{SystemTime, UNIX_EPOCH};

/// Wall-clock microseconds since the Unix epoch (for logs only; all
/// measurements use `std::time::Instant`).
pub fn unix_micros() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// Format a byte count human-readably (for logs and reports).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn unix_micros_monotonic_enough() {
        let a = unix_micros();
        let b = unix_micros();
        assert!(b >= a);
        assert!(a > 1_600_000_000_000_000); // after 2020
    }
}
