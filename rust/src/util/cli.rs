//! Tiny CLI argument parser (in-repo replacement for `clap`).
//!
//! Grammar: `tarragon <subcommand> [--flag] [--key value] [--key=value]`.
//! Typed accessors with defaults; unknown-flag detection via `finish()`.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

#[derive(Debug)]
pub enum CliError {
    BadValue(String, String),
    Unknown(String),
    Missing(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::BadValue(k, v) => write!(f, "invalid value for --{k}: '{v}'"),
            CliError::Unknown(args) => write!(f, "unknown argument(s): {args}"),
            CliError::Missing(k) => write!(f, "missing required argument --{k}"),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut items: Vec<String> = argv.into_iter().collect();
        let subcommand = if !items.is_empty() && !items[0].starts_with('-') {
            Some(items.remove(0))
        } else {
            None
        };
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < items.len() {
            let item = &items[i];
            if let Some(stripped) = item.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    values.insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    values.insert(stripped.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(stripped.to_string());
                }
            } else {
                flags.push(item.clone());
            }
            i += 1;
        }
        Args { subcommand, values, flags, consumed: Default::default() }
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.values.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn required(&self, key: &str) -> Result<String, CliError> {
        self.str_opt(key).ok_or_else(|| CliError::Missing(key.to_string()))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| CliError::BadValue(key.to_string(), v)),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        self.parse_or(key, default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        self.parse_or(key, default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        self.parse_or(key, default)
    }

    /// Boolean switch: `--verbose` (no value).
    pub fn has_flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list: `--rates 30,40,50`.
    pub fn list_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, CliError> {
        match self.str_opt(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<f64>()
                        .map_err(|_| CliError::BadValue(key.to_string(), v.clone()))
                })
                .collect(),
        }
    }

    /// Error if any provided argument was never consumed by an accessor —
    /// catches typos like `--scenaro`.
    pub fn finish(&self) -> Result<(), CliError> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<String> = self
            .values
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .cloned()
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::Unknown(unknown.join(", ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_kv() {
        let a = args("fig9 --scenario aw --rate 50 --duration=30");
        assert_eq!(a.subcommand.as_deref(), Some("fig9"));
        assert_eq!(a.str_or("scenario", "x"), "aw");
        assert_eq!(a.u64_or("rate", 0).unwrap(), 50);
        assert_eq!(a.u64_or("duration", 0).unwrap(), 30);
        a.finish().unwrap();
    }

    #[test]
    fn flags_and_defaults() {
        let a = args("serve --verbose");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.usize_or("num-aws", 8).unwrap(), 8);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_args_detected() {
        let a = args("fig9 --scenaro aw");
        assert_eq!(a.str_or("scenario", "x"), "x");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_value_is_error() {
        let a = args("x --rate abc");
        assert!(a.u64_or("rate", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = args("x --rates 30,40.5,50");
        assert_eq!(a.list_or("rates", &[]).unwrap(), vec![30.0, 40.5, 50.0]);
    }

    #[test]
    fn no_subcommand() {
        let a = args("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.has_flag("help"));
    }
}
