//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! In-repo replacement for the unavailable `serde_json`. Supports the full
//! JSON grammar the build pipeline emits (manifest.json, golden.json) and
//! the experiment harnesses write (results/*.json): objects, arrays,
//! strings with escapes, numbers, booleans, null. Not streaming; documents
//! here are at most a few MB.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name (manifest loading
    /// wants good messages, not silent None).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing key '{key}'"),
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// [1,2,3] -> Vec<usize>; errors on non-numeric entries.
    pub fn usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- writing ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literals; an empty-sample
                    // summary (e.g. `LatencySummary::of(&[])`) must not
                    // poison a BENCH_*.json file with invalid syntax.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

/// Convenience constructors for the harnesses that emit result files.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("unknown escape")),
                },
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Re-decode multi-byte UTF-8.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{"d":false}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c").unwrap().get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_unicode_escape_and_utf8() {
        let j = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("é café ☕"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-3}}"#;
        let j = Json::parse(src).unwrap();
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"artifacts":[{"name":"expert_b4","bucket":4,
                       "inputs":[{"name":"x","shape":[4,128],"dtype":"f32"}]}]}"#;
        let j = Json::parse(src).unwrap();
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("bucket").unwrap().as_usize(), Some(4));
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .usize_vec(),
            Some(vec![4, 128])
        );
    }

    #[test]
    fn integers_write_without_fraction() {
        assert_eq!(Json::Num(4.0).to_string(), "4");
        assert_eq!(Json::Num(4.5).to_string(), "4.5");
    }

    #[test]
    fn non_finite_numbers_write_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        // The empty-sample summary shape stays parseable end to end.
        let doc = obj(vec![("median_ms", num(f64::NAN)), ("count", num(0.0))]);
        let text = doc.to_string();
        assert_eq!(text, r#"{"count":0,"median_ms":null}"#);
        assert_eq!(Json::parse(&text).unwrap().get("median_ms"), Some(&Json::Null));
    }
}
