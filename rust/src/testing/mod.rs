//! Test utilities: a small randomized property-testing harness (the
//! vendored crate set has no proptest), micro-benchmark support used by
//! the `rust/benches` targets, the synthetic in-repo model artifacts
//! ([`synthetic`]) that let the integration tier run without Python-built
//! `artifacts/`, and the virtual-clock failure-scenario harness
//! ([`scenario`]).

pub mod alloccount;
pub mod bench;
pub mod prop;
pub mod scenario;
pub mod synthetic;
