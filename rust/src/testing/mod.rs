//! Test utilities: a small randomized property-testing harness (the
//! vendored crate set has no proptest) and micro-benchmark support used by
//! the `rust/benches` targets.

pub mod bench;
pub mod prop;
