//! Synthetic in-repo model artifacts: a tiny MoE manifest + weight blob +
//! golden generation fixture built entirely from Rust, so the integration
//! tier runs everywhere — no Python build, no `artifacts/` directory.
//!
//! The generator writes a real artifact directory (manifest.json,
//! weights.bin, placeholder `.hlo.txt` files) into the system temp dir
//! and loads it back through the production `modelcfg` paths, so the
//! exact same manifest/weights plumbing is exercised as with
//! Python-built artifacts. Execution semantics come from the
//! [`runtime::xla`](crate::runtime::xla) reference executor (HLO files
//! are only checked for existence), and the golden fixture is produced
//! by a single-device reference decoder that mirrors the cluster's
//! numerics exactly: bucket padding, per-row routing, and
//! expert-ascending output accumulation.

use crate::coordinator::router::{self, ExpertGroups};
use crate::modelcfg::{weights::Weights, Buckets, Manifest};
use crate::runtime::{kern, ArgValue, Device, DeviceRole};
use crate::tensor::{ops, Tensor};
use crate::util::clock::Clock;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Pcg;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// Seed for deterministic synthetic weights (same spirit as the Python
/// pipeline's WEIGHT_SEED; a different value so fixtures can't be
/// confused).
pub const SYNTH_SEED: u64 = 0x7A44_A61;

/// Bump when dims/weights/reference math change: the artifact directory
/// name carries it, so stale cached dirs are never reused.
const VERSION: &str = "v1";

// Tiny-MoE dims. Small enough that a full scenario decodes in
// milliseconds of compute, big enough to exercise GQA (2 heads over 1 KV
// head), 4 experts / top-2 routing, and multi-page KV sequences.
const LAYERS: usize = 2;
const HIDDEN: usize = 32;
const HEADS: usize = 2;
const KV_HEADS: usize = 1;
const HEAD_DIM: usize = 16;
const FFN: usize = 64;
const EXPERTS: usize = 4;
const TOP_K: usize = 2;
const VOCAB: usize = 128;
const MAX_SEQ: usize = 160;

const PREFILL_T: [usize; 2] = [8, 16];
const DECODE_B: [usize; 4] = [1, 2, 4, 8];
const EXPERT_B: [usize; 6] = [1, 2, 4, 8, 16, 32];
const ROUTER_B: [usize; 5] = [1, 2, 4, 8, 16];
const LM_HEAD_B: [usize; 4] = [1, 2, 4, 8];

/// Golden cases: (prompt, tokens to decode).
const GOLDEN_CASES: [(&[u32], usize); 3] =
    [(&[1, 2, 3, 4, 5, 6, 7, 8], 12), (&[42, 17, 99, 9], 8), (&[100, 3, 64], 10)];

type GoldenCases = Vec<(Vec<u32>, Vec<u32>)>;

/// Build (or reuse) the synthetic artifact directory, load it, and
/// compute the golden fixture. Cached per process.
pub fn ensure() -> (Arc<Manifest>, Weights, GoldenCases) {
    static CACHE: OnceLock<(Arc<Manifest>, Weights, GoldenCases)> = OnceLock::new();
    CACHE
        .get_or_init(|| {
            let dir = ensure_dir();
            let manifest = Arc::new(Manifest::load(&dir).expect("synthetic manifest loads"));
            let weights = Weights::load(&manifest).expect("synthetic weights load");
            let golden = golden_cases(&manifest, &weights);
            write_golden_json(&dir, &golden);
            (manifest, weights, golden)
        })
        .clone()
}

/// Path of the synthetic artifact directory, creating it if needed.
pub fn ensure_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tarragon-synth-{VERSION}-{SYNTH_SEED:x}"));
    if dir.join("manifest.json").exists() {
        return dir;
    }
    // Write into a process-unique staging dir, then rename into place so
    // concurrent test processes can't observe a torn directory.
    let staging = std::env::temp_dir().join(format!(
        "tarragon-synth-{VERSION}-{SYNTH_SEED:x}.tmp-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&staging);
    write_artifact_dir(&staging).expect("write synthetic artifacts");
    match std::fs::rename(&staging, &dir) {
        Ok(()) => dir,
        Err(_) if dir.join("manifest.json").exists() => {
            // Lost the race to another process; its copy is identical.
            let _ = std::fs::remove_dir_all(&staging);
            dir
        }
        Err(_) => staging, // fall back to our private copy
    }
}

// ---------------------------------------------------------------------------
// Artifact directory generation
// ---------------------------------------------------------------------------

struct TensorSpec {
    name: String,
    shape: Vec<usize>,
    /// Standard deviation of the generated values; 0.0 = constant 1.0
    /// (norm gains).
    std: f64,
}

fn weight_plan() -> Vec<TensorSpec> {
    let t = |name: String, shape: Vec<usize>, std: f64| TensorSpec { name, shape, std };
    let kvd = KV_HEADS * HEAD_DIM;
    let m_std = |fan_in: usize| 1.0 / (fan_in as f64).sqrt();
    let mut plan = vec![t("embed".into(), vec![VOCAB, HIDDEN], 1.0)];
    for l in 0..LAYERS {
        plan.push(t(format!("layer{l}.wq"), vec![HIDDEN, HIDDEN], m_std(HIDDEN)));
        plan.push(t(format!("layer{l}.wk"), vec![HIDDEN, kvd], m_std(HIDDEN)));
        plan.push(t(format!("layer{l}.wv"), vec![HIDDEN, kvd], m_std(HIDDEN)));
        plan.push(t(format!("layer{l}.wo"), vec![HIDDEN, HIDDEN], m_std(HIDDEN)));
        plan.push(t(format!("layer{l}.ln1"), vec![HIDDEN], 0.0));
        plan.push(t(format!("layer{l}.ln2"), vec![HIDDEN], 0.0));
        plan.push(t(format!("layer{l}.router"), vec![HIDDEN, EXPERTS], m_std(HIDDEN)));
        for e in 0..EXPERTS {
            plan.push(t(format!("layer{l}.expert{e}.w1"), vec![HIDDEN, FFN], m_std(HIDDEN)));
            plan.push(t(format!("layer{l}.expert{e}.w3"), vec![HIDDEN, FFN], m_std(HIDDEN)));
            plan.push(t(format!("layer{l}.expert{e}.w2"), vec![FFN, HIDDEN], m_std(FFN)));
        }
    }
    plan.push(t("ln_f".into(), vec![HIDDEN], 0.0));
    plan.push(t("lm_head".into(), vec![HIDDEN, VOCAB], m_std(HIDDEN)));
    plan
}

fn io(name: &str, shape: &[usize], dtype: &str) -> Json {
    obj(vec![
        ("name", s(name)),
        ("shape", arr(shape.iter().map(|&x| num(x as f64)))),
        ("dtype", s(dtype)),
    ])
}

fn artifact(
    name: String,
    kind: &str,
    bucket: usize,
    inputs: Vec<Json>,
    outputs: Vec<Json>,
) -> Json {
    let file = format!("{name}.hlo.txt");
    obj(vec![
        ("name", s(&name)),
        ("kind", s(kind)),
        ("bucket", num(bucket as f64)),
        ("file", s(&file)),
        ("inputs", Json::Arr(inputs)),
        ("outputs", Json::Arr(outputs)),
    ])
}

fn artifact_plan() -> Vec<Json> {
    let (h, kvh, d, sq, e, f, v) = (HIDDEN, KV_HEADS, HEAD_DIM, MAX_SEQ, EXPERTS, FFN, VOCAB);
    let kvd = kvh * d;
    let attn_w = |inputs: &mut Vec<Json>| {
        inputs.push(io("wq", &[h, h], "f32"));
        inputs.push(io("wk", &[h, kvd], "f32"));
        inputs.push(io("wv", &[h, kvd], "f32"));
        inputs.push(io("wo", &[h, h], "f32"));
        inputs.push(io("ln1", &[h], "f32"));
        inputs.push(io("ln2", &[h], "f32"));
    };
    let mut plan = Vec::new();
    for t in PREFILL_T {
        let mut inputs = vec![io("x", &[t, h], "f32")];
        attn_w(&mut inputs);
        let outputs = vec![
            io("h", &[t, h], "f32"),
            io("g", &[t, h], "f32"),
            io("k", &[t, kvh, d], "f32"),
            io("v", &[t, kvh, d], "f32"),
        ];
        plan.push(artifact(format!("attn_prefill_t{t}"), "attn_prefill", t, inputs, outputs));
    }
    for b in DECODE_B {
        let mut inputs = vec![
            io("x", &[b, h], "f32"),
            io("k_cache", &[b, sq, kvh, d], "f32"),
            io("v_cache", &[b, sq, kvh, d], "f32"),
            io("pos", &[b], "i32"),
        ];
        attn_w(&mut inputs);
        let outputs = vec![
            io("h", &[b, h], "f32"),
            io("g", &[b, h], "f32"),
            io("k_new", &[b, kvh, d], "f32"),
            io("v_new", &[b, kvh, d], "f32"),
        ];
        plan.push(artifact(format!("attn_decode_b{b}"), "attn_decode", b, inputs, outputs));
    }
    for b in ROUTER_B {
        plan.push(artifact(
            format!("router_b{b}"),
            "router",
            b,
            vec![io("g", &[b, h], "f32"), io("wg", &[h, e], "f32")],
            vec![io("probs", &[b, e], "f32")],
        ));
    }
    for b in EXPERT_B {
        plan.push(artifact(
            format!("expert_b{b}"),
            "expert",
            b,
            vec![
                io("x", &[b, h], "f32"),
                io("w1", &[h, f], "f32"),
                io("w3", &[h, f], "f32"),
                io("w2", &[f, h], "f32"),
            ],
            vec![io("y", &[b, h], "f32")],
        ));
    }
    for b in LM_HEAD_B {
        plan.push(artifact(
            format!("lm_head_b{b}"),
            "lm_head",
            b,
            vec![io("h", &[b, h], "f32"), io("ln_f", &[h], "f32"), io("wlm", &[h, v], "f32")],
            vec![io("logits", &[b, v], "f32")],
        ));
    }
    plan
}

fn write_artifact_dir(dir: &std::path::Path) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;

    // --- weights.bin + offset table -----------------------------------
    let plan = weight_plan();
    let mut rng = Pcg::seeded(SYNTH_SEED);
    let mut blob: Vec<u8> = Vec::new();
    let mut tensors: Vec<Json> = Vec::new();
    let mut offset = 0usize;
    for spec in &plan {
        let n: usize = spec.shape.iter().product();
        let nbytes = n * 4;
        for _ in 0..n {
            let v = if spec.std == 0.0 { 1.0f32 } else { rng.normal_ms(0.0, spec.std) as f32 };
            blob.extend_from_slice(&v.to_le_bytes());
        }
        tensors.push(obj(vec![
            ("name", s(&spec.name)),
            ("shape", arr(spec.shape.iter().map(|&x| num(x as f64)))),
            ("offset", num(offset as f64)),
            ("nbytes", num(nbytes as f64)),
            ("dtype", s("f32")),
        ]));
        offset += nbytes;
    }
    std::fs::write(dir.join("weights.bin"), &blob)?;

    // --- artifacts (placeholder HLO text; semantics live in the
    //     manifest specs + runtime::xla reference executor) ------------
    let artifacts = artifact_plan();
    for a in &artifacts {
        let file = a.get("file").and_then(|v| v.as_str()).unwrap().to_string();
        std::fs::write(
            dir.join(file),
            "synthetic placeholder HLO (reference-executed; see rust/src/runtime/xla.rs)\n",
        )?;
    }

    // --- manifest.json ------------------------------------------------
    let manifest = obj(vec![
        ("version", num(1.0)),
        (
            "model",
            obj(vec![
                ("layers", num(LAYERS as f64)),
                ("hidden", num(HIDDEN as f64)),
                ("heads", num(HEADS as f64)),
                ("kv_heads", num(KV_HEADS as f64)),
                ("head_dim", num(HEAD_DIM as f64)),
                ("ffn", num(FFN as f64)),
                ("experts", num(EXPERTS as f64)),
                ("top_k", num(TOP_K as f64)),
                ("vocab", num(VOCAB as f64)),
                ("max_seq", num(MAX_SEQ as f64)),
            ]),
        ),
        (
            "buckets",
            obj(vec![
                ("prefill_t", arr(PREFILL_T.iter().map(|&x| num(x as f64)))),
                ("decode_b", arr(DECODE_B.iter().map(|&x| num(x as f64)))),
                ("expert_b", arr(EXPERT_B.iter().map(|&x| num(x as f64)))),
                ("router_b", arr(ROUTER_B.iter().map(|&x| num(x as f64)))),
                ("lm_head_b", arr(LM_HEAD_B.iter().map(|&x| num(x as f64)))),
            ]),
        ),
        ("weight_seed", num(SYNTH_SEED as f64)),
        ("artifacts", Json::Arr(artifacts)),
        (
            "weights",
            obj(vec![
                ("file", s("weights.bin")),
                ("total_bytes", num(offset as f64)),
                ("tensors", Json::Arr(tensors)),
            ]),
        ),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string())?;
    Ok(())
}

fn write_golden_json(dir: &std::path::Path, golden: &GoldenCases) {
    // The bare `golden.json` name is reserved for the reference backend so
    // a simd-flavoured run (e.g. TARRAGON_KERNEL_BACKEND=simd in CI) can
    // never poison the shared cached artifact directory for reference runs.
    let kind = kern::default_kind().resolve();
    let file = match kind {
        kern::BackendKind::Reference => "golden.json".to_string(),
        _ => format!("golden-{}.json", kind.name()),
    };
    let path = dir.join(file);
    if path.exists() {
        return;
    }
    let cases = golden.iter().map(|(p, g)| {
        obj(vec![
            ("prompt", arr(p.iter().map(|&x| num(x as f64)))),
            ("generated", arr(g.iter().map(|&x| num(x as f64)))),
        ])
    });
    let _ = std::fs::write(path, obj(vec![("cases", arr(cases))]).to_string());
}

// ---------------------------------------------------------------------------
// Reference decoder (the golden oracle)
// ---------------------------------------------------------------------------

/// Generate the golden fixture with a single monolithic device, mirroring
/// the cluster's numerics step for step. Runs on the process-default
/// kernel backend (see [`kern::default_kind`]).
pub fn golden_cases(manifest: &Arc<Manifest>, weights: &Weights) -> GoldenCases {
    golden_cases_on(manifest, weights, kern::default_kind())
}

/// [`golden_cases`] pinned to an explicit kernel backend. The cross-backend
/// suites use this to regenerate goldens under `simd` in-process and
/// compare them against a cluster configured with the same backend.
pub fn golden_cases_on(
    manifest: &Arc<Manifest>,
    weights: &Weights,
    kind: kern::BackendKind,
) -> GoldenCases {
    let dev = Device::spawn_kernel(
        "synthetic-oracle",
        manifest.clone(),
        weights.clone(),
        DeviceRole::Monolithic.plan(manifest),
        Duration::ZERO,
        Clock::wall(),
        kind,
    )
    .expect("oracle device");
    let out = GOLDEN_CASES
        .iter()
        .map(|&(prompt, n_dec)| {
            let generated = reference_generate(&dev, manifest, weights, prompt, n_dec);
            (prompt.to_vec(), generated)
        })
        .collect();
    dev.shutdown();
    out
}

fn attn_weight_args(layer: usize) -> Vec<ArgValue> {
    vec![
        ArgValue::weight(format!("layer{layer}.wq")),
        ArgValue::weight(format!("layer{layer}.wk")),
        ArgValue::weight(format!("layer{layer}.wv")),
        ArgValue::weight(format!("layer{layer}.wo")),
        ArgValue::weight(format!("layer{layer}.ln1")),
        ArgValue::weight(format!("layer{layer}.ln2")),
    ]
}

/// One request, one device: prefill + token-by-token decode. Numerically
/// identical to the cluster path because every kernel is row-independent,
/// attention is causal/pos-masked, and expert contributions accumulate in
/// expert-ascending order on both sides.
pub fn reference_generate(
    dev: &Device,
    manifest: &Manifest,
    weights: &Weights,
    prompt: &[u32],
    n_dec: usize,
) -> Vec<u32> {
    let m = &manifest.model;
    let seg = m.kv_heads * m.head_dim;
    let mut kv: Vec<(Vec<f32>, Vec<f32>)> =
        vec![(vec![0.0; m.max_seq * seg], vec![0.0; m.max_seq * seg]); m.layers];
    let mut len = 0usize;
    let mut out = Vec::with_capacity(n_dec);

    // --- prefill -------------------------------------------------------
    let p_len = prompt.len();
    let bucket = Buckets::fit(&manifest.buckets.prefill_t, p_len).expect("prompt fits");
    let mut x = Tensor::zeros(vec![bucket, m.hidden]);
    for (i, &tok) in prompt.iter().enumerate() {
        x.row_mut(i).copy_from_slice(weights.embed_row(tok as usize));
    }
    for layer in 0..m.layers {
        let mut args = vec![ArgValue::f32(x.clone())];
        args.extend(attn_weight_args(layer));
        let outs = dev.execute(&format!("attn_prefill_t{bucket}"), args).expect("prefill");
        let (h, g, k, v) = unpack4(outs);
        for pos in 0..p_len {
            kv[layer].0[pos * seg..(pos + 1) * seg].copy_from_slice(k.row(pos));
            kv[layer].1[pos * seg..(pos + 1) * seg].copy_from_slice(v.row(pos));
        }
        let mut h = h;
        expert_mix(dev, layer, &g, p_len, m.top_k, &mut h);
        for pos in p_len..bucket {
            h.row_mut(pos).fill(0.0);
        }
        x = h;
    }
    len = len.max(p_len);
    let mut next = lm_head(dev, manifest, x.row(p_len - 1));
    out.push(next);

    // --- decode --------------------------------------------------------
    for _ in 1..n_dec {
        let bucket = Buckets::fit(&manifest.buckets.decode_b, 1).expect("decode bucket");
        let mut x = Tensor::zeros(vec![bucket, m.hidden]);
        x.row_mut(0).copy_from_slice(weights.embed_row(next as usize));
        for layer in 0..m.layers {
            let row = m.max_seq * seg;
            let mut kc = vec![0.0f32; bucket * row];
            let mut vc = vec![0.0f32; bucket * row];
            kc[..len * seg].copy_from_slice(&kv[layer].0[..len * seg]);
            vc[..len * seg].copy_from_slice(&kv[layer].1[..len * seg]);
            let mut pos = vec![len as i32];
            pos.resize(bucket, 0);
            let shape = vec![bucket, m.max_seq, m.kv_heads, m.head_dim];
            let mut args = vec![
                ArgValue::f32(x.clone()),
                ArgValue::f32(Tensor::new(shape.clone(), kc)),
                ArgValue::f32(Tensor::new(shape, vc)),
                ArgValue::I32(pos, vec![bucket]),
            ];
            args.extend(attn_weight_args(layer));
            let outs = dev.execute(&format!("attn_decode_b{bucket}"), args).expect("decode");
            let (h, g, k_new, v_new) = unpack4(outs);
            kv[layer].0[len * seg..(len + 1) * seg].copy_from_slice(k_new.row(0));
            kv[layer].1[len * seg..(len + 1) * seg].copy_from_slice(v_new.row(0));
            let mut h = h;
            expert_mix(dev, layer, &g, 1, m.top_k, &mut h);
            for i in 1..bucket {
                h.row_mut(i).fill(0.0);
            }
            x = h;
        }
        len += 1;
        next = lm_head(dev, manifest, x.row(0));
        out.push(next);
    }
    out
}

/// Route the first `rows` of `g` and accumulate expert outputs into `h`,
/// expert-ascending — the cluster's canonical accumulation order.
fn expert_mix(dev: &Device, layer: usize, g: &Tensor, rows: usize, top_k: usize, h: &mut Tensor) {
    let bucket = g.rows();
    let probs = dev
        .execute(
            &format!("router_b{bucket}"),
            vec![ArgValue::f32(g.clone()), ArgValue::weight(format!("layer{layer}.router"))],
        )
        .expect("router");
    let routes = router::select_top_k(&probs[0], rows, top_k);
    let groups = ExpertGroups::from_routes(&routes);
    let hidden = g.row_len();
    for (&expert, entries) in &groups.groups {
        // Mirror the EW's chunked execution over the expert buckets.
        let rows_data: Vec<&[f32]> = entries.iter().map(|&(row, _)| g.row(row)).collect();
        let outs = run_expert_chunked(dev, layer, expert, &rows_data, hidden);
        for ((row, w), out) in entries.iter().zip(outs) {
            ops::axpy_row(h.row_mut(*row), *w, &out);
        }
    }
}

fn run_expert_chunked(
    dev: &Device,
    layer: usize,
    expert: usize,
    rows: &[&[f32]],
    hidden: usize,
) -> Vec<Vec<f32>> {
    let buckets = EXPERT_B;
    let max_bucket = *buckets.last().unwrap();
    let mut out = Vec::with_capacity(rows.len());
    let mut i = 0;
    while i < rows.len() {
        let n = (rows.len() - i).min(max_bucket);
        let bucket = Buckets::fit(&buckets, n).unwrap_or(max_bucket);
        let mut data = vec![0.0f32; bucket * hidden];
        for (j, row) in rows[i..i + n].iter().enumerate() {
            data[j * hidden..(j + 1) * hidden].copy_from_slice(row);
        }
        let result = dev
            .execute(
                &format!("expert_b{bucket}"),
                vec![
                    ArgValue::f32(Tensor::new(vec![bucket, hidden], data)),
                    ArgValue::weight(format!("layer{layer}.expert{expert}.w1")),
                    ArgValue::weight(format!("layer{layer}.expert{expert}.w3")),
                    ArgValue::weight(format!("layer{layer}.expert{expert}.w2")),
                ],
            )
            .expect("expert");
        for j in 0..n {
            out.push(result[0].row(j).to_vec());
        }
        i += n;
    }
    out
}

fn lm_head(dev: &Device, manifest: &Manifest, row: &[f32]) -> u32 {
    let m = &manifest.model;
    let bucket = Buckets::fit(&manifest.buckets.lm_head_b, 1).expect("lm bucket");
    let mut x = Tensor::zeros(vec![bucket, m.hidden]);
    x.row_mut(0).copy_from_slice(row);
    let outs = dev
        .execute(
            &format!("lm_head_b{bucket}"),
            vec![ArgValue::f32(x), ArgValue::weight("ln_f"), ArgValue::weight("lm_head")],
        )
        .expect("lm_head");
    ops::argmax(outs[0].row(0)) as u32
}

fn unpack4(mut outs: Vec<Tensor>) -> (Tensor, Tensor, Tensor, Tensor) {
    assert_eq!(outs.len(), 4);
    let v = outs.pop().unwrap();
    let k = outs.pop().unwrap();
    let g = outs.pop().unwrap();
    let h = outs.pop().unwrap();
    (h, g, k, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_manifest_roundtrips_through_loader() {
        let (m, w, _) = ensure();
        assert_eq!(m.model.layers, LAYERS);
        assert_eq!(m.model.hidden, HEADS * HEAD_DIM);
        assert_eq!(m.model.experts, EXPERTS);
        // All five artifact kinds present, files on disk.
        for a in &m.artifacts {
            assert!(m.hlo_path(a).exists(), "missing {}", a.file);
        }
        // Weight table resolves through the blob.
        let (embed, shape) = w.expect("embed");
        assert_eq!(shape, &[VOCAB, HIDDEN]);
        assert_eq!(embed.len(), VOCAB * HIDDEN);
        let (ln, _) = w.expect("layer0.ln1");
        assert!(ln.iter().all(|&x| x == 1.0));
        assert!(w.get(&format!("layer{}.expert{}.w2", LAYERS - 1, EXPERTS - 1)).is_some());
    }

    #[test]
    fn golden_cases_are_deterministic_and_in_vocab() {
        let (m, w, golden) = ensure();
        assert_eq!(golden.len(), GOLDEN_CASES.len());
        for (prompt, gen) in &golden {
            assert!(!gen.is_empty());
            assert!(gen.iter().all(|&t| (t as usize) < m.model.vocab));
            assert!(prompt.len() + gen.len() <= m.model.max_seq);
        }
        // Re-running the oracle reproduces the fixture bit for bit.
        let again = golden_cases(&m, &w);
        assert_eq!(golden, again);
    }

    #[test]
    fn simd_goldens_are_deterministic_run_to_run() {
        let (m, w, _) = ensure();
        let a = golden_cases_on(&m, &w, kern::BackendKind::Simd);
        let b = golden_cases_on(&m, &w, kern::BackendKind::Simd);
        // Same input => same bits every run: the simd backend pins its
        // per-lane partial-sum order, so regenerated goldens are stable.
        assert_eq!(a, b);
        for (prompt, gen) in &a {
            assert!(!gen.is_empty());
            assert!(gen.iter().all(|&t| (t as usize) < m.model.vocab));
            assert!(prompt.len() + gen.len() <= m.model.max_seq);
        }
    }
}
