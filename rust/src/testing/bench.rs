//! Micro-benchmark harness (criterion is unavailable offline): warmup,
//! timed iterations, mean/median/p95 reporting, and a trivial
//! anti-optimization sink.

use crate::util::stats;
use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_us: stats::mean(&samples),
        median_us: stats::median(&samples),
        p95_us: stats::percentile(&samples, 95.0),
    };
    println!(
        "{:<44} {:>10.2} us/iter (median {:>10.2}, p95 {:>10.2}, n={})",
        r.name, r.mean_us, r.median_us, r.p95_us, r.iters
    );
    r
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Time a single invocation.
pub fn once<F: FnOnce()>(name: &str, f: F) -> Duration {
    let t0 = Instant::now();
    f();
    let dt = t0.elapsed();
    println!("{:<44} {:>10.2} ms (once)", name, dt.as_secs_f64() * 1e3);
    dt
}
