//! Minimal randomized property-testing harness (proptest is unavailable
//! offline). `check` runs a property over `n` seeded random cases and, on
//! failure, retries with the same seed after printing it — so failures are
//! reproducible by pinning `TARRAGON_PROP_SEED`.

use crate::util::rng::Pcg;

/// Run `prop(rng, case_index)` for `n` cases; panics with the failing seed.
pub fn check<F: FnMut(&mut Pcg, usize)>(name: &str, n: usize, mut prop: F) {
    let base = std::env::var("TARRAGON_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..n {
        let seed = base.wrapping_add(case as u64 * 0x9E3779B97F4A7C15);
        let mut rng = Pcg::seeded(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case)
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case} (TARRAGON_PROP_SEED={base}, case seed {seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        check("counts", 25, |_rng, _case| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    fn rng_is_seeded_per_case() {
        let mut firsts = Vec::new();
        check("seeds", 5, |rng, _| firsts.push(rng.next_u64()));
        firsts.sort();
        firsts.dedup();
        assert_eq!(firsts.len(), 5, "cases must get distinct streams");
    }
}
