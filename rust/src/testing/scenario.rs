//! Virtual-clock failure-scenario harness.
//!
//! A [`Scenario`] is a workload schedule plus a fault schedule — a tiny
//! DSL (`"at 120ms kill ew1"`, `"at 300ms sever aw0 store"`,
//! `"at 500ms respawn ew1"`) or the builder API — run against a full
//! cluster on a seeded [`VirtualClock`](crate::util::clock::VirtualClock).
//! Probe timeouts, silence windows, restart storms and idle gaps all cost
//! *virtual* time only, so multi-second recovery behavior replays in
//! milliseconds of wall time, deterministically: the same scenario and
//! seed yield a byte-identical event log, and the recovery guarantees
//! under test (token streams identical to the failure-free run) hold for
//! every seed.
//!
//! Fault times are offsets from the schedule start (the event-log epoch),
//! matching `Request::arrival_s`.

use crate::config::Config;
use crate::coordinator::cluster::{Cluster, ClusterReport, LaunchOptions};
use crate::modelcfg::{weights::Weights, Manifest};
use crate::transport::NodeId;
use crate::util::clock::Clock;
use crate::workload::Request;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// One injectable fault (or planned reconfiguration verb).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    KillAw(u32),
    KillEw(u32),
    Sever(NodeId, NodeId),
    Heal(NodeId, NodeId),
    RespawnAw(u32),
    RespawnEw(u32),
    /// Planned drain: migrate everything off the AW and stop routing new
    /// requests to it (scale-in / maintenance, DESIGN.md §9).
    DrainAw(u32),
    /// Planned migration: drain `from`, steering its requests onto `to`.
    MigrateAw(u32, u32),
}

/// A fault scheduled at an offset from the schedule start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduledFault {
    pub at: Duration,
    pub fault: Fault,
}

impl ScheduledFault {
    /// Parse one DSL line: `at <N>(us|ms|s) <verb> <node> [<node>]`, e.g.
    /// `at 120ms kill ew1`, `at 300ms sever aw0 store`,
    /// `at 800ms respawn aw0`, `at 900ms heal aw0 store`.
    pub fn parse(line: &str) -> Result<ScheduledFault, String> {
        let toks: Vec<&str> = line.split_whitespace().collect();
        let bad = |msg: &str| Err(format!("bad fault '{line}': {msg}"));
        if toks.len() < 4 || toks[0] != "at" {
            return bad("expected `at <time> <verb> <node> [<node>]`");
        }
        let at = parse_time(toks[1]).ok_or_else(|| format!("bad fault '{line}': bad time"))?;
        let verb = toks[2];
        let node =
            |t: &str| parse_node(t).ok_or_else(|| format!("bad fault '{line}': bad node '{t}'"));
        let fault = match (verb, toks.len()) {
            ("kill", 4) => match node(toks[3])? {
                NodeId::Aw(i) => Fault::KillAw(i),
                NodeId::Ew(i) => Fault::KillEw(i),
                other => return bad(&format!("cannot kill {other}")),
            },
            ("respawn", 4) => match node(toks[3])? {
                NodeId::Aw(i) => Fault::RespawnAw(i),
                NodeId::Ew(i) => Fault::RespawnEw(i),
                other => return bad(&format!("cannot respawn {other}")),
            },
            ("drain", 4) => match node(toks[3])? {
                NodeId::Aw(i) => Fault::DrainAw(i),
                other => return bad(&format!("cannot drain {other} (AWs only)")),
            },
            ("migrate", 5) => match (node(toks[3])?, node(toks[4])?) {
                (NodeId::Aw(a), NodeId::Aw(b)) => Fault::MigrateAw(a, b),
                _ => return bad("migrate takes two AWs"),
            },
            ("sever", 5) => Fault::Sever(node(toks[3])?, node(toks[4])?),
            ("heal", 5) => Fault::Heal(node(toks[3])?, node(toks[4])?),
            _ => {
                return bad(
                    "unknown verb/arity (kill|respawn|drain <node>, \
                     sever|heal|migrate <a> <b>)",
                )
            }
        };
        Ok(ScheduledFault { at, fault })
    }
}

fn parse_time(t: &str) -> Option<Duration> {
    let (digits, unit): (&str, &str) = if let Some(v) = t.strip_suffix("us") {
        (v, "us")
    } else if let Some(v) = t.strip_suffix("ms") {
        (v, "ms")
    } else if let Some(v) = t.strip_suffix('s') {
        (v, "s")
    } else {
        return None;
    };
    let n: f64 = digits.parse().ok()?;
    if n < 0.0 || !n.is_finite() {
        return None;
    }
    Some(match unit {
        "us" => Duration::from_secs_f64(n / 1e6),
        "ms" => Duration::from_secs_f64(n / 1e3),
        _ => Duration::from_secs_f64(n),
    })
}

fn parse_node(t: &str) -> Option<NodeId> {
    match t {
        "store" => return Some(NodeId::Store),
        "gateway" => return Some(NodeId::Gateway),
        "orch" | "orchestrator" => return Some(NodeId::Orchestrator),
        _ => {}
    }
    if let Some(i) = t.strip_prefix("aw") {
        return i.parse().ok().map(NodeId::Aw);
    }
    if let Some(i) = t.strip_prefix("ew") {
        return i.parse().ok().map(NodeId::Ew);
    }
    None
}

/// A complete scenario: cluster config, workload arrivals, fault schedule.
#[derive(Clone)]
pub struct Scenario {
    pub name: String,
    pub seed: u64,
    pub cfg: Config,
    pub schedule: Vec<Request>,
    pub faults: Vec<ScheduledFault>,
    /// Virtual-time budget for the workload to drain.
    pub drain_timeout: Duration,
}

impl Scenario {
    pub fn new(name: impl Into<String>, cfg: Config) -> Scenario {
        Scenario {
            name: name.into(),
            seed: 7,
            cfg,
            schedule: Vec::new(),
            faults: Vec::new(),
            drain_timeout: Duration::from_secs(60),
        }
    }

    pub fn seed(mut self, seed: u64) -> Scenario {
        self.seed = seed;
        self
    }

    /// Add a workload arrival.
    pub fn request(
        mut self,
        id: u64,
        arrival: Duration,
        prompt: Vec<u32>,
        max_new: usize,
    ) -> Scenario {
        self.schedule.push(Request {
            id,
            arrival_s: arrival.as_secs_f64(),
            prompt,
            max_new_tokens: max_new,
        });
        self
    }

    /// Add a fault from a DSL line (`at 120ms kill ew1`). Panics on a
    /// malformed line — scenarios are authored in tests.
    pub fn fault(mut self, line: &str) -> Scenario {
        self.faults.push(ScheduledFault::parse(line).unwrap());
        self
    }

    pub fn fault_at(mut self, at: Duration, fault: Fault) -> Scenario {
        self.faults.push(ScheduledFault { at, fault });
        self
    }

    /// A copy with the fault schedule stripped — the failure-free baseline
    /// the matrix tests compare token streams against.
    pub fn without_faults(&self) -> Scenario {
        let mut s = self.clone();
        s.faults.clear();
        s.name = format!("{}-baseline", s.name);
        s
    }

    /// Run on a fresh virtual clock; blocks the calling thread (which is
    /// registered as a clock participant for the duration).
    pub fn run(&self, manifest: Arc<Manifest>, weights: Weights) -> ScenarioOutcome {
        let clock = Clock::virtual_seeded(self.seed);
        let guard = clock.register();
        let opts = LaunchOptions { clock: clock.clone(), ..Default::default() };
        let cluster =
            Cluster::launch(self.cfg.clone(), manifest, weights, self.schedule.clone(), opts);

        // The gateway's schedule clock and the event log both start at
        // launch return (bring-up excluded); anchor fault times there too.
        let t0 = clock.now();
        let mut faults = self.faults.clone();
        faults.sort_by_key(|f| f.at);
        for f in &faults {
            clock.sleep_until(t0 + f.at);
            apply(&cluster, &f.fault);
        }
        let completed = cluster.wait_done(self.drain_timeout);
        let tokens: BTreeMap<u64, Vec<u32>> = self
            .schedule
            .iter()
            .map(|r| (r.id, cluster.gw.generated_of(r.id)))
            .collect();
        let event_log = cluster.events.render();
        let rejections = cluster.gw.rejections();
        let kv_peaks = cluster.spawner.kv_peaks();
        let kv_budget = self.cfg.sched.kv_budget_pages;
        let report = cluster.finish(1.0);
        drop(guard);
        ScenarioOutcome {
            name: self.name.clone(),
            completed,
            tokens,
            event_log,
            rejections,
            kv_peaks,
            kv_budget,
            report,
        }
    }
}

fn apply(cluster: &Cluster, fault: &Fault) {
    match fault {
        Fault::KillAw(i) => cluster.kill_aw(*i),
        Fault::KillEw(i) => cluster.kill_ew(*i),
        Fault::Sever(a, b) => cluster.fabric.sever(*a, *b),
        Fault::Heal(a, b) => cluster.fabric.heal(*a, *b),
        Fault::RespawnAw(i) => {
            let _ = cluster.respawn_aw(*i);
        }
        Fault::RespawnEw(i) => {
            let _ = cluster.respawn_ew(*i);
        }
        Fault::DrainAw(i) => cluster.drain_aw(*i),
        Fault::MigrateAw(a, b) => cluster.migrate_aw(*a, *b),
    }
}

/// What a scenario run yields for assertions.
pub struct ScenarioOutcome {
    pub name: String,
    /// Whether the workload drained within the virtual budget.
    pub completed: bool,
    /// Per-request generated token streams (gateway-deduped).
    pub tokens: BTreeMap<u64, Vec<u32>>,
    /// Canonical event-log rendering (byte-comparable across runs).
    pub event_log: String,
    /// Rejected requests with their stream-level errors.
    pub rejections: BTreeMap<u64, String>,
    /// Peak pages-in-use per AW arena (budget-invariant assertions).
    pub kv_peaks: BTreeMap<u32, usize>,
    /// The configured per-AW page budget (0 = unbounded).
    pub kv_budget: usize,
    pub report: ClusterReport,
}

impl ScenarioOutcome {
    /// Panics if any AW arena ever exceeded the configured page budget.
    pub fn assert_kv_budget_held(&self) {
        if self.kv_budget == 0 {
            return;
        }
        for (aw, &peak) in &self.kv_peaks {
            assert!(
                peak <= self.kv_budget,
                "{}: aw{aw} peaked at {peak} pages (budget {})",
                self.name,
                self.kv_budget
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsl_parses_the_issue_examples() {
        assert_eq!(
            ScheduledFault::parse("at 120ms kill ew1").unwrap(),
            ScheduledFault { at: Duration::from_millis(120), fault: Fault::KillEw(1) }
        );
        assert_eq!(
            ScheduledFault::parse("at 300ms sever aw0 store").unwrap(),
            ScheduledFault {
                at: Duration::from_millis(300),
                fault: Fault::Sever(NodeId::Aw(0), NodeId::Store),
            }
        );
        assert_eq!(
            ScheduledFault::parse("at 2s respawn aw3").unwrap(),
            ScheduledFault { at: Duration::from_secs(2), fault: Fault::RespawnAw(3) }
        );
        assert_eq!(
            ScheduledFault::parse("at 50us heal aw0 ew0").unwrap(),
            ScheduledFault {
                at: Duration::from_micros(50),
                fault: Fault::Heal(NodeId::Aw(0), NodeId::Ew(0)),
            }
        );
        assert_eq!(
            ScheduledFault::parse("at 500ms drain aw0").unwrap(),
            ScheduledFault { at: Duration::from_millis(500), fault: Fault::DrainAw(0) }
        );
        assert_eq!(
            ScheduledFault::parse("at 1s migrate aw0 aw1").unwrap(),
            ScheduledFault { at: Duration::from_secs(1), fault: Fault::MigrateAw(0, 1) }
        );
    }

    #[test]
    fn dsl_rejects_malformed_lines() {
        for bad in [
            "kill ew1",
            "at 10ms",
            "at 10ms kill store",
            "at 10ms kill",
            "at tenms kill ew1",
            "at 10ms sever aw0",
            "at 10ms explode ew0",
            "at 10ms kill zz9",
            "at 10ms drain ew0",
            "at 10ms drain store",
            "at 10ms migrate aw0 ew1",
            "at 10ms migrate aw0",
        ] {
            assert!(ScheduledFault::parse(bad).is_err(), "accepted: {bad}");
        }
    }
}
