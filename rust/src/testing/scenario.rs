//! Virtual-clock failure-scenario harness.
//!
//! A [`Scenario`] is a workload schedule plus a fault schedule — a tiny
//! DSL (`"at 120ms kill ew1"`, `"at 300ms sever aw0 store"`,
//! `"at 500ms respawn ew1"`) or the builder API — run against a full
//! cluster on a seeded [`VirtualClock`](crate::util::clock::VirtualClock).
//! Probe timeouts, silence windows, restart storms and idle gaps all cost
//! *virtual* time only, so multi-second recovery behavior replays in
//! milliseconds of wall time, deterministically: the same scenario and
//! seed yield a byte-identical event log, and the recovery guarantees
//! under test (token streams identical to the failure-free run) hold for
//! every seed.
//!
//! Fault times are offsets from the schedule start (the event-log epoch),
//! matching `Request::arrival_s`.

use crate::config::Config;
use crate::coordinator::cluster::{Cluster, ClusterReport, LaunchOptions};
use crate::metrics::trace::Span;
use crate::metrics::RecoveryReport;
use crate::modelcfg::{weights::Weights, Manifest};
use crate::transport::NodeId;
use crate::util::clock::Clock;
use crate::workload::Request;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// One injectable fault (or planned reconfiguration verb).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    KillAw(u32),
    KillEw(u32),
    Sever(NodeId, NodeId),
    Heal(NodeId, NodeId),
    RespawnAw(u32),
    RespawnEw(u32),
    /// Planned drain: migrate everything off the AW and stop routing new
    /// requests to it (scale-in / maintenance, DESIGN.md §9).
    DrainAw(u32),
    /// Planned migration: drain `from`, steering its requests onto `to`.
    MigrateAw(u32, u32),
    /// Elastic scale-out (DESIGN.md §11): provision one fresh EW as a
    /// warm tail candidate for every expert.
    ScaleEwUp,
    /// Elastic scale-in: remap the EW's primaries onto the remaining
    /// candidates and retire it (rejected for a last replica).
    ScaleEwDown(u32),
    /// Fail-stop a checkpoint-store replica (DESIGN.md §15).
    KillStore(u32),
    /// Rebuild a killed store replica on its slot (anti-entropy re-sync
    /// from a surviving peer).
    RespawnStore(u32),
    /// Fail-stop a gateway shard; survivors re-admit its requests.
    KillGateway(u32),
    /// Fail-stop the active orchestrator (the warm standby promotes).
    KillOrch,
    /// Planned orchestrator handover: standby demotes the active, then
    /// assumes the role (zero-incident mobility).
    PromoteOrch,
    /// Drop a store replica's sealed-page content index — the
    /// `page_refs_missed` degradation: restores fall back to
    /// recompute/resubmit instead of page-ref resolution.
    CorruptStoreIndex(u32),
    /// Workload-shaping: skew the router onto expert K for the whole run
    /// (installed at launch regardless of the scheduled time, so token
    /// streams stay comparable across fault schedules; kept by
    /// [`Scenario::without_faults`] for the same reason).
    Hotspot(u32),
}

/// The DSL verb table — the single source for parsing, the usage/error
/// text, and the canonical rendering. Adding a verb means adding a row
/// here (the drift-guard tests parse every `example` and require the
/// error text to advertise every `name`).
pub const VERBS: &[VerbSpec] = &[
    VerbSpec {
        name: "kill",
        usage: "kill <aw|ew|store|gateway><N> | kill orch",
        example: "at 10ms kill ew1",
    },
    VerbSpec {
        name: "respawn",
        usage: "respawn <aw|ew|store><N>",
        example: "at 10ms respawn aw0",
    },
    VerbSpec { name: "drain", usage: "drain aw<N>", example: "at 10ms drain aw0" },
    VerbSpec { name: "sever", usage: "sever <node> <node>", example: "at 10ms sever aw0 ew0" },
    VerbSpec { name: "heal", usage: "heal <node> <node>", example: "at 10ms heal aw0 ew0" },
    VerbSpec { name: "migrate", usage: "migrate aw<A> aw<B>", example: "at 10ms migrate aw0 aw1" },
    VerbSpec {
        name: "scale_ew",
        usage: "scale_ew up | scale_ew down ew<N>",
        example: "at 10ms scale_ew down ew1",
    },
    VerbSpec { name: "hotspot", usage: "hotspot e<K>", example: "at 10ms hotspot e2" },
    VerbSpec { name: "promote", usage: "promote orch", example: "at 10ms promote orch" },
    VerbSpec {
        name: "corrupt_index",
        usage: "corrupt_index store<N>",
        example: "at 10ms corrupt_index store0",
    },
];

/// One row of the verb table.
#[derive(Debug, Clone, Copy)]
pub struct VerbSpec {
    pub name: &'static str,
    pub usage: &'static str,
    pub example: &'static str,
}

/// The usage string advertised by parse errors — generated from [`VERBS`]
/// so new verbs cannot drift out of the error text.
pub fn verb_usage() -> String {
    VERBS.iter().map(|v| v.usage).collect::<Vec<_>>().join(", ")
}

/// A fault scheduled at an offset from the schedule start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScheduledFault {
    pub at: Duration,
    pub fault: Fault,
}

impl ScheduledFault {
    /// Parse one DSL line: `at <N>(us|ms|s) <verb> ...`, e.g.
    /// `at 120ms kill ew1`, `at 300ms sever aw0 store`,
    /// `at 500ms scale_ew down ew0`, `at 0ms hotspot e2`.
    pub fn parse(line: &str) -> Result<ScheduledFault, String> {
        let toks: Vec<&str> = line.split_whitespace().collect();
        let bad = |msg: &str| Err(format!("bad fault '{line}': {msg}"));
        if toks.len() < 4 || toks[0] != "at" {
            return bad(&format!("expected `at <time> <verb> ...` ({})", verb_usage()));
        }
        let at = parse_time(toks[1]).ok_or_else(|| format!("bad fault '{line}': bad time"))?;
        let verb = toks[2];
        let Some(spec) = VERBS.iter().find(|v| v.name == verb) else {
            return bad(&format!("unknown verb '{verb}' (supported: {})", verb_usage()));
        };
        let node =
            |t: &str| parse_node(t).ok_or_else(|| format!("bad fault '{line}': bad node '{t}'"));
        let fault = match (verb, toks.len()) {
            ("kill", 4) => match node(toks[3])? {
                NodeId::Aw(i) => Fault::KillAw(i),
                NodeId::Ew(i) => Fault::KillEw(i),
                NodeId::Store(i) => Fault::KillStore(i),
                NodeId::Gateway(i) => Fault::KillGateway(i),
                NodeId::Orchestrator => Fault::KillOrch,
                other => return bad(&format!("cannot kill {other}")),
            },
            ("respawn", 4) => match node(toks[3])? {
                NodeId::Aw(i) => Fault::RespawnAw(i),
                NodeId::Ew(i) => Fault::RespawnEw(i),
                NodeId::Store(i) => Fault::RespawnStore(i),
                other => return bad(&format!("cannot respawn {other}")),
            },
            ("promote", 4) => match node(toks[3])? {
                NodeId::Orchestrator => Fault::PromoteOrch,
                other => return bad(&format!("cannot promote {other} (orch only)")),
            },
            ("corrupt_index", 4) => match node(toks[3])? {
                NodeId::Store(i) => Fault::CorruptStoreIndex(i),
                other => return bad(&format!("cannot corrupt {other} (stores only)")),
            },
            ("drain", 4) => match node(toks[3])? {
                NodeId::Aw(i) => Fault::DrainAw(i),
                other => return bad(&format!("cannot drain {other} (AWs only)")),
            },
            ("migrate", 5) => match (node(toks[3])?, node(toks[4])?) {
                (NodeId::Aw(a), NodeId::Aw(b)) => Fault::MigrateAw(a, b),
                _ => return bad("migrate takes two AWs"),
            },
            ("sever", 5) => Fault::Sever(node(toks[3])?, node(toks[4])?),
            ("heal", 5) => Fault::Heal(node(toks[3])?, node(toks[4])?),
            ("scale_ew", 4) if toks[3] == "up" => Fault::ScaleEwUp,
            ("scale_ew", 5) if toks[3] == "down" => match node(toks[4])? {
                NodeId::Ew(i) => Fault::ScaleEwDown(i),
                other => return bad(&format!("cannot scale down {other} (EWs only)")),
            },
            ("hotspot", 4) => {
                let expert = toks[3]
                    .strip_prefix('e')
                    .and_then(|v| v.parse::<u32>().ok())
                    .ok_or_else(|| format!("bad fault '{line}': bad expert '{}'", toks[3]))?;
                Fault::Hotspot(expert)
            }
            _ => return bad(&format!("bad arity for '{verb}' (usage: {})", spec.usage)),
        };
        Ok(ScheduledFault { at, fault })
    }
}

impl std::fmt::Display for Fault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fault::KillAw(i) => write!(f, "kill aw{i}"),
            Fault::KillEw(i) => write!(f, "kill ew{i}"),
            Fault::Sever(a, b) => write!(f, "sever {a} {b}"),
            Fault::Heal(a, b) => write!(f, "heal {a} {b}"),
            Fault::RespawnAw(i) => write!(f, "respawn aw{i}"),
            Fault::RespawnEw(i) => write!(f, "respawn ew{i}"),
            Fault::DrainAw(i) => write!(f, "drain aw{i}"),
            Fault::MigrateAw(a, b) => write!(f, "migrate aw{a} aw{b}"),
            Fault::ScaleEwUp => write!(f, "scale_ew up"),
            Fault::ScaleEwDown(i) => write!(f, "scale_ew down ew{i}"),
            Fault::Hotspot(e) => write!(f, "hotspot e{e}"),
            Fault::KillStore(i) => write!(f, "kill store{i}"),
            Fault::RespawnStore(i) => write!(f, "respawn store{i}"),
            Fault::KillGateway(i) => write!(f, "kill gateway{i}"),
            Fault::KillOrch => write!(f, "kill orch"),
            Fault::PromoteOrch => write!(f, "promote orch"),
            Fault::CorruptStoreIndex(i) => write!(f, "corrupt_index store{i}"),
        }
    }
}

impl std::fmt::Display for ScheduledFault {
    /// Canonical DSL rendering — `parse(x.to_string())` round-trips, so
    /// failing chaos schedules print in directly replayable form.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "at {}us {}", self.at.as_micros(), self.fault)
    }
}

fn parse_time(t: &str) -> Option<Duration> {
    let (digits, unit): (&str, &str) = if let Some(v) = t.strip_suffix("us") {
        (v, "us")
    } else if let Some(v) = t.strip_suffix("ms") {
        (v, "ms")
    } else if let Some(v) = t.strip_suffix('s') {
        (v, "s")
    } else {
        return None;
    };
    let n: f64 = digits.parse().ok()?;
    if n < 0.0 || !n.is_finite() {
        return None;
    }
    Some(match unit {
        "us" => Duration::from_secs_f64(n / 1e6),
        "ms" => Duration::from_secs_f64(n / 1e3),
        _ => Duration::from_secs_f64(n),
    })
}

fn parse_node(t: &str) -> Option<NodeId> {
    match t {
        // Bare role names address replica/shard 0 (the single-instance
        // deployments every pre-§15 scenario was written against).
        "store" => return Some(NodeId::Store(0)),
        "gateway" => return Some(NodeId::Gateway(0)),
        "orch" | "orchestrator" => return Some(NodeId::Orchestrator),
        _ => {}
    }
    if let Some(i) = t.strip_prefix("aw") {
        return i.parse().ok().map(NodeId::Aw);
    }
    if let Some(i) = t.strip_prefix("ew") {
        return i.parse().ok().map(NodeId::Ew);
    }
    if let Some(i) = t.strip_prefix("store") {
        return i.parse().ok().map(NodeId::Store);
    }
    if let Some(i) = t.strip_prefix("gateway") {
        return i.parse().ok().map(NodeId::Gateway);
    }
    None
}

/// A complete scenario: cluster config, workload arrivals, fault schedule.
#[derive(Clone)]
pub struct Scenario {
    pub name: String,
    pub seed: u64,
    pub cfg: Config,
    pub schedule: Vec<Request>,
    pub faults: Vec<ScheduledFault>,
    /// Virtual-time budget for the workload to drain.
    pub drain_timeout: Duration,
}

impl Scenario {
    pub fn new(name: impl Into<String>, cfg: Config) -> Scenario {
        Scenario {
            name: name.into(),
            seed: 7,
            cfg,
            schedule: Vec::new(),
            faults: Vec::new(),
            drain_timeout: Duration::from_secs(60),
        }
    }

    pub fn seed(mut self, seed: u64) -> Scenario {
        self.seed = seed;
        self
    }

    /// Add a workload arrival.
    pub fn request(
        mut self,
        id: u64,
        arrival: Duration,
        prompt: Vec<u32>,
        max_new: usize,
    ) -> Scenario {
        self.schedule.push(Request {
            id,
            arrival_s: arrival.as_secs_f64(),
            prompt,
            max_new_tokens: max_new,
        });
        self
    }

    /// Add a fault from a DSL line (`at 120ms kill ew1`). Panics on a
    /// malformed line — scenarios are authored in tests.
    pub fn fault(mut self, line: &str) -> Scenario {
        self.faults.push(ScheduledFault::parse(line).unwrap());
        self
    }

    pub fn fault_at(mut self, at: Duration, fault: Fault) -> Scenario {
        self.faults.push(ScheduledFault { at, fault });
        self
    }

    /// A copy with the fault schedule stripped — the failure-free baseline
    /// the matrix tests compare token streams against. Workload-shaping
    /// verbs (`hotspot`) are kept: they define *what* is computed, not
    /// what fails, so the baseline must compute the same streams.
    pub fn without_faults(&self) -> Scenario {
        let mut s = self.clone();
        s.faults.retain(|f| matches!(f.fault, Fault::Hotspot(_)));
        s.name = format!("{}-baseline", s.name);
        s
    }

    /// Run on a fresh virtual clock; blocks the calling thread (which is
    /// registered as a clock participant for the duration).
    pub fn run(&self, manifest: Arc<Manifest>, weights: Weights) -> ScenarioOutcome {
        let clock = Clock::virtual_seeded(self.seed);
        let guard = clock.register();
        let opts = LaunchOptions { clock: clock.clone(), ..Default::default() };
        // Hotspot verbs are workload-shaping: they configure the routers
        // at launch (whole-run skew) rather than firing at their
        // scheduled time — a mid-run routing flip would make streams
        // depend on where each request's decode happened to be when the
        // flip landed, destroying cross-schedule comparability.
        let mut cfg = self.cfg.clone();
        let mut timed: Vec<ScheduledFault> = Vec::new();
        for f in &self.faults {
            match f.fault {
                Fault::Hotspot(e) => cfg.workload.hotspot_expert = Some(e as usize),
                _ => timed.push(f.clone()),
            }
        }
        let cluster = Cluster::launch(cfg, manifest, weights, self.schedule.clone(), opts);

        // The gateway's schedule clock and the event log both start at
        // launch return (bring-up excluded); anchor fault times there too.
        let t0 = clock.now();
        let mut faults = timed;
        faults.sort_by_key(|f| f.at);
        for f in &faults {
            clock.sleep_until(t0 + f.at);
            apply(&cluster, &f.fault);
        }
        let completed = cluster.wait_done(self.drain_timeout);
        let rejections = cluster.gw.rejections();
        // A scheduled request with no token stream after a completed
        // drain is *lost*, not "finished empty" — only rejected requests
        // may legitimately lack one. (`generated_of` returning `Option`
        // is what makes this detectable; it used to default to empty.)
        let tokens: BTreeMap<u64, Vec<u32>> = self
            .schedule
            .iter()
            .map(|r| {
                let stream = cluster.gw.generated_of(r.id).unwrap_or_else(|| {
                    assert!(
                        !completed || rejections.contains_key(&r.id),
                        "scenario {}: request {} was lost (drained with no \
                         token stream and no rejection)",
                        self.name,
                        r.id
                    );
                    Vec::new()
                });
                (r.id, stream)
            })
            .collect();
        let event_log = cluster.events.render();
        let recovery = RecoveryReport::from_log(&cluster.events);
        let spans = cluster.tracer.as_ref().map(|t| t.snapshot()).unwrap_or_default();
        let kv_peaks = cluster.spawner.kv_peaks();
        let kv_budget = self.cfg.sched.kv_budget_pages;
        let report = cluster.finish(1.0);
        drop(guard);
        ScenarioOutcome {
            name: self.name.clone(),
            completed,
            tokens,
            event_log,
            recovery,
            spans,
            rejections,
            kv_peaks,
            kv_budget,
            report,
        }
    }
}

fn apply(cluster: &Cluster, fault: &Fault) {
    match fault {
        Fault::KillAw(i) => cluster.kill_aw(*i),
        Fault::KillEw(i) => cluster.kill_ew(*i),
        Fault::Sever(a, b) => cluster.fabric.sever(*a, *b),
        Fault::Heal(a, b) => cluster.fabric.heal(*a, *b),
        Fault::RespawnAw(i) => {
            let _ = cluster.respawn_aw(*i);
        }
        Fault::RespawnEw(i) => {
            let _ = cluster.respawn_ew(*i);
        }
        Fault::DrainAw(i) => cluster.drain_aw(*i),
        Fault::MigrateAw(a, b) => cluster.migrate_aw(*a, *b),
        Fault::ScaleEwUp => cluster.scale_ew_up(),
        Fault::ScaleEwDown(i) => cluster.scale_ew_down(*i),
        Fault::KillStore(i) => cluster.kill_store(*i),
        Fault::RespawnStore(i) => {
            let _ = cluster.respawn_store(*i);
        }
        Fault::KillGateway(i) => cluster.kill_gateway(*i),
        Fault::KillOrch => cluster.kill_orch(),
        Fault::PromoteOrch => cluster.promote_orch(),
        Fault::CorruptStoreIndex(i) => cluster.corrupt_store_index(*i),
        // Workload-shaping: consumed at launch by `Scenario::run`.
        Fault::Hotspot(_) => {}
    }
}

/// What a scenario run yields for assertions.
pub struct ScenarioOutcome {
    pub name: String,
    /// Whether the workload drained within the virtual budget.
    pub completed: bool,
    /// Per-request generated token streams (gateway-deduped).
    pub tokens: BTreeMap<u64, Vec<u32>>,
    /// Canonical event-log rendering (byte-comparable across runs).
    pub event_log: String,
    /// Per-victim stall anatomy recovered from the failure-lifecycle
    /// events (empty when no fault was detected).
    pub recovery: RecoveryReport,
    /// Trace spans captured during the run; empty unless
    /// `cfg.trace.enabled` was set.
    pub spans: Vec<Span>,
    /// Rejected requests with their stream-level errors.
    pub rejections: BTreeMap<u64, String>,
    /// Peak pages-in-use per AW arena (budget-invariant assertions).
    pub kv_peaks: BTreeMap<u32, usize>,
    /// The configured per-AW page budget (0 = unbounded).
    pub kv_budget: usize,
    pub report: ClusterReport,
}

impl ScenarioOutcome {
    /// Panics if any AW arena ever exceeded the configured page budget.
    pub fn assert_kv_budget_held(&self) {
        if self.kv_budget == 0 {
            return;
        }
        for (aw, &peak) in &self.kv_peaks {
            assert!(
                peak <= self.kv_budget,
                "{}: aw{aw} peaked at {peak} pages (budget {})",
                self.name,
                self.kv_budget
            );
        }
    }

    /// Panics unless the run's `RecoveryReport` shows at least
    /// `min_incidents` detected faults, every incident was detected
    /// within `max_detect`, every victim's total stall stayed within
    /// `max_stall`, and each victim's phase decomposition is coherent
    /// (no negative phases; stall covers at least the detect phase).
    pub fn assert_recovery(&self, min_incidents: usize, max_detect: Duration, max_stall: Duration) {
        let r = &self.recovery;
        assert!(
            r.incidents.len() >= min_incidents,
            "{}: expected >= {min_incidents} recovery incidents, got {}:\n{}",
            self.name,
            r.incidents.len(),
            r.render()
        );
        assert!(
            r.max_detect_s() <= max_detect.as_secs_f64(),
            "{}: detection took {:.3}s (budget {:?}):\n{}",
            self.name,
            r.max_detect_s(),
            max_detect,
            r.render()
        );
        assert!(
            r.max_total_stall_s() <= max_stall.as_secs_f64(),
            "{}: victim stalled {:.3}s (budget {:?}):\n{}",
            self.name,
            r.max_total_stall_s(),
            max_stall,
            r.render()
        );
        for v in r.victims() {
            let nonneg = v.detect_s >= 0.0
                && v.reroute_s >= 0.0
                && v.restore_s >= 0.0
                && v.recompute_s >= 0.0;
            assert!(nonneg, "{}: negative phase for req {}: {v:?}", self.name, v.request);
            assert!(
                v.total_stall_s + 1e-9 >= v.detect_s,
                "{}: stall smaller than its detect phase for req {}",
                self.name,
                v.request
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsl_parses_the_issue_examples() {
        assert_eq!(
            ScheduledFault::parse("at 120ms kill ew1").unwrap(),
            ScheduledFault { at: Duration::from_millis(120), fault: Fault::KillEw(1) }
        );
        assert_eq!(
            ScheduledFault::parse("at 300ms sever aw0 store").unwrap(),
            ScheduledFault {
                at: Duration::from_millis(300),
                fault: Fault::Sever(NodeId::Aw(0), NodeId::Store(0)),
            }
        );
        assert_eq!(
            ScheduledFault::parse("at 2s respawn aw3").unwrap(),
            ScheduledFault { at: Duration::from_secs(2), fault: Fault::RespawnAw(3) }
        );
        assert_eq!(
            ScheduledFault::parse("at 50us heal aw0 ew0").unwrap(),
            ScheduledFault {
                at: Duration::from_micros(50),
                fault: Fault::Heal(NodeId::Aw(0), NodeId::Ew(0)),
            }
        );
        assert_eq!(
            ScheduledFault::parse("at 500ms drain aw0").unwrap(),
            ScheduledFault { at: Duration::from_millis(500), fault: Fault::DrainAw(0) }
        );
        assert_eq!(
            ScheduledFault::parse("at 1s migrate aw0 aw1").unwrap(),
            ScheduledFault { at: Duration::from_secs(1), fault: Fault::MigrateAw(0, 1) }
        );
        assert_eq!(
            ScheduledFault::parse("at 100ms scale_ew up").unwrap(),
            ScheduledFault { at: Duration::from_millis(100), fault: Fault::ScaleEwUp }
        );
        assert_eq!(
            ScheduledFault::parse("at 100ms scale_ew down ew2").unwrap(),
            ScheduledFault { at: Duration::from_millis(100), fault: Fault::ScaleEwDown(2) }
        );
        assert_eq!(
            ScheduledFault::parse("at 0ms hotspot e3").unwrap(),
            ScheduledFault { at: Duration::ZERO, fault: Fault::Hotspot(3) }
        );
        // Control-plane verbs (DESIGN.md §15). A bare role name means
        // replica/shard 0.
        assert_eq!(
            ScheduledFault::parse("at 10ms kill store1").unwrap(),
            ScheduledFault { at: Duration::from_millis(10), fault: Fault::KillStore(1) }
        );
        assert_eq!(
            ScheduledFault::parse("at 10ms kill store").unwrap(),
            ScheduledFault { at: Duration::from_millis(10), fault: Fault::KillStore(0) }
        );
        assert_eq!(
            ScheduledFault::parse("at 10ms respawn store1").unwrap(),
            ScheduledFault { at: Duration::from_millis(10), fault: Fault::RespawnStore(1) }
        );
        assert_eq!(
            ScheduledFault::parse("at 10ms kill gateway0").unwrap(),
            ScheduledFault { at: Duration::from_millis(10), fault: Fault::KillGateway(0) }
        );
        assert_eq!(
            ScheduledFault::parse("at 10ms kill orch").unwrap(),
            ScheduledFault { at: Duration::from_millis(10), fault: Fault::KillOrch }
        );
        assert_eq!(
            ScheduledFault::parse("at 10ms promote orch").unwrap(),
            ScheduledFault { at: Duration::from_millis(10), fault: Fault::PromoteOrch }
        );
        assert_eq!(
            ScheduledFault::parse("at 10ms corrupt_index store0").unwrap(),
            ScheduledFault { at: Duration::from_millis(10), fault: Fault::CorruptStoreIndex(0) }
        );
    }

    #[test]
    fn dsl_rejects_malformed_lines() {
        for bad in [
            "kill ew1",
            "at 10ms",
            "at 10ms kill",
            "at tenms kill ew1",
            "at 10ms sever aw0",
            "at 10ms explode ew0",
            "at 10ms kill zz9",
            "at 10ms drain ew0",
            "at 10ms drain store",
            "at 10ms migrate aw0 ew1",
            "at 10ms migrate aw0",
            "at 10ms scale_ew sideways",
            "at 10ms scale_ew down aw0",
            "at 10ms scale_ew down",
            "at 10ms hotspot ew1",
            "at 10ms hotspot 3",
            "at 10ms respawn orch",
            "at 10ms promote aw0",
            "at 10ms promote",
            "at 10ms corrupt_index aw0",
            "at 10ms corrupt_index",
        ] {
            assert!(ScheduledFault::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    /// Drift guards: the verb table is the single source — every row's
    /// example parses, every row's name appears in the unknown-verb error
    /// text, and the canonical rendering round-trips through the parser.
    #[test]
    fn verb_table_examples_parse_and_errors_advertise_every_verb() {
        for spec in VERBS {
            let parsed = ScheduledFault::parse(spec.example)
                .unwrap_or_else(|e| panic!("example for '{}' failed: {e}", spec.name));
            // Round-trip: canonical rendering parses back to the same fault.
            let reparsed = ScheduledFault::parse(&parsed.to_string()).unwrap();
            assert_eq!(parsed, reparsed, "rendering of '{}' does not round-trip", spec.name);
        }
        let err = ScheduledFault::parse("at 10ms explode ew0").unwrap_err();
        for spec in VERBS {
            assert!(
                err.contains(spec.usage),
                "error text omits '{}' (got: {err})",
                spec.name
            );
        }
    }

    #[test]
    fn without_faults_strips_failures_but_keeps_hotspot() {
        let s = Scenario::new("wf", Config::small_test())
            .fault("at 10ms kill ew0")
            .fault("at 0ms hotspot e1")
            .fault("at 20ms scale_ew down ew1");
        let base = s.without_faults();
        assert_eq!(base.faults.len(), 1);
        assert_eq!(base.faults[0].fault, Fault::Hotspot(1));
    }
}
