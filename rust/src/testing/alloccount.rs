//! Counting global allocator: the measurement side of the zero-alloc
//! decode-hot-path contract (DESIGN.md §10).
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! allocation (alloc, alloc_zeroed, and growth reallocs). It is **gated
//! to dedicated binaries**: this module only defines the type — a test
//! or bench binary opts in by declaring
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: tarragon::testing::alloccount::CountingAlloc =
//!     tarragon::testing::alloccount::CountingAlloc::new();
//! ```
//!
//! (`rust/tests/alloc.rs` and `rust/benches/decode.rs` do exactly this).
//! The library itself never installs it, so the normal test/bench tiers
//! pay nothing.
//!
//! Counters are process-global atomics: run measured regions on one
//! thread (or in one `#[test]` body) to keep them attributable.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static BYTES: AtomicU64 = AtomicU64::new(0);

/// System-allocator wrapper that counts allocations.
pub struct CountingAlloc;

impl CountingAlloc {
    #[allow(clippy::new_without_default)]
    pub const fn new() -> CountingAlloc {
        CountingAlloc
    }
}

// SAFETY: defers every operation to `System`; only adds atomic counter
// updates, which allocate nothing.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A growth realloc is a fresh reservation; count it like one.
        if new_size > layout.size() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add((new_size - layout.size()) as u64, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Total allocations since process start (meaningful only when a binary
/// installed [`CountingAlloc`] as its `#[global_allocator]`).
pub fn allocation_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Total bytes requested since process start.
pub fn allocated_bytes() -> u64 {
    BYTES.load(Ordering::Relaxed)
}

/// Allocations performed by `f` (delta around the call).
pub fn allocations_during<T>(f: impl FnOnce() -> T) -> (u64, T) {
    let before = allocation_count();
    let out = f();
    (allocation_count() - before, out)
}
