//! Slab-style block pool for paged KV-cache memory.
//!
//! The pool hands out fixed-size *pages* — `page_tokens` token slots of
//! one layer's K||V rows — from a shared arena. Every producer and
//! consumer of KV bytes (decode append, batch assembly, checkpoint
//! segment emit, restore install) addresses KV state through
//! (page, slot) coordinates, so per-request resident memory scales with
//! the actual sequence length instead of `max_seq`, and a freed
//! request's pages are immediately reusable by any other request.
//!
//! Layout: slot `t` of a page occupies
//! `[t * 2 * seg, (t + 1) * 2 * seg)` floats — the K row (`seg` floats,
//! `kv_heads * head_dim`) followed by the V row. One checkpoint segment
//! (§6.1) is therefore a single contiguous slot, which keeps segment
//! read/restore a one-slice copy.
//!
//! Freed pages stay resident on the free list (slab recycling): the
//! arena's high-water mark is the cost of a burst, not of the lifetime.
//! Recycled pages are re-zeroed on alloc so padding invariants hold for
//! whoever gets them next.
//!
//! **Budget + pressure (DESIGN.md §9).** A pool may carry a hard *page
//! budget* modeling the GPU memory actually available for KV state.
//! [`KvPool::try_alloc`] fails (returns `None`) at the budget instead of
//! growing, and [`KvPool::pressure`] (`in_use / budget`) is the signal
//! the serving scheduler keys its admission watermarks and preemption
//! decisions off. `alloc` panics when the budget is exceeded: every
//! caller on the serving path must have reserved headroom first, so an
//! over-budget grab is a scheduler bug, not a condition to paper over.
//!
//! **Sharing + copy-on-write (DESIGN.md §13).** Pages carry a refcount
//! and *completed* (full) pages can be *sealed*: hashed by content and
//! published in a pool-wide index. A later request whose prompt produces
//! an identical page takes a reference ([`KvPool::share_by_hash`],
//! verified bitwise against the candidate — a hash collision can never
//! alias wrong data) instead of allocating and rewriting a physical
//! page. Sealed pages are immutable: every write path asserts
//! `refs <= 1`, and [`KvPool::cow_break`] is the escape hatch — copy
//! into a fresh private page, drop the shared reference. All accounting
//! (`in_use`, `pressure`, `free_pages`, `peak_pages`) counts *physical*
//! pages — a refcount bump changes none of them, which is exactly why
//! sharing saves budget.

use crate::modelcfg::ModelSpec;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

// ---- content hashing ------------------------------------------------------
//
// FNV-1a over the little-endian bytes of `f32::to_bits`, seeded per
// layer so identical K/V floats at different layers never collide into
// one index entry. The same byte stream is produced by every hasher of a
// page's content — prefill (K row then V row per slot), the restore path
// (one K||V segment per slot), and the checkpoint store (segment
// payloads) — so a page hashes identically no matter which path
// materialized it.

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Starting hash state for one layer's page content.
pub fn page_hash_seed(layer: usize) -> u64 {
    let mut h = FNV_OFFSET;
    for b in (layer as u64).to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fold a run of floats (bitwise) into a page-content hash.
pub fn page_hash_update(mut h: u64, data: &[f32]) -> u64 {
    for &x in data {
        for b in x.to_bits().to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Default tokens per page. 16 matches vLLM-style paged attention block
/// sizes and keeps internal fragmentation at most 15 token slots per
/// (request, layer).
pub const DEFAULT_PAGE_TOKENS: usize = 16;

/// Handle to one page in a [`KvPool`]. Only meaningful for the pool that
/// issued it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PageId(u32);

impl PageId {
    pub fn index(&self) -> usize {
        self.0 as usize
    }
}

/// Geometry of a pool's pages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolConfig {
    /// Token slots per page.
    pub page_tokens: usize,
    /// Floats of one K (or V) row: `kv_heads * head_dim`.
    pub seg: usize,
}

impl PoolConfig {
    pub fn from_model(m: &ModelSpec) -> PoolConfig {
        PoolConfig {
            page_tokens: DEFAULT_PAGE_TOKENS.min(m.max_seq).max(1),
            seg: m.kv_heads * m.head_dim,
        }
    }

    /// Floats per page: `page_tokens` slots of K||V.
    pub fn page_floats(&self) -> usize {
        self.page_tokens * 2 * self.seg
    }
}

struct PageSlot {
    data: Box<[f32]>,
    in_use: bool,
    /// References held on this physical page. 1 = private; > 1 = shared
    /// (immutable until every extra reference is dropped or CoW-broken).
    refs: u32,
    /// Content hash when sealed (full, immutable, index-published).
    /// `None` for mutable pages — decode tails are never sealed.
    hash: Option<u64>,
}

#[derive(Default)]
struct PoolInner {
    slots: Vec<PageSlot>,
    free: Vec<u32>,
    in_use: usize,
    peak_in_use: usize,
    total_allocs: u64,
    total_frees: u64,
    /// Hard cap on pages in use (0 = unbounded).
    budget: usize,
    /// Content hash -> sealed page holding that content (first sealer
    /// wins; entry removed when the page is written to or fully freed).
    index: HashMap<u64, u32>,
    /// Successful verified shares (prefill or restore prefix hits).
    prefix_hits: u64,
    /// Copy-on-write breaks (shared page about to be mutated).
    cow_breaks: u64,
    /// Pages currently shared (refs > 1) and the high-water mark.
    shared_now: usize,
    shared_peak: usize,
}

/// Shared KV page arena. Cheap to clone the `Arc`; all mutation goes
/// through a mutex (page grabs are rare relative to the float traffic
/// they amortize, and data copies happen under short critical sections).
pub struct KvPool {
    cfg: PoolConfig,
    inner: Mutex<PoolInner>,
}

impl std::fmt::Debug for KvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("KvPool")
            .field("page_tokens", &self.cfg.page_tokens)
            .field("seg", &self.cfg.seg)
            .field("in_use", &inner.in_use)
            .field("resident", &inner.slots.len())
            .finish()
    }
}

impl KvPool {
    pub fn new(cfg: PoolConfig) -> Arc<KvPool> {
        assert!(cfg.page_tokens > 0 && cfg.seg > 0);
        Arc::new(KvPool { cfg, inner: Mutex::new(PoolInner::default()) })
    }

    /// Pool with the default page size for a model.
    pub fn for_model(m: &ModelSpec) -> Arc<KvPool> {
        Self::new(PoolConfig::from_model(m))
    }

    /// Pool with an explicit page size (benches, fragmentation tests).
    pub fn with_page_tokens(m: &ModelSpec, page_tokens: usize) -> Arc<KvPool> {
        Self::new(PoolConfig { page_tokens, seg: m.kv_heads * m.head_dim })
    }

    /// Pool with a hard page budget (0 = unbounded).
    pub fn bounded(cfg: PoolConfig, budget_pages: usize) -> Arc<KvPool> {
        let p = Self::new(cfg);
        p.set_budget(budget_pages);
        p
    }

    pub fn config(&self) -> PoolConfig {
        self.cfg
    }

    pub fn page_tokens(&self) -> usize {
        self.cfg.page_tokens
    }

    /// Floats of one K (or V) row.
    pub fn row_elems(&self) -> usize {
        self.cfg.seg
    }

    pub fn page_floats(&self) -> usize {
        self.cfg.page_floats()
    }

    // ---- allocation ------------------------------------------------------

    /// Hand out a zeroed page, or `None` when the pool is at its page
    /// budget. Recycles the free list before growing the arena.
    pub fn try_alloc(&self) -> Option<PageId> {
        let mut inner = self.inner.lock().unwrap();
        if inner.budget > 0 && inner.in_use >= inner.budget {
            return None;
        }
        let id = if let Some(idx) = inner.free.pop() {
            let slot = &mut inner.slots[idx as usize];
            debug_assert!(!slot.in_use);
            slot.data.fill(0.0);
            slot.in_use = true;
            slot.refs = 1;
            slot.hash = None;
            PageId(idx)
        } else {
            let idx = inner.slots.len() as u32;
            inner.slots.push(PageSlot {
                data: vec![0.0f32; self.cfg.page_floats()].into_boxed_slice(),
                in_use: true,
                refs: 1,
                hash: None,
            });
            PageId(idx)
        };
        inner.in_use += 1;
        inner.peak_in_use = inner.peak_in_use.max(inner.in_use);
        inner.total_allocs += 1;
        Some(id)
    }

    /// Hand out a zeroed page. Panics at the page budget — callers on the
    /// serving path must reserve headroom (preempting if necessary) before
    /// growing a request, so hitting the budget here is a scheduler bug.
    pub fn alloc(&self) -> PageId {
        self.try_alloc().unwrap_or_else(|| {
            panic!("kv page budget exceeded ({} pages)", self.budget_pages())
        })
    }

    /// Return one reference on a page. On a shared page this only drops
    /// the caller's reference; the physical page is released (and its
    /// index entry retired) when the *last* reference goes — the
    /// share-aware evict contract. Panics on double free or a foreign
    /// id — a paging bug upstream must not silently corrupt another
    /// request's KV.
    pub fn free(&self, id: PageId) {
        let mut inner = self.inner.lock().unwrap();
        let (refs_left, hash) = {
            let slot = inner
                .slots
                .get_mut(id.index())
                .unwrap_or_else(|| panic!("free of unknown page {id:?}"));
            assert!(slot.in_use, "double free of page {id:?}");
            debug_assert!(slot.refs > 0);
            slot.refs -= 1;
            (slot.refs, slot.hash)
        };
        if refs_left == 1 {
            inner.shared_now -= 1;
        }
        if refs_left > 0 {
            return; // other holders keep the physical page alive
        }
        if let Some(h) = hash {
            if inner.index.get(&h) == Some(&id.0) {
                inner.index.remove(&h);
            }
            inner.slots[id.index()].hash = None;
        }
        inner.slots[id.index()].in_use = false;
        inner.free.push(id.0);
        inner.in_use -= 1;
        inner.total_frees += 1;
    }

    // ---- sharing / copy-on-write ----------------------------------------

    /// Take a reference on the sealed page published under `hash`, after
    /// `verify` confirms bitwise that the candidate's raw floats really
    /// are the content the caller computed (hash collisions must never
    /// alias wrong data). Does not change physical accounting: `in_use`,
    /// `pressure`, and `free_pages` are untouched — that is the saving.
    pub fn share_by_hash<F: FnOnce(&[f32]) -> bool>(&self, hash: u64, verify: F) -> Option<PageId> {
        let mut inner = self.inner.lock().unwrap();
        let idx = *inner.index.get(&hash)?;
        {
            let slot = &inner.slots[idx as usize];
            debug_assert!(slot.in_use && slot.hash == Some(hash));
            if !verify(&slot.data) {
                return None;
            }
        }
        let newly_shared = {
            let slot = &mut inner.slots[idx as usize];
            slot.refs += 1;
            slot.refs == 2
        };
        if newly_shared {
            inner.shared_now += 1;
            inner.shared_peak = inner.shared_peak.max(inner.shared_now);
        }
        inner.prefix_hits += 1;
        Some(PageId(idx))
    }

    /// Seal a *full* page: record its content hash and publish it for
    /// sharing. First sealer of a given hash owns the index entry; a
    /// page obtained via [`share_by_hash`](Self::share_by_hash) may be
    /// re-sealed with the same hash (idempotent). Sealed pages are
    /// immutable — any write path unseals (and asserts unshared) first.
    pub fn seal(&self, id: PageId, hash: u64) {
        let mut inner = self.inner.lock().unwrap();
        {
            let slot = &mut inner.slots[id.index()];
            assert!(slot.in_use, "access to freed page {id:?}");
            debug_assert!(
                slot.hash.is_none() || slot.hash == Some(hash),
                "re-seal of page {id:?} with a different hash"
            );
            slot.hash = Some(hash);
        }
        inner.index.entry(hash).or_insert(id.0);
    }

    /// Is a sealed page with this content hash available for sharing?
    pub fn has_sealed(&self, hash: u64) -> bool {
        self.inner.lock().unwrap().index.contains_key(&hash)
    }

    /// References currently held on a page.
    pub fn ref_count(&self, id: PageId) -> u32 {
        let inner = self.inner.lock().unwrap();
        let slot = &inner.slots[id.index()];
        assert!(slot.in_use, "access to freed page {id:?}");
        slot.refs
    }

    /// Does anyone else hold a reference on this page?
    pub fn is_shared(&self, id: PageId) -> bool {
        self.ref_count(id) > 1
    }

    /// The content hash a page was sealed with, if sealed.
    pub fn page_hash(&self, id: PageId) -> Option<u64> {
        let inner = self.inner.lock().unwrap();
        let slot = &inner.slots[id.index()];
        assert!(slot.in_use, "access to freed page {id:?}");
        slot.hash
    }

    /// Copy the full content of `src` into `dst` under one lock. `dst`
    /// must be private (refs <= 1); it is unsealed by the write.
    pub fn copy_page(&self, src: PageId, dst: PageId) {
        let (si, di) = (src.index(), dst.index());
        assert_ne!(si, di, "copy_page onto itself");
        let mut inner = self.inner.lock().unwrap();
        {
            let s = &inner.slots[si];
            assert!(s.in_use, "access to freed page {src:?}");
            let d = &inner.slots[di];
            assert!(d.in_use, "access to freed page {dst:?}");
            assert!(d.refs <= 1, "write to shared page {dst:?} (refs {})", d.refs);
        }
        if let Some(h) = inner.slots[di].hash.take() {
            if inner.index.get(&h) == Some(&(di as u32)) {
                inner.index.remove(&h);
            }
        }
        let (lo, hi) = inner.slots.split_at_mut(si.max(di));
        let (s, d) = if si < di { (&lo[si], &mut hi[0]) } else { (&hi[0], &mut lo[di]) };
        d.data.copy_from_slice(&s.data);
    }

    /// Copy-on-write break: give the caller a private copy of a shared
    /// page and drop its reference on the original. Returns the same id
    /// when the page is already private (idempotent), `None` when the
    /// pool is at budget (caller must make headroom first, exactly like
    /// any other allocation on the serving path).
    pub fn cow_break(&self, id: PageId) -> Option<PageId> {
        {
            let inner = self.inner.lock().unwrap();
            let slot = &inner.slots[id.index()];
            assert!(slot.in_use, "access to freed page {id:?}");
            if slot.refs <= 1 {
                return Some(id);
            }
        }
        let fresh = self.try_alloc()?;
        self.copy_page(id, fresh);
        self.free(id); // drop our reference; others keep the original
        self.inner.lock().unwrap().cow_breaks += 1;
        Some(fresh)
    }

    // ---- data plane ------------------------------------------------------

    /// Write the K and V rows of one token slot.
    pub fn write_rows(&self, id: PageId, slot: usize, k_row: &[f32], v_row: &[f32]) {
        let seg = self.cfg.seg;
        assert!(slot < self.cfg.page_tokens);
        assert_eq!(k_row.len(), seg);
        assert_eq!(v_row.len(), seg);
        let mut inner = self.inner.lock().unwrap();
        let page = self.page_mut(&mut inner, id);
        let off = slot * 2 * seg;
        page[off..off + seg].copy_from_slice(k_row);
        page[off + seg..off + 2 * seg].copy_from_slice(v_row);
    }

    /// Write one checkpoint segment (K||V) into a token slot — the
    /// restore path. One contiguous copy.
    pub fn write_segment(&self, id: PageId, slot: usize, data: &[f32]) {
        let seg2 = 2 * self.cfg.seg;
        assert!(slot < self.cfg.page_tokens);
        assert_eq!(data.len(), seg2, "bad segment size");
        let mut inner = self.inner.lock().unwrap();
        let page = self.page_mut(&mut inner, id);
        page[slot * seg2..(slot + 1) * seg2].copy_from_slice(data);
    }

    /// Read one segment (K||V) out of a token slot — the checkpoint
    /// streamer's source. One contiguous copy.
    pub fn read_segment(&self, id: PageId, slot: usize) -> Vec<f32> {
        let seg2 = 2 * self.cfg.seg;
        assert!(slot < self.cfg.page_tokens);
        let inner = self.inner.lock().unwrap();
        let page = self.page(&inner, id);
        page[slot * seg2..(slot + 1) * seg2].to_vec()
    }

    /// Gather the first `tokens` slots of a page into separate K / V
    /// destinations (`tokens * seg` floats each) — batch assembly.
    pub fn copy_rows_into(&self, id: PageId, tokens: usize, k_dst: &mut [f32], v_dst: &mut [f32]) {
        let seg = self.cfg.seg;
        assert!(tokens <= self.cfg.page_tokens);
        assert!(k_dst.len() >= tokens * seg && v_dst.len() >= tokens * seg);
        let inner = self.inner.lock().unwrap();
        let page = self.page(&inner, id);
        for t in 0..tokens {
            let off = t * 2 * seg;
            k_dst[t * seg..(t + 1) * seg].copy_from_slice(&page[off..off + seg]);
            v_dst[t * seg..(t + 1) * seg].copy_from_slice(&page[off + seg..off + 2 * seg]);
        }
    }

    /// Take the arena read lock for direct paged access — the decode
    /// attention kernel reads KV rows in place through this instead of
    /// materializing a contiguous copy per step (DESIGN.md §10). The
    /// guard holds the pool mutex: keep it for one kernel invocation
    /// (the worker thread is blocked on that call anyway) and never
    /// across another pool operation.
    pub fn read(&self) -> PagesRead<'_> {
        PagesRead {
            inner: self.inner.lock().unwrap(),
            seg: self.cfg.seg,
            page_tokens: self.cfg.page_tokens,
        }
    }

    fn page<'a>(&self, inner: &'a PoolInner, id: PageId) -> &'a [f32] {
        let slot = &inner.slots[id.index()];
        assert!(slot.in_use, "access to freed page {id:?}");
        &slot.data
    }

    /// Mutable access to a page's floats — every write path funnels
    /// through here, which is where the sharing invariants bite: a
    /// shared page must be CoW-broken before mutation, and a sealed page
    /// loses its seal (and index entry) the moment it is written.
    fn page_mut<'a>(&self, inner: &'a mut PoolInner, id: PageId) -> &'a mut [f32] {
        {
            let slot = &inner.slots[id.index()];
            assert!(slot.in_use, "access to freed page {id:?}");
            assert!(
                slot.refs <= 1,
                "write to shared page {id:?} (refs {}): CoW break required",
                slot.refs
            );
        }
        if let Some(h) = inner.slots[id.index()].hash.take() {
            if inner.index.get(&h) == Some(&id.0) {
                inner.index.remove(&h);
            }
        }
        &mut inner.slots[id.index()].data
    }

    // ---- accounting ------------------------------------------------------

    /// Pages currently handed out.
    pub fn pages_in_use(&self) -> usize {
        self.inner.lock().unwrap().in_use
    }

    /// Pages resident in the arena (in use + recycled on the free list).
    pub fn pages_resident(&self) -> usize {
        self.inner.lock().unwrap().slots.len()
    }

    /// High-water mark of pages in use.
    pub fn peak_pages(&self) -> usize {
        self.inner.lock().unwrap().peak_in_use
    }

    /// The hard page budget (0 = unbounded).
    pub fn budget_pages(&self) -> usize {
        self.inner.lock().unwrap().budget
    }

    /// Install (or clear, with 0) the hard page budget. Shrinking below
    /// the current in-use count is allowed: existing pages stay valid and
    /// pressure reads above 1.0 until enough are freed.
    pub fn set_budget(&self, pages: usize) {
        self.inner.lock().unwrap().budget = pages;
    }

    /// Pages left under the budget, or `None` for an unbounded pool.
    pub fn free_pages(&self) -> Option<usize> {
        let inner = self.inner.lock().unwrap();
        if inner.budget == 0 {
            None
        } else {
            Some(inner.budget.saturating_sub(inner.in_use))
        }
    }

    /// Memory pressure: `in_use / budget`, or 0.0 for an unbounded pool.
    /// The serving scheduler compares this against its watermarks.
    pub fn pressure(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        if inner.budget == 0 {
            0.0
        } else {
            inner.in_use as f64 / inner.budget as f64
        }
    }

    /// Floats held by pages currently in use.
    pub fn floats_in_use(&self) -> usize {
        self.pages_in_use() * self.cfg.page_floats()
    }

    /// Bytes held by pages currently in use.
    pub fn bytes_in_use(&self) -> usize {
        self.floats_in_use() * 4
    }

    pub fn total_allocs(&self) -> u64 {
        self.inner.lock().unwrap().total_allocs
    }

    pub fn total_frees(&self) -> u64 {
        self.inner.lock().unwrap().total_frees
    }

    /// Successful verified prefix shares.
    pub fn prefix_hits(&self) -> u64 {
        self.inner.lock().unwrap().prefix_hits
    }

    /// Copy-on-write breaks taken.
    pub fn cow_breaks(&self) -> u64 {
        self.inner.lock().unwrap().cow_breaks
    }

    /// Pages currently shared (refs > 1).
    pub fn pages_shared_now(&self) -> usize {
        self.inner.lock().unwrap().shared_now
    }

    /// High-water mark of simultaneously shared pages.
    pub fn pages_shared_peak(&self) -> usize {
        self.inner.lock().unwrap().shared_peak
    }
}

/// Held read lock over a pool's arena: zero-copy (page, slot) row access
/// for the paged decode-attention kernel.
pub struct PagesRead<'a> {
    inner: std::sync::MutexGuard<'a, PoolInner>,
    seg: usize,
    page_tokens: usize,
}

impl PagesRead<'_> {
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Floats of one K (or V) row.
    pub fn row_elems(&self) -> usize {
        self.seg
    }

    /// Borrow the K and V rows of one token slot, in place.
    pub fn kv_rows(&self, id: PageId, slot: usize) -> (&[f32], &[f32]) {
        assert!(slot < self.page_tokens);
        let s = &self.inner.slots[id.index()];
        assert!(s.in_use, "access to freed page {id:?}");
        let off = slot * 2 * self.seg;
        let kv = &s.data[off..off + 2 * self.seg];
        kv.split_at(self.seg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(page_tokens: usize, seg: usize) -> Arc<KvPool> {
        KvPool::new(PoolConfig { page_tokens, seg })
    }

    #[test]
    fn read_lock_exposes_rows_in_place() {
        let p = pool(3, 4);
        let id = p.alloc();
        p.write_rows(id, 2, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        let read = p.read();
        let (k, v) = read.kv_rows(id, 2);
        assert_eq!(k, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(v, &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(read.page_tokens(), 3);
        assert_eq!(read.row_elems(), 4);
    }

    #[test]
    fn alloc_free_recycles_without_growth() {
        let p = pool(4, 8);
        let a = p.alloc();
        let b = p.alloc();
        assert_ne!(a, b);
        assert_eq!(p.pages_in_use(), 2);
        p.free(a);
        assert_eq!(p.pages_in_use(), 1);
        let c = p.alloc();
        assert_eq!(c, a, "free list must be recycled before growing");
        assert_eq!(p.pages_resident(), 2);
        assert_eq!(p.peak_pages(), 2);
        p.free(b);
        p.free(c);
        assert_eq!(p.pages_in_use(), 0);
    }

    #[test]
    fn recycled_pages_are_zeroed() {
        let p = pool(2, 4);
        let a = p.alloc();
        p.write_rows(a, 1, &[1.0; 4], &[2.0; 4]);
        p.free(a);
        let b = p.alloc();
        assert_eq!(b, a);
        assert_eq!(p.read_segment(b, 1), vec![0.0; 8]);
    }

    #[test]
    fn segment_layout_is_contiguous_k_then_v() {
        let p = pool(3, 4);
        let id = p.alloc();
        p.write_rows(id, 2, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        let seg = p.read_segment(id, 2);
        assert_eq!(seg, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let mut k = vec![0.0; 3 * 4];
        let mut v = vec![0.0; 3 * 4];
        p.copy_rows_into(id, 3, &mut k, &mut v);
        assert_eq!(&k[8..12], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&v[8..12], &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(&k[..8], &[0.0; 8]);
    }

    #[test]
    fn budget_caps_try_alloc_and_pressure_tracks() {
        let p = KvPool::bounded(PoolConfig { page_tokens: 2, seg: 2 }, 2);
        assert_eq!(p.budget_pages(), 2);
        assert_eq!(p.free_pages(), Some(2));
        assert_eq!(p.pressure(), 0.0);
        let a = p.try_alloc().unwrap();
        assert_eq!(p.pressure(), 0.5);
        let _b = p.try_alloc().unwrap();
        assert_eq!(p.pressure(), 1.0);
        assert_eq!(p.free_pages(), Some(0));
        assert!(p.try_alloc().is_none(), "at budget, try_alloc must fail");
        assert_eq!(p.pages_in_use(), 2);
        p.free(a);
        assert_eq!(p.pressure(), 0.5);
        assert!(p.try_alloc().is_some(), "freed headroom must be reusable");
    }

    #[test]
    fn unbounded_pool_reports_no_pressure() {
        let p = pool(2, 2);
        let _a = p.alloc();
        assert_eq!(p.budget_pages(), 0);
        assert_eq!(p.free_pages(), None);
        assert_eq!(p.pressure(), 0.0);
    }

    #[test]
    #[should_panic(expected = "kv page budget exceeded")]
    fn alloc_past_budget_panics() {
        let p = KvPool::bounded(PoolConfig { page_tokens: 2, seg: 2 }, 1);
        let _a = p.alloc();
        let _b = p.alloc();
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let p = pool(2, 2);
        let id = p.alloc();
        p.free(id);
        p.free(id);
    }

    #[test]
    #[should_panic(expected = "freed page")]
    fn use_after_free_panics() {
        let p = pool(2, 2);
        let id = p.alloc();
        p.free(id);
        p.read_segment(id, 0);
    }

    /// Fill every slot of a page with `base + slot` and return its hash
    /// the way prefill computes it (K row, then V row, per slot).
    fn fill_and_hash(p: &KvPool, id: PageId, layer: usize, base: f32) -> u64 {
        let seg = p.row_elems();
        let mut h = page_hash_seed(layer);
        for t in 0..p.page_tokens() {
            let k = vec![base + t as f32; seg];
            let v = vec![-(base + t as f32); seg];
            p.write_rows(id, t, &k, &v);
            h = page_hash_update(h, &k);
            h = page_hash_update(h, &v);
        }
        h
    }

    #[test]
    fn share_bumps_refs_but_not_physical_accounting() {
        let p = pool(2, 2);
        let a = p.alloc();
        let h = fill_and_hash(&p, a, 0, 1.0);
        p.seal(a, h);
        assert!(p.has_sealed(h));
        assert_eq!(p.page_hash(a), Some(h));

        let b = p.share_by_hash(h, |_| true).expect("sealed page must be shareable");
        assert_eq!(b, a, "share must return the indexed physical page");
        assert_eq!(p.ref_count(a), 2);
        assert!(p.is_shared(a));
        assert_eq!(p.prefix_hits(), 1);
        assert_eq!(p.pages_shared_now(), 1);
        assert_eq!(p.pages_shared_peak(), 1);
        // Physical accounting untouched by the share.
        assert_eq!(p.pages_in_use(), 1);
        assert_eq!(p.peak_pages(), 1);

        p.free(b);
        assert_eq!(p.ref_count(a), 1, "free of a shared page drops one reference");
        assert_eq!(p.pages_in_use(), 1);
        assert_eq!(p.pages_shared_now(), 0);
        assert!(p.has_sealed(h), "page stays sealed while a holder remains");
        p.free(a);
        assert_eq!(p.pages_in_use(), 0);
        assert!(!p.has_sealed(h), "last free retires the index entry");
    }

    #[test]
    fn share_verify_rejects_mismatched_content() {
        let p = pool(2, 2);
        let a = p.alloc();
        let h = fill_and_hash(&p, a, 0, 1.0);
        p.seal(a, h);
        // A verify that rejects (hash collision with different bytes)
        // must fail the share without touching refcounts.
        assert!(p.share_by_hash(h, |_| false).is_none());
        assert_eq!(p.ref_count(a), 1);
        assert_eq!(p.prefix_hits(), 0);
        // Unknown hash: no candidate at all.
        assert!(p.share_by_hash(h ^ 1, |_| true).is_none());
    }

    #[test]
    #[should_panic(expected = "write to shared page")]
    fn write_to_shared_page_panics() {
        let p = pool(2, 2);
        let a = p.alloc();
        let h = fill_and_hash(&p, a, 0, 1.0);
        p.seal(a, h);
        let _b = p.share_by_hash(h, |_| true).unwrap();
        p.write_rows(a, 0, &[9.0, 9.0], &[9.0, 9.0]);
    }

    #[test]
    fn cow_break_gives_private_copy_and_keeps_original() {
        let p = pool(2, 2);
        let a = p.alloc();
        let h = fill_and_hash(&p, a, 0, 1.0);
        p.seal(a, h);
        let b = p.share_by_hash(h, |_| true).unwrap();
        let before = p.read_segment(a, 1);

        let c = p.cow_break(b).expect("unbounded pool can always CoW");
        assert_ne!(c, a, "CoW must hand out a fresh physical page");
        assert_eq!(p.read_segment(c, 1), before, "copy must be bitwise identical");
        assert_eq!(p.ref_count(a), 1, "CoW drops the shared reference");
        assert_eq!(p.cow_breaks(), 1);
        assert_eq!(p.pages_in_use(), 2);
        assert!(p.page_hash(c).is_none(), "the copy starts unsealed/private");
        assert!(p.has_sealed(h), "the original stays sealed for future sharers");

        // Now diverge the copy and read back both variants.
        p.write_rows(c, 1, &[7.0, 7.0], &[8.0, 8.0]);
        assert_eq!(p.read_segment(c, 1), vec![7.0, 7.0, 8.0, 8.0]);
        assert_eq!(p.read_segment(a, 1), before, "original untouched by divergence");

        // cow_break on a private page is the identity.
        assert_eq!(p.cow_break(c), Some(c));
        assert_eq!(p.cow_breaks(), 1);
    }

    #[test]
    fn cow_break_respects_budget() {
        let p = KvPool::bounded(PoolConfig { page_tokens: 2, seg: 2 }, 1);
        let a = p.alloc();
        let h = fill_and_hash(&p, a, 0, 1.0);
        p.seal(a, h);
        let b = p.share_by_hash(h, |_| true).unwrap();
        assert!(p.cow_break(b).is_none(), "no headroom: CoW must fail, not panic");
        assert_eq!(p.ref_count(a), 2, "failed CoW must leave the reference intact");
        p.free(b);
        p.free(a);
    }

    #[test]
    fn write_unseals_a_private_sealed_page() {
        let p = pool(2, 2);
        let a = p.alloc();
        let h = fill_and_hash(&p, a, 0, 1.0);
        p.seal(a, h);
        assert!(p.has_sealed(h));
        p.write_rows(a, 0, &[9.0, 9.0], &[9.0, 9.0]);
        assert!(!p.has_sealed(h), "mutation retires the index entry");
        assert_eq!(p.page_hash(a), None);
    }

    #[test]
    fn layer_seed_separates_identical_content() {
        let data = [1.0f32, 2.0, 3.0];
        let h0 = page_hash_update(page_hash_seed(0), &data);
        let h1 = page_hash_update(page_hash_seed(1), &data);
        assert_ne!(h0, h1);
        // Incremental and one-shot hashing agree.
        let mut inc = page_hash_seed(0);
        inc = page_hash_update(inc, &data[..1]);
        inc = page_hash_update(inc, &data[1..]);
        assert_eq!(inc, h0);
    }
}
