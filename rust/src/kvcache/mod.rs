//! Per-request KV cache management on the Attention Worker — paged.
//!
//! KV memory is block-pool allocated (see [`pool`]): a [`RequestKv`] is a
//! per-layer *page table* into a shared [`KvPool`] arena instead of a
//! contiguous `max_seq × kv_heads × head_dim` preallocation, so resident
//! memory scales with the actual sequence length and a finished request's
//! pages are immediately reusable. A "segment" — the unit of incremental
//! checkpointing (§6.1) and restoration (§6.2) — is one (token, layer)'s
//! K and V vectors concatenated (`2 * kv_heads * head_dim` floats) and is
//! exactly one page slot, so segment read/restore is a single slice copy.
//!
//! [`BatchAssembler`] gathers the *valid prefix* of each request's pages
//! into the batched `[B, S, kv, d]` tensors of a decode step — one copy
//! per layer, and only `len` tokens of it per request rather than
//! `max_seq` (the decode artifact masks by the pos vector, so the padded
//! tail only ever needs to be zero).

pub mod pool;

pub use pool::{
    page_hash_seed, page_hash_update, KvPool, PageId, PagesRead, PoolConfig, DEFAULT_PAGE_TOKENS,
};

/// Worst-case pool pages for a request spanning `tokens` positions across
/// `layers` layers — the admission-time fit check: a request whose
/// worst-case footprint exceeds the per-AW page budget can never be
/// served and must be rejected at the gateway (DESIGN.md §9).
pub fn pages_for_tokens(tokens: usize, page_tokens: usize, layers: usize) -> usize {
    layers * tokens.div_ceil(page_tokens.max(1))
}

use crate::modelcfg::ModelSpec;
use crate::proto::SegPayload;
use crate::tensor::Tensor;
use std::sync::Arc;

/// A decode batch's KV state for one layer, by reference: the shared page
/// arena plus each batch row's page table. This is what the decode
/// attention artifact receives instead of contiguous `[B, S, kv, d]`
/// copies — the kernel reads rows in place under [`KvPool::read`]
/// (DESIGN.md §10). Cloning bumps two `Arc`s; no KV bytes move.
#[derive(Clone)]
pub struct PagedKvView {
    pub pool: Arc<KvPool>,
    /// Per batch row (row i = batch slot i): that row's page table for
    /// the layer. Rows beyond `tables.len()` are padding (no KV state).
    pub tables: Arc<Vec<Vec<PageId>>>,
}

impl PagedKvView {
    /// Valid (non-padding) batch rows.
    pub fn rows(&self) -> usize {
        self.tables.len()
    }
}

impl std::fmt::Debug for PagedKvView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedKvView")
            .field("rows", &self.tables.len())
            .field("pages", &self.tables.iter().map(|t| t.len()).sum::<usize>())
            .finish()
    }
}

/// Which positions of one prompt layer a bulk write actually touched:
/// full pages satisfied by a verified share (prefix hits — the caller
/// checkpoints them as one page *reference* each) vs. positions written
/// physically (the caller checkpoints them as ordinary segments).
#[derive(Debug, Default)]
pub struct PrefillOutcome {
    /// `(first_pos, content_hash)` per full page installed by sharing.
    pub shared: Vec<(usize, u64)>,
    /// Every position written physically (full pages get sealed).
    pub written: Vec<usize>,
}

/// Per-request KV cache across all layers, backed by pool pages.
pub struct RequestKv {
    pool: Arc<KvPool>,
    /// Per layer: pages covering positions `[0, pages.len() * page_tokens)`.
    tables: Vec<Vec<PageId>>,
    /// Valid positions [0, len).
    len: usize,
    s_max: usize,
    /// Elements of one K (or V) row: kv_heads * head_dim.
    seg: usize,
    /// Per layer: leading pages installed by sharing (DESIGN.md §13).
    /// Writes to a page below this watermark must `make_unique` first —
    /// in practice only the last, partially-filled page is ever written
    /// after install, so the check is a cold integer compare.
    shared_prefix: Vec<usize>,
}

impl std::fmt::Debug for RequestKv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RequestKv")
            .field("len", &self.len)
            .field("pages", &self.allocated_pages())
            .field("layers", &self.tables.len())
            .finish()
    }
}

impl RequestKv {
    /// An empty cache: no pages are allocated until positions are written.
    pub fn new(m: &ModelSpec, pool: &Arc<KvPool>) -> RequestKv {
        let seg = m.kv_heads * m.head_dim;
        assert_eq!(
            seg,
            pool.row_elems(),
            "pool geometry does not match the model (seg {} vs {})",
            pool.row_elems(),
            seg
        );
        RequestKv {
            pool: pool.clone(),
            tables: vec![Vec::new(); m.layers],
            len: 0,
            s_max: m.max_seq,
            seg,
            shared_prefix: vec![0; m.layers],
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn layers(&self) -> usize {
        self.tables.len()
    }

    /// Elements in one K or V row.
    pub fn row_elems(&self) -> usize {
        self.seg
    }

    /// Bytes of one checkpoint segment (K+V for one token, one layer).
    pub fn segment_bytes(&self) -> usize {
        2 * self.seg * 4
    }

    /// The arena this cache allocates from.
    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Pages currently allocated to this request (all layers).
    pub fn allocated_pages(&self) -> usize {
        self.tables.iter().map(|t| t.len()).sum()
    }

    /// Resident bytes of this request's KV state.
    pub fn resident_bytes(&self) -> usize {
        self.allocated_pages() * self.pool.page_floats() * 4
    }

    /// Additional pool pages this cache must allocate to cover positions
    /// `[0, new_len)` across all layers (0 if already covered) — the
    /// pre-step headroom check of the overload scheduler.
    pub fn pages_to_extend(&self, new_len: usize) -> usize {
        let pt = self.pool.page_tokens();
        let need = new_len.div_ceil(pt);
        self.tables.iter().map(|t| need.saturating_sub(t.len())).sum()
    }

    /// Eagerly allocate (zeroed) pages covering positions `[0, upto)`
    /// across all layers. The restore path *reserves* its prefix plus the
    /// next decode position this way, so a headroom check cannot be
    /// invalidated by a later install racing for the same free pages.
    pub fn reserve(&mut self, upto: usize) {
        let pt = self.pool.page_tokens();
        let need = upto.div_ceil(pt);
        for table in &mut self.tables {
            while table.len() < need {
                table.push(self.pool.alloc());
            }
        }
    }

    /// (page, slot) of a position, allocating pages on demand.
    fn locate_mut(&mut self, layer: usize, pos: usize) -> (PageId, usize) {
        let pt = self.pool.page_tokens();
        let page_idx = pos / pt;
        let table = &mut self.tables[layer];
        while table.len() <= page_idx {
            table.push(self.pool.alloc());
        }
        (table[page_idx], pos % pt)
    }

    fn locate(&self, layer: usize, pos: usize) -> (PageId, usize) {
        let pt = self.pool.page_tokens();
        let page_idx = pos / pt;
        (self.tables[layer][page_idx], pos % pt)
    }

    /// Write K/V for position `pos` of `layer` (decode append or prefill
    /// bulk write). Does NOT advance `len` — call `set_len` once all layers
    /// for a position are written (the per-step commit point). A write
    /// landing inside the shared prefix breaks that page copy-on-write
    /// first (never hit in steady state: only the partial tail is
    /// written after install).
    pub fn write(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(pos < self.s_max, "kv overflow: pos {pos} >= {}", self.s_max);
        self.cow_guard(layer, pos);
        let (page, slot) = self.locate_mut(layer, pos);
        self.pool.write_rows(page, slot, k_row, v_row);
    }

    /// Install a checkpoint segment (K||V concatenated), restoration path.
    /// Allocates exactly the pages the restored prefix needs.
    pub fn write_segment(&mut self, layer: usize, pos: usize, seg_data: &[f32]) {
        assert!(pos < self.s_max, "kv overflow: pos {pos} >= {}", self.s_max);
        assert_eq!(seg_data.len(), 2 * self.seg, "bad segment size");
        self.cow_guard(layer, pos);
        let (page, slot) = self.locate_mut(layer, pos);
        self.pool.write_segment(page, slot, seg_data);
    }

    /// CoW safety valve on the write paths: a position inside the shared
    /// prefix gets its page privatized before mutation.
    fn cow_guard(&mut self, layer: usize, pos: usize) {
        let page_idx = pos / self.pool.page_tokens();
        if page_idx < self.shared_prefix[layer] {
            self.make_unique(layer, page_idx);
        }
    }

    // ---- prefix sharing (DESIGN.md §13) ----------------------------------

    /// Per-layer watermark: leading pages installed by sharing.
    pub fn shared_prefix_pages(&self, layer: usize) -> usize {
        self.shared_prefix[layer]
    }

    /// Append the next page of `layer` by taking a verified reference on
    /// a sealed pool page with this content hash, if one is published.
    /// Pages must be installed in order (the page lands at the current
    /// end of the layer's table). Returns whether the share happened.
    pub fn try_share_page<F: FnOnce(&[f32]) -> bool>(
        &mut self,
        layer: usize,
        hash: u64,
        verify: F,
    ) -> bool {
        match self.pool.share_by_hash(hash, verify) {
            Some(id) => {
                let page_idx = self.tables[layer].len();
                self.tables[layer].push(id);
                self.shared_prefix[layer] = self.shared_prefix[layer].max(page_idx + 1);
                true
            }
            None => false,
        }
    }

    /// Seal a fully-written page of `layer` under its content hash,
    /// publishing it for sharing by later requests.
    pub fn seal_page(&mut self, layer: usize, page_idx: usize, hash: u64) {
        self.pool.seal(self.tables[layer][page_idx], hash);
    }

    /// Privatize one page of `layer`: if it is shared, copy-on-write into
    /// a fresh private page and swap the table entry. Idempotent on a
    /// private page. Panics at the page budget, like every serving-path
    /// alloc (callers reserve headroom first).
    pub fn make_unique(&mut self, layer: usize, page_idx: usize) {
        let id = self.tables[layer][page_idx];
        let fresh = self.pool.cow_break(id).unwrap_or_else(|| {
            panic!("kv page budget exceeded ({} pages)", self.pool.budget_pages())
        });
        self.tables[layer][page_idx] = fresh;
    }

    /// Bulk-write one layer's prompt K/V rows (`p_len` rows of `k`/`v`),
    /// sharing instead of writing wherever a full page's content is
    /// already sealed in the pool. Full pages that miss are written and
    /// sealed (so the *next* request with this prompt hits); the partial
    /// tail is written privately and never sealed. The outcome tells the
    /// caller which positions need ordinary checkpoint segments and
    /// which pages are covered by a single page reference.
    ///
    /// Sharing only engages when the layer's table is empty (prefill
    /// writes each layer exactly once, from the front). Re-prefilling an
    /// already-populated cache (micro-benchmarks, replay baselines) falls
    /// back to plain in-place overwrites — the CoW guard on `write`
    /// privatizes any page the overwrite would otherwise clobber.
    pub fn write_prompt_layer(
        &mut self,
        layer: usize,
        p_len: usize,
        k: &Tensor,
        v: &Tensor,
    ) -> PrefillOutcome {
        assert!(p_len <= self.s_max, "kv overflow: prompt {p_len} > {}", self.s_max);
        if !self.tables[layer].is_empty() {
            let mut out = PrefillOutcome::default();
            for t in 0..p_len {
                self.write(layer, t, k.row(t), v.row(t));
                out.written.push(t);
            }
            return out;
        }
        let pt = self.pool.page_tokens();
        let seg = self.seg;
        let mut out = PrefillOutcome::default();
        let mut pos = 0;
        while pos + pt <= p_len {
            let mut h = page_hash_seed(layer);
            for t in pos..pos + pt {
                h = page_hash_update(h, k.row(t));
                h = page_hash_update(h, v.row(t));
            }
            let hit = self.try_share_page(layer, h, |raw| {
                (0..pt).all(|t| {
                    let off = t * 2 * seg;
                    raw[off..off + seg] == *k.row(pos + t)
                        && raw[off + seg..off + 2 * seg] == *v.row(pos + t)
                })
            });
            if hit {
                out.shared.push((pos, h));
            } else {
                let page_idx = pos / pt;
                for t in pos..pos + pt {
                    self.write(layer, t, k.row(t), v.row(t));
                }
                self.seal_page(layer, page_idx, h);
                out.written.extend(pos..pos + pt);
            }
            pos += pt;
        }
        for t in pos..p_len {
            self.write(layer, t, k.row(t), v.row(t));
            out.written.push(t);
        }
        out
    }

    /// Fallible deep copy: `try_alloc` with full rollback — if the pool
    /// runs out of budget mid-copy, every page already allocated for the
    /// half-built clone is returned and `None` comes back (the infallible
    /// `Clone` used to leak those pages by panicking mid-build). Pages
    /// are copied page-to-page under one pool lock each, not one heap
    /// `Vec` per slot.
    pub fn try_clone(&self) -> Option<RequestKv> {
        let mut tables: Vec<Vec<PageId>> = Vec::with_capacity(self.tables.len());
        for table in &self.tables {
            let mut t: Vec<PageId> = Vec::with_capacity(table.len());
            for &src in table {
                match self.pool.try_alloc() {
                    Some(dst) => {
                        self.pool.copy_page(src, dst);
                        t.push(dst);
                    }
                    None => {
                        for &p in t.iter().chain(tables.iter().flatten()) {
                            self.pool.free(p);
                        }
                        return None;
                    }
                }
            }
            tables.push(t);
        }
        Some(RequestKv {
            pool: self.pool.clone(),
            tables,
            len: self.len,
            s_max: self.s_max,
            seg: self.seg,
            // The clone owns every page privately.
            shared_prefix: vec![0; self.tables.len()],
        })
    }

    /// Read one segment back (K||V) — the checkpoint streamer's source.
    pub fn read_segment(&self, layer: usize, pos: usize) -> Vec<f32> {
        let (page, slot) = self.locate(layer, pos);
        self.pool.read_segment(page, slot)
    }

    /// Read one segment as a shared checkpoint payload. This is the single
    /// copy on the checkpoint path: the returned `Arc` travels through the
    /// streamer, the wire, and the store log without further cloning.
    pub fn segment_payload(&self, layer: usize, pos: usize) -> SegPayload {
        Arc::new(self.read_segment(layer, pos))
    }

    pub fn set_len(&mut self, len: usize) {
        assert!(len <= self.s_max);
        self.len = len;
    }

    /// This request's page table for one layer (positions `[0,
    /// tables[layer].len() * page_tokens)` are backed).
    pub fn page_table(&self, layer: usize) -> &[PageId] {
        &self.tables[layer]
    }

    /// Copy the valid prefix (`len` tokens) of one layer into K / V
    /// destinations of `s_max * seg` floats each (batch-assembly rows).
    /// Positions beyond `len` are left untouched.
    pub fn copy_layer_into(&self, layer: usize, k_dst: &mut [f32], v_dst: &mut [f32]) {
        assert!(k_dst.len() >= self.len * self.seg);
        assert!(v_dst.len() >= self.len * self.seg);
        let pt = self.pool.page_tokens();
        let mut remaining = self.len;
        for (i, &page) in self.tables[layer].iter().enumerate() {
            if remaining == 0 {
                break;
            }
            let tokens = remaining.min(pt);
            let off = i * pt * self.seg;
            self.pool.copy_rows_into(
                page,
                tokens,
                &mut k_dst[off..off + tokens * self.seg],
                &mut v_dst[off..off + tokens * self.seg],
            );
            remaining -= tokens;
        }
    }

    /// Materialize the valid K and V prefixes of a layer in one pass
    /// (`len * seg` floats each). Debug/test helper — the hot path uses
    /// `copy_layer_into`.
    pub fn layer_vecs(&self, layer: usize) -> (Vec<f32>, Vec<f32>) {
        let mut k = vec![0.0; self.len * self.seg];
        let mut v = vec![0.0; self.len * self.seg];
        self.copy_layer_into(layer, &mut k, &mut v);
        (k, v)
    }

    /// Materialize the valid K prefix of a layer (`len * seg` floats).
    pub fn k_layer_vec(&self, layer: usize) -> Vec<f32> {
        self.layer_vecs(layer).0
    }

    /// Materialize the valid V prefix of a layer (`len * seg` floats).
    pub fn v_layer_vec(&self, layer: usize) -> Vec<f32> {
        self.layer_vecs(layer).1
    }
}

impl Drop for RequestKv {
    fn drop(&mut self) {
        for table in &self.tables {
            for &page in table {
                self.pool.free(page);
            }
        }
    }
}

impl Clone for RequestKv {
    /// Deep copy: allocates fresh pages and copies every allocated slot
    /// (not just the valid prefix — in-flight positions above `len` are
    /// preserved too). Panics at the page budget; use
    /// [`RequestKv::try_clone`] when failure must not leak.
    fn clone(&self) -> RequestKv {
        self.try_clone().unwrap_or_else(|| {
            panic!("kv page budget exceeded ({} pages)", self.pool.budget_pages())
        })
    }
}

/// Batched KV gather for decode steps. Writes each request's valid page
/// prefix directly into the output tensors — one copy, no intermediate
/// scratch, and no `max_seq` over-copy for short sequences.
pub struct BatchAssembler {
    s_max: usize,
    seg: usize,
    /// Recycled paged-view storage: the `Arc` handed out by
    /// [`gather_paged`](Self::gather_paged) comes back here; once the
    /// caller drops its view, `Arc::get_mut` reclaims the buffer in
    /// place, so steady-state decode does zero heap allocation (the same
    /// contract `IoScratch` gives the expert-I/O path).
    paged_scratch: Option<Arc<Vec<Vec<PageId>>>>,
    /// Warm per-row page-id vectors parked across batch-size changes.
    spare_rows: Vec<Vec<PageId>>,
}

impl BatchAssembler {
    pub fn new(m: &ModelSpec) -> BatchAssembler {
        BatchAssembler {
            s_max: m.max_seq,
            seg: m.kv_heads * m.head_dim,
            paged_scratch: None,
            spare_rows: Vec::new(),
        }
    }

    /// Gather `layer`'s caches of `reqs` into [B, S, kv, d] K/V tensors
    /// (B = bucket size; rows past reqs.len() and positions past each
    /// request's `len` are zero) plus the pos vector.
    /// kv_shape = [bucket, S, kv_heads, head_dim].
    pub fn gather(
        &mut self,
        reqs: &[&RequestKv],
        layer: usize,
        bucket: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> (Tensor, Tensor, Vec<i32>) {
        assert!(reqs.len() <= bucket);
        let row = self.s_max * self.seg;
        let mut k_buf = vec![0.0f32; bucket * row];
        let mut v_buf = vec![0.0f32; bucket * row];
        let mut pos = Vec::with_capacity(bucket);
        for (i, r) in reqs.iter().enumerate() {
            r.copy_layer_into(
                layer,
                &mut k_buf[i * row..(i + 1) * row],
                &mut v_buf[i * row..(i + 1) * row],
            );
            pos.push(r.len() as i32);
        }
        pos.resize(bucket, 0);
        let shape = vec![bucket, self.s_max, kv_heads, head_dim];
        (Tensor::new(shape.clone(), k_buf), Tensor::new(shape, v_buf), pos)
    }

    /// Copy-free gather: hand the decode artifact each request's page
    /// table plus the shared arena instead of materializing contiguous
    /// K/V tensors. KV floats are read in place by the kernel, and the
    /// page-id rows live in recycled storage — once the caller drops the
    /// previous step's view, a gather allocates nothing.
    ///
    /// The arena comes in as a parameter (not stolen from `reqs[0]`) so
    /// an *empty* batch — a bucket drained by a preemption race between
    /// batch selection and gather — yields a valid zero-row view instead
    /// of a panic, mirroring the dense `gather`. `pos` is cleared and
    /// refilled (padded to `bucket`).
    pub fn gather_paged(
        &mut self,
        pool: &Arc<KvPool>,
        reqs: &[&RequestKv],
        layer: usize,
        bucket: usize,
        pos: &mut Vec<i32>,
    ) -> PagedKvView {
        assert!(reqs.len() <= bucket);
        let mut arc = self.paged_scratch.take().unwrap_or_else(|| Arc::new(Vec::new()));
        if Arc::get_mut(&mut arc).is_none() {
            // The caller still holds the previous view; start fresh.
            arc = Arc::new(Vec::new());
        }
        let tables = Arc::get_mut(&mut arc).unwrap();
        while tables.len() > reqs.len() {
            self.spare_rows.push(tables.pop().unwrap());
        }
        while tables.len() < reqs.len() {
            tables.push(self.spare_rows.pop().unwrap_or_default());
        }
        pos.clear();
        for (i, r) in reqs.iter().enumerate() {
            debug_assert!(
                Arc::ptr_eq(r.pool(), pool),
                "batched requests must share one KV arena"
            );
            let t = &mut tables[i];
            t.clear();
            t.extend_from_slice(r.page_table(layer));
            pos.push(r.len() as i32);
        }
        pos.resize(bucket, 0);
        let view = PagedKvView { pool: pool.clone(), tables: arc.clone() };
        self.paged_scratch = Some(arc);
        view
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec {
            layers: 2,
            hidden: 8,
            heads: 2,
            kv_heads: 1,
            head_dim: 4,
            ffn: 16,
            experts: 4,
            top_k: 2,
            vocab: 32,
            max_seq: 6,
        }
    }

    #[test]
    fn write_read_roundtrip() {
        let m = spec();
        let pool = KvPool::for_model(&m);
        let mut kv = RequestKv::new(&m, &pool);
        assert_eq!(kv.segment_bytes(), m.kv_segment_bytes());
        let k = [1.0, 2.0, 3.0, 4.0];
        let v = [5.0, 6.0, 7.0, 8.0];
        kv.write(1, 3, &k, &v);
        kv.set_len(4);
        let seg = kv.read_segment(1, 3);
        assert_eq!(&seg[..4], &k);
        assert_eq!(&seg[4..], &v);
        assert_eq!(kv.len(), 4);
    }

    #[test]
    fn segment_roundtrip_via_restore_path() {
        let m = spec();
        let pool = KvPool::for_model(&m);
        let mut a = RequestKv::new(&m, &pool);
        a.write(0, 2, &[9.0; 4], &[8.0; 4]);
        let seg = a.read_segment(0, 2);
        let mut b = RequestKv::new(&m, &pool);
        b.write_segment(0, 2, &seg);
        b.set_len(3);
        assert_eq!(b.read_segment(0, 2), seg);
    }

    #[test]
    #[should_panic(expected = "kv overflow")]
    fn overflow_panics() {
        let m = spec();
        let pool = KvPool::for_model(&m);
        let mut kv = RequestKv::new(&m, &pool);
        kv.write(0, 6, &[0.0; 4], &[0.0; 4]);
    }

    #[test]
    fn pages_to_extend_counts_worst_case_growth() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut kv = RequestKv::new(&m, &pool);
        // Fresh cache: covering 3 positions needs ceil(3/2)=2 pages/layer.
        assert_eq!(kv.pages_to_extend(3), 2 * m.layers);
        kv.write(0, 0, &[0.0; 4], &[0.0; 4]); // layer 0 now has 1 page
        assert_eq!(kv.pages_to_extend(2), 1, "only layer 1 still needs a page");
        assert_eq!(kv.pages_to_extend(0), 0);
        assert_eq!(pages_for_tokens(3, 2, m.layers), 2 * m.layers);
        assert_eq!(pages_for_tokens(4, 2, 1), 2);
    }

    #[test]
    fn pages_allocate_on_demand_and_free_on_drop() {
        let m = spec(); // max_seq 6 => page_tokens 6 (clamped)
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut kv = RequestKv::new(&m, &pool);
        assert_eq!(pool.pages_in_use(), 0, "empty cache must hold no pages");
        kv.write(0, 0, &[1.0; 4], &[1.0; 4]);
        assert_eq!(pool.pages_in_use(), 1);
        kv.write(0, 3, &[2.0; 4], &[2.0; 4]); // page 1 of layer 0 (+ page 0 already there)
        assert_eq!(pool.pages_in_use(), 2);
        kv.write(1, 0, &[3.0; 4], &[3.0; 4]);
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(kv.allocated_pages(), 3);
        drop(kv);
        assert_eq!(pool.pages_in_use(), 0, "drop must return every page");
    }

    #[test]
    fn clone_deep_copies_pages() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut a = RequestKv::new(&m, &pool);
        a.write(0, 0, &[1.0; 4], &[2.0; 4]);
        a.set_len(1);
        let b = a.clone();
        assert_eq!(pool.pages_in_use(), 2);
        a.write(0, 0, &[9.0; 4], &[9.0; 4]);
        assert_eq!(b.read_segment(0, 0)[..4], [1.0; 4]);
        drop(a);
        assert_eq!(b.read_segment(0, 0)[4..], [2.0; 4]);
    }

    #[test]
    fn batch_assembly_pads_and_orders() {
        let m = spec();
        let pool = KvPool::for_model(&m);
        let mut r1 = RequestKv::new(&m, &pool);
        r1.write(0, 0, &[1.0; 4], &[2.0; 4]);
        r1.set_len(1);
        let mut r2 = RequestKv::new(&m, &pool);
        r2.write(0, 0, &[3.0; 4], &[4.0; 4]);
        r2.write(0, 1, &[5.0; 4], &[6.0; 4]);
        r2.set_len(2);

        let mut asm = BatchAssembler::new(&m);
        let (k, v, pos) = asm.gather(&[&r1, &r2], 0, 4, m.kv_heads, m.head_dim);
        assert_eq!(k.shape(), &[4, 6, 1, 4]);
        assert_eq!(pos, vec![1, 2, 0, 0]);
        // r2's pos-1 K row lands at batch row 1, seq 1.
        let row = 6 * 4;
        assert_eq!(&k.data()[row + 4..row + 8], &[5.0; 4]);
        // padding rows are zero
        assert!(k.data()[2 * row..].iter().all(|&x| x == 0.0));
        assert_eq!(&v.data()[row..row + 4], &[4.0; 4]);
        // positions past each request's len are zero too
        assert!(k.data()[4..row].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn paged_gather_matches_dense_gather_values() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut r1 = RequestKv::new(&m, &pool);
        for p in 0..3 {
            r1.write(0, p, &[p as f32 + 1.0; 4], &[p as f32 + 10.0; 4]);
        }
        r1.set_len(3);
        let mut asm = BatchAssembler::new(&m);
        let (k, v, pos_dense) = asm.gather(&[&r1], 0, 2, m.kv_heads, m.head_dim);
        let mut pos = Vec::new();
        let view = asm.gather_paged(&pool, &[&r1], 0, 2, &mut pos);
        assert_eq!(pos, pos_dense);
        assert_eq!(view.rows(), 1);
        assert_eq!(view.tables[0], r1.page_table(0));
        // Every valid position reads the same floats through either path.
        let read = view.pool.read();
        let seg = m.kv_heads * m.head_dim;
        for t in 0..3 {
            let page = view.tables[0][t / 2];
            let (kr, vr) = read.kv_rows(page, t % 2);
            assert_eq!(kr, &k.data()[t * seg..(t + 1) * seg]);
            assert_eq!(vr, &v.data()[t * seg..(t + 1) * seg]);
        }
    }

    #[test]
    fn paged_gather_accepts_empty_batch() {
        let m = spec();
        let pool = KvPool::for_model(&m);
        let mut asm = BatchAssembler::new(&m);
        let mut pos = Vec::new();
        // A bucket drained by a preemption race must not panic the AW.
        let view = asm.gather_paged(&pool, &[], 0, 4, &mut pos);
        assert_eq!(view.rows(), 0);
        assert_eq!(pos, vec![0; 4], "empty batch still pads pos to the bucket");
    }

    #[test]
    fn paged_gather_recycles_view_storage() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut r1 = RequestKv::new(&m, &pool);
        r1.write(0, 0, &[1.0; 4], &[2.0; 4]);
        r1.set_len(1);
        let mut asm = BatchAssembler::new(&m);
        let mut pos = Vec::new();
        let first = asm.gather_paged(&pool, &[&r1], 0, 2, &mut pos);
        let ptr = Arc::as_ptr(&first.tables);
        drop(first);
        let second = asm.gather_paged(&pool, &[&r1], 0, 2, &mut pos);
        assert_eq!(
            Arc::as_ptr(&second.tables),
            ptr,
            "dropped view's storage must be reused in place"
        );
        assert_eq!(second.tables[0], r1.page_table(0));
        // Held view forces a fresh buffer (correctness over recycling).
        let third = asm.gather_paged(&pool, &[&r1], 0, 2, &mut pos);
        assert_ne!(Arc::as_ptr(&third.tables), Arc::as_ptr(&second.tables));
        assert_eq!(third.tables[0], r1.page_table(0));
    }

    #[test]
    fn try_clone_rolls_back_on_budget_without_leaking() {
        let m = spec();
        // Budget fits the source (3 pages) plus only 2 more: the clone
        // needs 3, so it must fail and return every partial page.
        let pool = KvPool::bounded(PoolConfig { page_tokens: 2, seg: 4 }, 5);
        let mut kv = RequestKv::new(&m, &pool);
        for pos in 0..3 {
            kv.write(0, pos, &[pos as f32; 4], &[pos as f32; 4]);
        }
        kv.write(1, 0, &[7.0; 4], &[7.0; 4]);
        kv.set_len(3);
        assert_eq!(pool.pages_in_use(), 3);
        assert!(kv.try_clone().is_none(), "clone cannot fit under the budget");
        assert_eq!(pool.pages_in_use(), 3, "failed clone must leak nothing");
        // With headroom the clone succeeds and is a bitwise deep copy.
        pool.set_budget(6);
        let c = kv.try_clone().expect("fits now");
        assert_eq!(pool.pages_in_use(), 6);
        assert_eq!(c.read_segment(0, 2), kv.read_segment(0, 2));
        assert_eq!(c.read_segment(1, 0), kv.read_segment(1, 0));
        drop(c);
        assert_eq!(pool.pages_in_use(), 3);
    }

    #[test]
    fn prompt_layer_share_hits_and_cow_diverges() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let seg = m.kv_heads * m.head_dim;
        // 5-token prompt = 2 full pages + 1 tail token per layer.
        let p_len = 5;
        let k = Tensor::new(
            vec![p_len, seg],
            (0..p_len * seg).map(|i| i as f32).collect(),
        );
        let v = Tensor::new(
            vec![p_len, seg],
            (0..p_len * seg).map(|i| -(i as f32)).collect(),
        );

        let mut a = RequestKv::new(&m, &pool);
        let out_a = a.write_prompt_layer(0, p_len, &k, &v);
        a.set_len(p_len);
        assert!(out_a.shared.is_empty(), "first request has nothing to share");
        assert_eq!(out_a.written, (0..p_len).collect::<Vec<_>>());
        let pages_after_a = pool.pages_in_use();

        let mut b = RequestKv::new(&m, &pool);
        let out_b = b.write_prompt_layer(0, p_len, &k, &v);
        b.set_len(p_len);
        assert_eq!(out_b.shared.len(), 2, "both full pages must hit");
        assert_eq!(out_b.written, vec![4], "only the tail is written");
        assert_eq!(b.shared_prefix_pages(0), 2);
        assert_eq!(
            pool.pages_in_use(),
            pages_after_a + 1,
            "the sharing request pays one physical page (its tail)"
        );
        assert_eq!(b.page_table(0)[..2], a.page_table(0)[..2]);
        assert_ne!(b.page_table(0)[2], a.page_table(0)[2]);

        // Byte-identical reads through the shared pages.
        for pos in 0..p_len {
            assert_eq!(b.read_segment(0, pos), a.read_segment(0, pos));
        }

        // Divergence inside the shared prefix triggers CoW: both
        // variants remain readable, bitwise.
        let before = a.read_segment(0, 1);
        b.write(0, 1, &[99.0; 4], &[98.0; 4]);
        assert_eq!(pool.cow_breaks(), 1);
        assert_ne!(b.page_table(0)[0], a.page_table(0)[0]);
        assert_eq!(a.read_segment(0, 1), before, "original untouched");
        assert_eq!(b.read_segment(0, 1), [[99.0; 4], [98.0; 4]].concat());
        assert_eq!(b.read_segment(0, 0), a.read_segment(0, 0), "untouched slot copied over");

        // Drops balance: every physical page comes back.
        drop(b);
        drop(a);
        assert_eq!(pool.pages_in_use(), 0);
    }

    #[test]
    fn assembly_spanning_multiple_pages() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut kv = RequestKv::new(&m, &pool);
        for pos in 0..5 {
            kv.write(0, pos, &[pos as f32; 4], &[10.0 + pos as f32; 4]);
        }
        kv.set_len(5);
        assert_eq!(kv.tables[0].len(), 3);
        let mut asm = BatchAssembler::new(&m);
        let (k, v, pos) = asm.gather(&[&kv], 0, 1, m.kv_heads, m.head_dim);
        assert_eq!(pos, vec![5]);
        for p in 0..5 {
            assert_eq!(&k.data()[p * 4..(p + 1) * 4], &[p as f32; 4]);
            assert_eq!(&v.data()[p * 4..(p + 1) * 4], &[10.0 + p as f32; 4]);
        }
        assert!(k.data()[5 * 4..].iter().all(|&x| x == 0.0));
    }
}
