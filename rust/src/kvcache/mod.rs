//! Per-request KV cache management on the Attention Worker — paged.
//!
//! KV memory is block-pool allocated (see [`pool`]): a [`RequestKv`] is a
//! per-layer *page table* into a shared [`KvPool`] arena instead of a
//! contiguous `max_seq × kv_heads × head_dim` preallocation, so resident
//! memory scales with the actual sequence length and a finished request's
//! pages are immediately reusable. A "segment" — the unit of incremental
//! checkpointing (§6.1) and restoration (§6.2) — is one (token, layer)'s
//! K and V vectors concatenated (`2 * kv_heads * head_dim` floats) and is
//! exactly one page slot, so segment read/restore is a single slice copy.
//!
//! [`BatchAssembler`] gathers the *valid prefix* of each request's pages
//! into the batched `[B, S, kv, d]` tensors of a decode step — one copy
//! per layer, and only `len` tokens of it per request rather than
//! `max_seq` (the decode artifact masks by the pos vector, so the padded
//! tail only ever needs to be zero).

pub mod pool;

pub use pool::{KvPool, PageId, PagesRead, PoolConfig, DEFAULT_PAGE_TOKENS};

/// Worst-case pool pages for a request spanning `tokens` positions across
/// `layers` layers — the admission-time fit check: a request whose
/// worst-case footprint exceeds the per-AW page budget can never be
/// served and must be rejected at the gateway (DESIGN.md §9).
pub fn pages_for_tokens(tokens: usize, page_tokens: usize, layers: usize) -> usize {
    layers * tokens.div_ceil(page_tokens.max(1))
}

use crate::modelcfg::ModelSpec;
use crate::proto::SegPayload;
use crate::tensor::Tensor;
use std::sync::Arc;

/// A decode batch's KV state for one layer, by reference: the shared page
/// arena plus each batch row's page table. This is what the decode
/// attention artifact receives instead of contiguous `[B, S, kv, d]`
/// copies — the kernel reads rows in place under [`KvPool::read`]
/// (DESIGN.md §10). Cloning bumps two `Arc`s; no KV bytes move.
#[derive(Clone)]
pub struct PagedKvView {
    pub pool: Arc<KvPool>,
    /// Per batch row (row i = batch slot i): that row's page table for
    /// the layer. Rows beyond `tables.len()` are padding (no KV state).
    pub tables: Arc<Vec<Vec<PageId>>>,
}

impl PagedKvView {
    /// Valid (non-padding) batch rows.
    pub fn rows(&self) -> usize {
        self.tables.len()
    }
}

impl std::fmt::Debug for PagedKvView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedKvView")
            .field("rows", &self.tables.len())
            .field("pages", &self.tables.iter().map(|t| t.len()).sum::<usize>())
            .finish()
    }
}

/// Per-request KV cache across all layers, backed by pool pages.
pub struct RequestKv {
    pool: Arc<KvPool>,
    /// Per layer: pages covering positions `[0, pages.len() * page_tokens)`.
    tables: Vec<Vec<PageId>>,
    /// Valid positions [0, len).
    len: usize,
    s_max: usize,
    /// Elements of one K (or V) row: kv_heads * head_dim.
    seg: usize,
}

impl std::fmt::Debug for RequestKv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RequestKv")
            .field("len", &self.len)
            .field("pages", &self.allocated_pages())
            .field("layers", &self.tables.len())
            .finish()
    }
}

impl RequestKv {
    /// An empty cache: no pages are allocated until positions are written.
    pub fn new(m: &ModelSpec, pool: &Arc<KvPool>) -> RequestKv {
        let seg = m.kv_heads * m.head_dim;
        assert_eq!(
            seg,
            pool.row_elems(),
            "pool geometry does not match the model (seg {} vs {})",
            pool.row_elems(),
            seg
        );
        RequestKv {
            pool: pool.clone(),
            tables: vec![Vec::new(); m.layers],
            len: 0,
            s_max: m.max_seq,
            seg,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn layers(&self) -> usize {
        self.tables.len()
    }

    /// Elements in one K or V row.
    pub fn row_elems(&self) -> usize {
        self.seg
    }

    /// Bytes of one checkpoint segment (K+V for one token, one layer).
    pub fn segment_bytes(&self) -> usize {
        2 * self.seg * 4
    }

    /// The arena this cache allocates from.
    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Pages currently allocated to this request (all layers).
    pub fn allocated_pages(&self) -> usize {
        self.tables.iter().map(|t| t.len()).sum()
    }

    /// Resident bytes of this request's KV state.
    pub fn resident_bytes(&self) -> usize {
        self.allocated_pages() * self.pool.page_floats() * 4
    }

    /// Additional pool pages this cache must allocate to cover positions
    /// `[0, new_len)` across all layers (0 if already covered) — the
    /// pre-step headroom check of the overload scheduler.
    pub fn pages_to_extend(&self, new_len: usize) -> usize {
        let pt = self.pool.page_tokens();
        let need = new_len.div_ceil(pt);
        self.tables.iter().map(|t| need.saturating_sub(t.len())).sum()
    }

    /// Eagerly allocate (zeroed) pages covering positions `[0, upto)`
    /// across all layers. The restore path *reserves* its prefix plus the
    /// next decode position this way, so a headroom check cannot be
    /// invalidated by a later install racing for the same free pages.
    pub fn reserve(&mut self, upto: usize) {
        let pt = self.pool.page_tokens();
        let need = upto.div_ceil(pt);
        for table in &mut self.tables {
            while table.len() < need {
                table.push(self.pool.alloc());
            }
        }
    }

    /// (page, slot) of a position, allocating pages on demand.
    fn locate_mut(&mut self, layer: usize, pos: usize) -> (PageId, usize) {
        let pt = self.pool.page_tokens();
        let page_idx = pos / pt;
        let table = &mut self.tables[layer];
        while table.len() <= page_idx {
            table.push(self.pool.alloc());
        }
        (table[page_idx], pos % pt)
    }

    fn locate(&self, layer: usize, pos: usize) -> (PageId, usize) {
        let pt = self.pool.page_tokens();
        let page_idx = pos / pt;
        (self.tables[layer][page_idx], pos % pt)
    }

    /// Write K/V for position `pos` of `layer` (decode append or prefill
    /// bulk write). Does NOT advance `len` — call `set_len` once all layers
    /// for a position are written (the per-step commit point).
    pub fn write(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(pos < self.s_max, "kv overflow: pos {pos} >= {}", self.s_max);
        let (page, slot) = self.locate_mut(layer, pos);
        self.pool.write_rows(page, slot, k_row, v_row);
    }

    /// Install a checkpoint segment (K||V concatenated), restoration path.
    /// Allocates exactly the pages the restored prefix needs.
    pub fn write_segment(&mut self, layer: usize, pos: usize, seg_data: &[f32]) {
        assert!(pos < self.s_max, "kv overflow: pos {pos} >= {}", self.s_max);
        assert_eq!(seg_data.len(), 2 * self.seg, "bad segment size");
        let (page, slot) = self.locate_mut(layer, pos);
        self.pool.write_segment(page, slot, seg_data);
    }

    /// Read one segment back (K||V) — the checkpoint streamer's source.
    pub fn read_segment(&self, layer: usize, pos: usize) -> Vec<f32> {
        let (page, slot) = self.locate(layer, pos);
        self.pool.read_segment(page, slot)
    }

    /// Read one segment as a shared checkpoint payload. This is the single
    /// copy on the checkpoint path: the returned `Arc` travels through the
    /// streamer, the wire, and the store log without further cloning.
    pub fn segment_payload(&self, layer: usize, pos: usize) -> SegPayload {
        Arc::new(self.read_segment(layer, pos))
    }

    pub fn set_len(&mut self, len: usize) {
        assert!(len <= self.s_max);
        self.len = len;
    }

    /// This request's page table for one layer (positions `[0,
    /// tables[layer].len() * page_tokens)` are backed).
    pub fn page_table(&self, layer: usize) -> &[PageId] {
        &self.tables[layer]
    }

    /// Copy the valid prefix (`len` tokens) of one layer into K / V
    /// destinations of `s_max * seg` floats each (batch-assembly rows).
    /// Positions beyond `len` are left untouched.
    pub fn copy_layer_into(&self, layer: usize, k_dst: &mut [f32], v_dst: &mut [f32]) {
        assert!(k_dst.len() >= self.len * self.seg);
        assert!(v_dst.len() >= self.len * self.seg);
        let pt = self.pool.page_tokens();
        let mut remaining = self.len;
        for (i, &page) in self.tables[layer].iter().enumerate() {
            if remaining == 0 {
                break;
            }
            let tokens = remaining.min(pt);
            let off = i * pt * self.seg;
            self.pool.copy_rows_into(
                page,
                tokens,
                &mut k_dst[off..off + tokens * self.seg],
                &mut v_dst[off..off + tokens * self.seg],
            );
            remaining -= tokens;
        }
    }

    /// Materialize the valid K and V prefixes of a layer in one pass
    /// (`len * seg` floats each). Debug/test helper — the hot path uses
    /// `copy_layer_into`.
    pub fn layer_vecs(&self, layer: usize) -> (Vec<f32>, Vec<f32>) {
        let mut k = vec![0.0; self.len * self.seg];
        let mut v = vec![0.0; self.len * self.seg];
        self.copy_layer_into(layer, &mut k, &mut v);
        (k, v)
    }

    /// Materialize the valid K prefix of a layer (`len * seg` floats).
    pub fn k_layer_vec(&self, layer: usize) -> Vec<f32> {
        self.layer_vecs(layer).0
    }

    /// Materialize the valid V prefix of a layer (`len * seg` floats).
    pub fn v_layer_vec(&self, layer: usize) -> Vec<f32> {
        self.layer_vecs(layer).1
    }
}

impl Drop for RequestKv {
    fn drop(&mut self) {
        for table in &self.tables {
            for &page in table {
                self.pool.free(page);
            }
        }
    }
}

impl Clone for RequestKv {
    /// Deep copy: allocates fresh pages and copies every allocated slot
    /// (not just the valid prefix — in-flight positions above `len` are
    /// preserved too).
    fn clone(&self) -> RequestKv {
        let pt = self.pool.page_tokens();
        let tables = self
            .tables
            .iter()
            .map(|table| {
                table
                    .iter()
                    .map(|&src| {
                        let dst = self.pool.alloc();
                        for slot in 0..pt {
                            let data = self.pool.read_segment(src, slot);
                            self.pool.write_segment(dst, slot, &data);
                        }
                        dst
                    })
                    .collect()
            })
            .collect();
        RequestKv {
            pool: self.pool.clone(),
            tables,
            len: self.len,
            s_max: self.s_max,
            seg: self.seg,
        }
    }
}

/// Batched KV gather for decode steps. Writes each request's valid page
/// prefix directly into the output tensors — one copy, no intermediate
/// scratch, and no `max_seq` over-copy for short sequences.
pub struct BatchAssembler {
    s_max: usize,
    seg: usize,
}

impl BatchAssembler {
    pub fn new(m: &ModelSpec) -> BatchAssembler {
        BatchAssembler { s_max: m.max_seq, seg: m.kv_heads * m.head_dim }
    }

    /// Gather `layer`'s caches of `reqs` into [B, S, kv, d] K/V tensors
    /// (B = bucket size; rows past reqs.len() and positions past each
    /// request's `len` are zero) plus the pos vector.
    /// kv_shape = [bucket, S, kv_heads, head_dim].
    pub fn gather(
        &mut self,
        reqs: &[&RequestKv],
        layer: usize,
        bucket: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> (Tensor, Tensor, Vec<i32>) {
        assert!(reqs.len() <= bucket);
        let row = self.s_max * self.seg;
        let mut k_buf = vec![0.0f32; bucket * row];
        let mut v_buf = vec![0.0f32; bucket * row];
        let mut pos = Vec::with_capacity(bucket);
        for (i, r) in reqs.iter().enumerate() {
            r.copy_layer_into(
                layer,
                &mut k_buf[i * row..(i + 1) * row],
                &mut v_buf[i * row..(i + 1) * row],
            );
            pos.push(r.len() as i32);
        }
        pos.resize(bucket, 0);
        let shape = vec![bucket, self.s_max, kv_heads, head_dim];
        (Tensor::new(shape.clone(), k_buf), Tensor::new(shape, v_buf), pos)
    }

    /// Copy-free gather: hand the decode artifact each request's page
    /// table plus the shared arena instead of materializing contiguous
    /// K/V tensors. The only per-call work is cloning `reqs.len()` small
    /// page-id vectors; KV floats are read in place by the kernel.
    /// Returns the view and the pos vector (padded to `bucket`).
    pub fn gather_paged(
        &mut self,
        reqs: &[&RequestKv],
        layer: usize,
        bucket: usize,
    ) -> (PagedKvView, Vec<i32>) {
        assert!(!reqs.is_empty() && reqs.len() <= bucket);
        let pool = reqs[0].pool().clone();
        let mut tables = Vec::with_capacity(reqs.len());
        let mut pos = Vec::with_capacity(bucket);
        for r in reqs {
            debug_assert!(
                Arc::ptr_eq(r.pool(), &pool),
                "batched requests must share one KV arena"
            );
            tables.push(r.page_table(layer).to_vec());
            pos.push(r.len() as i32);
        }
        pos.resize(bucket, 0);
        (PagedKvView { pool, tables: Arc::new(tables) }, pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec {
            layers: 2,
            hidden: 8,
            heads: 2,
            kv_heads: 1,
            head_dim: 4,
            ffn: 16,
            experts: 4,
            top_k: 2,
            vocab: 32,
            max_seq: 6,
        }
    }

    #[test]
    fn write_read_roundtrip() {
        let m = spec();
        let pool = KvPool::for_model(&m);
        let mut kv = RequestKv::new(&m, &pool);
        assert_eq!(kv.segment_bytes(), m.kv_segment_bytes());
        let k = [1.0, 2.0, 3.0, 4.0];
        let v = [5.0, 6.0, 7.0, 8.0];
        kv.write(1, 3, &k, &v);
        kv.set_len(4);
        let seg = kv.read_segment(1, 3);
        assert_eq!(&seg[..4], &k);
        assert_eq!(&seg[4..], &v);
        assert_eq!(kv.len(), 4);
    }

    #[test]
    fn segment_roundtrip_via_restore_path() {
        let m = spec();
        let pool = KvPool::for_model(&m);
        let mut a = RequestKv::new(&m, &pool);
        a.write(0, 2, &[9.0; 4], &[8.0; 4]);
        let seg = a.read_segment(0, 2);
        let mut b = RequestKv::new(&m, &pool);
        b.write_segment(0, 2, &seg);
        b.set_len(3);
        assert_eq!(b.read_segment(0, 2), seg);
    }

    #[test]
    #[should_panic(expected = "kv overflow")]
    fn overflow_panics() {
        let m = spec();
        let pool = KvPool::for_model(&m);
        let mut kv = RequestKv::new(&m, &pool);
        kv.write(0, 6, &[0.0; 4], &[0.0; 4]);
    }

    #[test]
    fn pages_to_extend_counts_worst_case_growth() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut kv = RequestKv::new(&m, &pool);
        // Fresh cache: covering 3 positions needs ceil(3/2)=2 pages/layer.
        assert_eq!(kv.pages_to_extend(3), 2 * m.layers);
        kv.write(0, 0, &[0.0; 4], &[0.0; 4]); // layer 0 now has 1 page
        assert_eq!(kv.pages_to_extend(2), 1, "only layer 1 still needs a page");
        assert_eq!(kv.pages_to_extend(0), 0);
        assert_eq!(pages_for_tokens(3, 2, m.layers), 2 * m.layers);
        assert_eq!(pages_for_tokens(4, 2, 1), 2);
    }

    #[test]
    fn pages_allocate_on_demand_and_free_on_drop() {
        let m = spec(); // max_seq 6 => page_tokens 6 (clamped)
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut kv = RequestKv::new(&m, &pool);
        assert_eq!(pool.pages_in_use(), 0, "empty cache must hold no pages");
        kv.write(0, 0, &[1.0; 4], &[1.0; 4]);
        assert_eq!(pool.pages_in_use(), 1);
        kv.write(0, 3, &[2.0; 4], &[2.0; 4]); // page 1 of layer 0 (+ page 0 already there)
        assert_eq!(pool.pages_in_use(), 2);
        kv.write(1, 0, &[3.0; 4], &[3.0; 4]);
        assert_eq!(pool.pages_in_use(), 3);
        assert_eq!(kv.allocated_pages(), 3);
        drop(kv);
        assert_eq!(pool.pages_in_use(), 0, "drop must return every page");
    }

    #[test]
    fn clone_deep_copies_pages() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut a = RequestKv::new(&m, &pool);
        a.write(0, 0, &[1.0; 4], &[2.0; 4]);
        a.set_len(1);
        let b = a.clone();
        assert_eq!(pool.pages_in_use(), 2);
        a.write(0, 0, &[9.0; 4], &[9.0; 4]);
        assert_eq!(b.read_segment(0, 0)[..4], [1.0; 4]);
        drop(a);
        assert_eq!(b.read_segment(0, 0)[4..], [2.0; 4]);
    }

    #[test]
    fn batch_assembly_pads_and_orders() {
        let m = spec();
        let pool = KvPool::for_model(&m);
        let mut r1 = RequestKv::new(&m, &pool);
        r1.write(0, 0, &[1.0; 4], &[2.0; 4]);
        r1.set_len(1);
        let mut r2 = RequestKv::new(&m, &pool);
        r2.write(0, 0, &[3.0; 4], &[4.0; 4]);
        r2.write(0, 1, &[5.0; 4], &[6.0; 4]);
        r2.set_len(2);

        let mut asm = BatchAssembler::new(&m);
        let (k, v, pos) = asm.gather(&[&r1, &r2], 0, 4, m.kv_heads, m.head_dim);
        assert_eq!(k.shape(), &[4, 6, 1, 4]);
        assert_eq!(pos, vec![1, 2, 0, 0]);
        // r2's pos-1 K row lands at batch row 1, seq 1.
        let row = 6 * 4;
        assert_eq!(&k.data()[row + 4..row + 8], &[5.0; 4]);
        // padding rows are zero
        assert!(k.data()[2 * row..].iter().all(|&x| x == 0.0));
        assert_eq!(&v.data()[row..row + 4], &[4.0; 4]);
        // positions past each request's len are zero too
        assert!(k.data()[4..row].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn paged_gather_matches_dense_gather_values() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut r1 = RequestKv::new(&m, &pool);
        for p in 0..3 {
            r1.write(0, p, &[p as f32 + 1.0; 4], &[p as f32 + 10.0; 4]);
        }
        r1.set_len(3);
        let mut asm = BatchAssembler::new(&m);
        let (k, v, pos_dense) = asm.gather(&[&r1], 0, 2, m.kv_heads, m.head_dim);
        let (view, pos) = asm.gather_paged(&[&r1], 0, 2);
        assert_eq!(pos, pos_dense);
        assert_eq!(view.rows(), 1);
        assert_eq!(view.tables[0], r1.page_table(0));
        // Every valid position reads the same floats through either path.
        let read = view.pool.read();
        let seg = m.kv_heads * m.head_dim;
        for t in 0..3 {
            let page = view.tables[0][t / 2];
            let (kr, vr) = read.kv_rows(page, t % 2);
            assert_eq!(kr, &k.data()[t * seg..(t + 1) * seg]);
            assert_eq!(vr, &v.data()[t * seg..(t + 1) * seg]);
        }
    }

    #[test]
    fn assembly_spanning_multiple_pages() {
        let m = spec();
        let pool = KvPool::with_page_tokens(&m, 2);
        let mut kv = RequestKv::new(&m, &pool);
        for pos in 0..5 {
            kv.write(0, pos, &[pos as f32; 4], &[10.0 + pos as f32; 4]);
        }
        kv.set_len(5);
        assert_eq!(kv.tables[0].len(), 3);
        let mut asm = BatchAssembler::new(&m);
        let (k, v, pos) = asm.gather(&[&kv], 0, 1, m.kv_heads, m.head_dim);
        assert_eq!(pos, vec![5]);
        for p in 0..5 {
            assert_eq!(&k.data()[p * 4..(p + 1) * 4], &[p as f32; 4]);
            assert_eq!(&v.data()[p * 4..(p + 1) * 4], &[10.0 + p as f32; 4]);
        }
        assert!(k.data()[5 * 4..].iter().all(|&x| x == 0.0));
    }
}
