//! Per-request KV cache management on the Attention Worker.
//!
//! Layout mirrors what the decode artifact consumes: per layer, two
//! contiguous `[S, kv_heads, head_dim]` f32 regions (K and V), with a
//! valid-prefix length shared by all layers. A "segment" — the unit of
//! incremental checkpointing (§6.1) and restoration (§6.2) — is one
//! (token, layer)'s K and V vectors concatenated: `2 * kv_heads * head_dim`
//! floats.
//!
//! [`BatchAssembler`] gathers per-request caches into the batched
//! `[B, S, kv, d]` tensors of a decode step with a single copy per layer
//! (the buffers are handed to the device, so the copy is unavoidable; the
//! perf pass removed the second copy a scratch-buffer design had).

use crate::modelcfg::ModelSpec;
use crate::tensor::Tensor;

/// Per-request KV cache across all layers.
#[derive(Debug, Clone)]
pub struct RequestKv {
    /// Per layer: K then V, each `s_max * seg` floats.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Valid positions [0, len).
    len: usize,
    s_max: usize,
    /// Elements of one K (or V) row: kv_heads * head_dim.
    seg: usize,
}

impl RequestKv {
    pub fn new(m: &ModelSpec) -> RequestKv {
        let seg = m.kv_heads * m.head_dim;
        RequestKv {
            k: (0..m.layers).map(|_| vec![0.0; m.max_seq * seg]).collect(),
            v: (0..m.layers).map(|_| vec![0.0; m.max_seq * seg]).collect(),
            len: 0,
            s_max: m.max_seq,
            seg,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn layers(&self) -> usize {
        self.k.len()
    }

    /// Elements in one K or V row.
    pub fn row_elems(&self) -> usize {
        self.seg
    }

    /// Bytes of one checkpoint segment (K+V for one token, one layer).
    pub fn segment_bytes(&self) -> usize {
        2 * self.seg * 4
    }

    /// Write K/V for position `pos` of `layer` (decode append or prefill
    /// bulk write). Does NOT advance `len` — call `set_len` once all layers
    /// for a position are written (the per-step commit point).
    pub fn write(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(pos < self.s_max, "kv overflow: pos {pos} >= {}", self.s_max);
        assert_eq!(k_row.len(), self.seg);
        assert_eq!(v_row.len(), self.seg);
        let off = pos * self.seg;
        self.k[layer][off..off + self.seg].copy_from_slice(k_row);
        self.v[layer][off..off + self.seg].copy_from_slice(v_row);
    }

    /// Install a checkpoint segment (K||V concatenated), restoration path.
    pub fn write_segment(&mut self, layer: usize, pos: usize, seg_data: &[f32]) {
        assert_eq!(seg_data.len(), 2 * self.seg, "bad segment size");
        let (kr, vr) = seg_data.split_at(self.seg);
        self.write(layer, pos, kr, vr);
    }

    /// Read one segment back (K||V) — the checkpoint streamer's source.
    pub fn read_segment(&self, layer: usize, pos: usize) -> Vec<f32> {
        let off = pos * self.seg;
        let mut out = Vec::with_capacity(2 * self.seg);
        out.extend_from_slice(&self.k[layer][off..off + self.seg]);
        out.extend_from_slice(&self.v[layer][off..off + self.seg]);
        out
    }

    pub fn set_len(&mut self, len: usize) {
        assert!(len <= self.s_max);
        self.len = len;
    }

    pub fn k_layer(&self, layer: usize) -> &[f32] {
        &self.k[layer]
    }

    pub fn v_layer(&self, layer: usize) -> &[f32] {
        &self.v[layer]
    }
}

/// Batched KV gather for decode steps. Writes each request's cache
/// directly into the output tensors — one copy, no intermediate scratch
/// (perf pass: the gather runs once per layer per decode step).
pub struct BatchAssembler {
    s_max: usize,
    seg: usize,
}

impl BatchAssembler {
    pub fn new(m: &ModelSpec) -> BatchAssembler {
        BatchAssembler { s_max: m.max_seq, seg: m.kv_heads * m.head_dim }
    }

    /// Gather `layer`'s caches of `reqs` into [B, S, kv, d] K/V tensors
    /// (B = bucket size; rows past reqs.len() are zero-padded) plus the
    /// pos vector. kv_shape = [bucket, S, kv_heads, head_dim].
    pub fn gather(
        &mut self,
        reqs: &[&RequestKv],
        layer: usize,
        bucket: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> (Tensor, Tensor, Vec<i32>) {
        assert!(reqs.len() <= bucket);
        let row = self.s_max * self.seg;
        let mut k_buf = vec![0.0f32; bucket * row];
        let mut v_buf = vec![0.0f32; bucket * row];
        let mut pos = Vec::with_capacity(bucket);
        for (i, r) in reqs.iter().enumerate() {
            k_buf[i * row..(i + 1) * row].copy_from_slice(r.k_layer(layer));
            v_buf[i * row..(i + 1) * row].copy_from_slice(r.v_layer(layer));
            pos.push(r.len() as i32);
        }
        pos.resize(bucket, 0);
        let shape = vec![bucket, self.s_max, kv_heads, head_dim];
        (Tensor::new(shape.clone(), k_buf), Tensor::new(shape, v_buf), pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ModelSpec {
        ModelSpec {
            layers: 2,
            hidden: 8,
            heads: 2,
            kv_heads: 1,
            head_dim: 4,
            ffn: 16,
            experts: 4,
            top_k: 2,
            vocab: 32,
            max_seq: 6,
        }
    }

    #[test]
    fn write_read_roundtrip() {
        let m = spec();
        let mut kv = RequestKv::new(&m);
        assert_eq!(kv.segment_bytes(), m.kv_segment_bytes());
        let k = [1.0, 2.0, 3.0, 4.0];
        let v = [5.0, 6.0, 7.0, 8.0];
        kv.write(1, 3, &k, &v);
        kv.set_len(4);
        let seg = kv.read_segment(1, 3);
        assert_eq!(&seg[..4], &k);
        assert_eq!(&seg[4..], &v);
        assert_eq!(kv.len(), 4);
    }

    #[test]
    fn segment_roundtrip_via_restore_path() {
        let m = spec();
        let mut a = RequestKv::new(&m);
        a.write(0, 2, &[9.0; 4], &[8.0; 4]);
        let seg = a.read_segment(0, 2);
        let mut b = RequestKv::new(&m);
        b.write_segment(0, 2, &seg);
        b.set_len(3);
        assert_eq!(b.read_segment(0, 2), seg);
    }

    #[test]
    #[should_panic(expected = "kv overflow")]
    fn overflow_panics() {
        let m = spec();
        let mut kv = RequestKv::new(&m);
        kv.write(0, 6, &[0.0; 4], &[0.0; 4]);
    }

    #[test]
    fn batch_assembly_pads_and_orders() {
        let m = spec();
        let mut r1 = RequestKv::new(&m);
        r1.write(0, 0, &[1.0; 4], &[2.0; 4]);
        r1.set_len(1);
        let mut r2 = RequestKv::new(&m);
        r2.write(0, 0, &[3.0; 4], &[4.0; 4]);
        r2.write(0, 1, &[5.0; 4], &[6.0; 4]);
        r2.set_len(2);

        let mut asm = BatchAssembler::new(&m);
        let (k, v, pos) = asm.gather(&[&r1, &r2], 0, 4, m.kv_heads, m.head_dim);
        assert_eq!(k.shape(), &[4, 6, 1, 4]);
        assert_eq!(pos, vec![1, 2, 0, 0]);
        // r2's pos-1 K row lands at batch row 1, seq 1.
        let row = 6 * 4;
        assert_eq!(&k.data()[row + 4..row + 8], &[5.0; 4]);
        // padding rows are zero
        assert!(k.data()[2 * row..].iter().all(|&x| x == 0.0));
        assert_eq!(&v.data()[row..row + 4], &[4.0; 4]);
    }
}
