//! Model/artifact manifest loading: the contract between the build-time
//! Python pipeline (`python/compile/aot.py`) and the Rust runtime.
//!
//! `artifacts/manifest.json` carries the model architecture, the static
//! shape buckets every artifact was AOT-compiled for, per-artifact I/O
//! specs (the call ABI), and the weight-blob offset table.

pub mod weights;

use crate::util::json::Json;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum ManifestError {
    Io(PathBuf, std::io::Error),
    Parse(String),
}

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ManifestError::Io(path, e) => {
                write!(f, "io error reading {}: {e}", path.display())
            }
            ManifestError::Parse(msg) => write!(f, "manifest parse error: {msg}"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io(_, e) => Some(e),
            ManifestError::Parse(_) => None,
        }
    }
}

/// Architecture of the served model (mirrors python/compile/configs.py).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub experts: usize,
    pub top_k: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl ModelSpec {
    /// Bytes of one KV-cache segment: one token, one layer (K and V).
    /// This is `C` in the paper's Appendix C checkpoint-overhead analysis.
    pub fn kv_segment_bytes(&self) -> usize {
        2 * self.kv_heads * self.head_dim * 4
    }

    /// Bytes of per-token, per-layer AW->EW traffic (`V` in Appendix C):
    /// top_k expert dispatches of a hidden vector, there and back.
    pub fn expert_traffic_bytes(&self) -> usize {
        2 * self.top_k * self.hidden * 4
    }

    /// Full per-request KV-cache bytes across all layers at max_seq.
    pub fn kv_request_bytes(&self) -> usize {
        self.layers * self.max_seq * self.kv_segment_bytes()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    AttnPrefill,
    AttnDecode,
    Router,
    Expert,
    LmHead,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Option<ArtifactKind> {
        Some(match s {
            "attn_prefill" => ArtifactKind::AttnPrefill,
            "attn_decode" => ArtifactKind::AttnDecode,
            "router" => ArtifactKind::Router,
            "expert" => ArtifactKind::Expert,
            "lm_head" => ArtifactKind::LmHead,
            _ => return None,
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ArtifactKind::AttnPrefill => "attn_prefill",
            ArtifactKind::AttnDecode => "attn_decode",
            ArtifactKind::Router => "router",
            ArtifactKind::Expert => "expert",
            ArtifactKind::LmHead => "lm_head",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub bucket: usize,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Shape buckets (see python/compile/configs.py::Buckets).
#[derive(Debug, Clone)]
pub struct Buckets {
    pub prefill_t: Vec<usize>,
    pub decode_b: Vec<usize>,
    pub expert_b: Vec<usize>,
    pub router_b: Vec<usize>,
    pub lm_head_b: Vec<usize>,
}

impl Buckets {
    /// Smallest bucket >= n, or None if n exceeds the largest bucket.
    pub fn fit(buckets: &[usize], n: usize) -> Option<usize> {
        buckets.iter().copied().filter(|&b| b >= n).min()
    }
}

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset in *elements* (f32) into the blob.
    pub offset_elems: usize,
    pub len_elems: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelSpec,
    pub buckets: Buckets,
    pub artifacts: Vec<ArtifactSpec>,
    pub weight_file: String,
    pub weight_entries: Vec<WeightEntry>,
}

fn parse_err(msg: impl Into<String>) -> ManifestError {
    ManifestError::Parse(msg.into())
}

fn req_usize(j: &Json, key: &str) -> Result<usize, ManifestError> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| parse_err(format!("missing numeric field '{key}'")))
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, ManifestError> {
    j.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| parse_err(format!("missing string field '{key}'")))
}

fn usize_list(j: &Json, key: &str) -> Result<Vec<usize>, ManifestError> {
    j.get(key)
        .and_then(|v| v.usize_vec())
        .ok_or_else(|| parse_err(format!("missing list field '{key}'")))
}

fn parse_io(j: &Json) -> Result<IoSpec, ManifestError> {
    let dtype = match req_str(j, "dtype")? {
        "f32" => DType::F32,
        "i32" => DType::I32,
        other => return Err(parse_err(format!("unknown dtype '{other}'"))),
    };
    Ok(IoSpec {
        name: req_str(j, "name")?.to_string(),
        shape: usize_list(j, "shape")?,
        dtype,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .map_err(|e| ManifestError::Io(mpath.clone(), e))?;
        let j = Json::parse(&text).map_err(|e| parse_err(e.to_string()))?;

        let m = j.get("model").ok_or_else(|| parse_err("missing 'model'"))?;
        let model = ModelSpec {
            layers: req_usize(m, "layers")?,
            hidden: req_usize(m, "hidden")?,
            heads: req_usize(m, "heads")?,
            kv_heads: req_usize(m, "kv_heads")?,
            head_dim: req_usize(m, "head_dim")?,
            ffn: req_usize(m, "ffn")?,
            experts: req_usize(m, "experts")?,
            top_k: req_usize(m, "top_k")?,
            vocab: req_usize(m, "vocab")?,
            max_seq: req_usize(m, "max_seq")?,
        };

        let b = j.get("buckets").ok_or_else(|| parse_err("missing 'buckets'"))?;
        let buckets = Buckets {
            prefill_t: usize_list(b, "prefill_t")?,
            decode_b: usize_list(b, "decode_b")?,
            expert_b: usize_list(b, "expert_b")?,
            router_b: usize_list(b, "router_b")?,
            lm_head_b: usize_list(b, "lm_head_b")?,
        };

        let arts = j
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| parse_err("missing 'artifacts'"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let kind_s = req_str(a, "kind")?;
            let kind = ArtifactKind::parse(kind_s)
                .ok_or_else(|| parse_err(format!("unknown artifact kind '{kind_s}'")))?;
            let inputs = a
                .get("inputs")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| parse_err("artifact missing inputs"))?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>, _>>()?;
            let outputs = a
                .get("outputs")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| parse_err("artifact missing outputs"))?
                .iter()
                .map(parse_io)
                .collect::<Result<Vec<_>, _>>()?;
            artifacts.push(ArtifactSpec {
                name: req_str(a, "name")?.to_string(),
                kind,
                bucket: req_usize(a, "bucket")?,
                file: req_str(a, "file")?.to_string(),
                inputs,
                outputs,
            });
        }

        let w = j.get("weights").ok_or_else(|| parse_err("missing 'weights'"))?;
        let weight_file = req_str(w, "file")?.to_string();
        let tensors = w
            .get("tensors")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| parse_err("missing weight tensors"))?;
        let mut weight_entries = Vec::with_capacity(tensors.len());
        for t in tensors {
            let shape = usize_list(t, "shape")?;
            let nbytes = req_usize(t, "nbytes")?;
            weight_entries.push(WeightEntry {
                name: req_str(t, "name")?.to_string(),
                len_elems: nbytes / 4,
                offset_elems: req_usize(t, "offset")? / 4,
                shape,
            });
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            buckets,
            artifacts,
            weight_file,
            weight_entries,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts of a kind, sorted by bucket ascending.
    pub fn artifacts_of(&self, kind: ArtifactKind) -> Vec<&ArtifactSpec> {
        let mut v: Vec<&ArtifactSpec> =
            self.artifacts.iter().filter(|a| a.kind == kind).collect();
        v.sort_by_key(|a| a.bucket);
        v
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Default artifacts directory: $TARRAGON_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("TARRAGON_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> Option<PathBuf> {
        let d = Manifest::default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn segment_and_traffic_math() {
        let m = ModelSpec {
            layers: 32,
            hidden: 4096,
            heads: 32,
            kv_heads: 8,
            head_dim: 128,
            ffn: 14336,
            experts: 8,
            top_k: 2,
            vocab: 32000,
            max_seq: 4096,
        };
        // Mixtral-8x7B: C = 2*8*128*4 = 8 KiB; V = 2*2*4096*4 = 64 KiB
        assert_eq!(m.kv_segment_bytes(), 8192);
        assert_eq!(m.expert_traffic_bytes(), 65536);
        // Appendix C: checkpoint traffic is 12.5% of expert traffic.
        assert!((m.kv_segment_bytes() as f64 / m.expert_traffic_bytes() as f64
            - 0.125)
            .abs()
            < 1e-9);
    }

    #[test]
    fn bucket_fitting() {
        let b = vec![1, 2, 4, 8];
        assert_eq!(Buckets::fit(&b, 1), Some(1));
        assert_eq!(Buckets::fit(&b, 3), Some(4));
        assert_eq!(Buckets::fit(&b, 8), Some(8));
        assert_eq!(Buckets::fit(&b, 9), None);
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let Some(dir) = manifest_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model.layers >= 1);
        assert_eq!(m.model.heads % m.model.kv_heads, 0);
        // Our scaled config preserves the 12.5% ckpt/expert traffic ratio.
        assert!((m.model.kv_segment_bytes() as f64
            / m.model.expert_traffic_bytes() as f64
            - 0.125)
            .abs()
            < 1e-9);
        // Every kind appears with at least one bucket and files exist.
        for kind in [
            ArtifactKind::AttnPrefill,
            ArtifactKind::AttnDecode,
            ArtifactKind::Router,
            ArtifactKind::Expert,
            ArtifactKind::LmHead,
        ] {
            let arts = m.artifacts_of(kind);
            assert!(!arts.is_empty(), "no artifacts of kind {kind:?}");
            for a in arts {
                assert!(m.hlo_path(a).exists(), "missing {}", a.file);
            }
        }
        // Weight table covers embed + per-layer + head tensors.
        assert!(m.weight_entries.iter().any(|w| w.name == "embed"));
        assert!(m.weight_entries.iter().any(|w| w.name == "lm_head"));
        assert!(m
            .weight_entries
            .iter()
            .any(|w| w.name == format!("layer{}.expert0.w1", m.model.layers - 1)));
    }
}
