//! Weight blob loading. One shared, immutable, reference-counted copy of
//! `weights.bin` per process; each worker device uploads the tensors it
//! needs to its own PJRT client at init (the upload is part of T_w, the
//! blob read is amortized).

use super::{Manifest, ManifestError, WeightEntry};
use std::collections::HashMap;
use std::sync::Arc;

#[derive(Clone)]
pub struct Weights {
    blob: Arc<Vec<f32>>,
    index: Arc<HashMap<String, WeightEntry>>,
}

impl Weights {
    pub fn load(manifest: &Manifest) -> Result<Weights, ManifestError> {
        let path = manifest.dir.join(&manifest.weight_file);
        let bytes = std::fs::read(&path).map_err(|e| ManifestError::Io(path.clone(), e))?;
        if bytes.len() % 4 != 0 {
            return Err(ManifestError::Parse(format!(
                "weight blob size {} not a multiple of 4",
                bytes.len()
            )));
        }
        let mut blob = vec![0f32; bytes.len() / 4];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            blob[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        let mut index = HashMap::with_capacity(manifest.weight_entries.len());
        for e in &manifest.weight_entries {
            if e.offset_elems + e.len_elems > blob.len() {
                return Err(ManifestError::Parse(format!(
                    "weight '{}' overruns blob",
                    e.name
                )));
            }
            index.insert(e.name.clone(), e.clone());
        }
        Ok(Weights { blob: Arc::new(blob), index: Arc::new(index) })
    }

    /// Borrow a named tensor's elements (row-major).
    pub fn get(&self, name: &str) -> Option<(&[f32], &[usize])> {
        let e = self.index.get(name)?;
        Some((
            &self.blob[e.offset_elems..e.offset_elems + e.len_elems],
            e.shape.as_slice(),
        ))
    }

    /// Like `get` but panics with the tensor name — init-time only.
    pub fn expect(&self, name: &str) -> (&[f32], &[usize]) {
        self.get(name)
            .unwrap_or_else(|| panic!("weight tensor '{name}' missing from manifest"))
    }

    /// Embedding row for a token id (init-checked: embed exists).
    pub fn embed_row(&self, token: usize) -> &[f32] {
        let (data, shape) = self.expect("embed");
        let h = shape[1];
        &data[token * h..(token + 1) * h]
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.index.keys()
    }

    pub fn total_elems(&self) -> usize {
        self.blob.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelcfg::Manifest;

    #[test]
    fn loads_blob_and_indexes() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let w = Weights::load(&m).unwrap();
        let (embed, shape) = w.expect("embed");
        assert_eq!(shape, &[m.model.vocab, m.model.hidden]);
        assert_eq!(embed.len(), m.model.vocab * m.model.hidden);
        // ln weights are initialized to exactly 1.0 by the generator.
        let (ln, _) = w.expect("layer0.ln1");
        assert!(ln.iter().all(|&x| x == 1.0));
        // embed_row slices the right stride.
        let row5 = w.embed_row(5);
        assert_eq!(row5, &embed[5 * m.model.hidden..6 * m.model.hidden]);
        // total bytes match the manifest.
        let expected: usize = m.weight_entries.iter().map(|e| e.len_elems).sum();
        assert_eq!(w.total_elems(), expected);
    }
}
