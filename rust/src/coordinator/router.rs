//! Gate selection: turn the router artifact's softmax output into per-row
//! top-k expert assignments (renormalized, Mixtral convention), then group
//! rows by expert for dispatch. Top-k selection is control flow, so it
//! lives in the coordinator rather than in an artifact; ties break to the
//! lowest expert id, matching `jax.lax.top_k` in the L2 oracle.

use crate::tensor::{ops, Tensor};
use std::collections::BTreeMap;

/// One row's routing decision.
#[derive(Debug, Clone, PartialEq)]
pub struct RowRoute {
    /// (expert, renormalized gate weight), len top_k, descending weight.
    pub gates: Vec<(usize, f32)>,
}

/// Route every row of `probs` ([B, E], only first `rows` valid).
pub fn select_top_k(probs: &Tensor, rows: usize, top_k: usize) -> Vec<RowRoute> {
    select_top_k_hotspot(probs, rows, top_k, None)
}

/// Top-k routing with an optional hotspot skew (DESIGN.md §11): when
/// `hotspot` names an expert, every row routes to it — if it missed the
/// natural top-k, it replaces the lowest-probability pick (at its own
/// router probability) before renormalization. Deterministic, so the
/// skew is a workload property: the same prompts produce the same
/// streams under any fault/scaling schedule.
pub fn select_top_k_hotspot(
    probs: &Tensor,
    rows: usize,
    top_k: usize,
    hotspot: Option<usize>,
) -> Vec<RowRoute> {
    let e = probs.row_len();
    assert!(top_k <= e);
    (0..rows)
        .map(|i| {
            let mut gates = ops::top_k(probs.row(i), top_k);
            if let Some(hk) = hotspot {
                if hk < e && !gates.is_empty() && !gates.iter().any(|&(x, _)| x == hk) {
                    let last = gates.len() - 1;
                    gates[last] = (hk, probs.row(i)[hk]);
                    gates.sort_by(|a, b| {
                        b.1.partial_cmp(&a.1)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.0.cmp(&b.0))
                    });
                }
            }
            ops::renormalize(&mut gates);
            RowRoute { gates }
        })
        .collect()
}

/// Rows grouped by expert: expert -> (row indices, gate weights).
#[derive(Debug, Default, Clone)]
pub struct ExpertGroups {
    pub groups: BTreeMap<usize, Vec<(usize, f32)>>,
}

impl ExpertGroups {
    pub fn from_routes(routes: &[RowRoute]) -> ExpertGroups {
        let mut groups: BTreeMap<usize, Vec<(usize, f32)>> = BTreeMap::new();
        for (row, r) in routes.iter().enumerate() {
            for &(expert, w) in &r.gates {
                groups.entry(expert).or_default().push((row, w));
            }
        }
        ExpertGroups { groups }
    }

    pub fn num_assignments(&self) -> usize {
        self.groups.values().map(|v| v.len()).sum()
    }

    /// Per-expert batch sizes — the Fig. 13(a) distribution.
    pub fn batch_sizes(&self) -> Vec<(usize, usize)> {
        self.groups.iter().map(|(e, v)| (*e, v.len())).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probs(rows: Vec<Vec<f32>>) -> Tensor {
        let b = rows.len();
        let e = rows[0].len();
        Tensor::new(vec![b, e], rows.into_iter().flatten().collect())
    }

    #[test]
    fn top2_selection_and_renormalization() {
        let p = probs(vec![vec![0.5, 0.3, 0.1, 0.1]]);
        let routes = select_top_k(&p, 1, 2);
        assert_eq!(routes[0].gates[0].0, 0);
        assert_eq!(routes[0].gates[1].0, 1);
        let w0 = routes[0].gates[0].1;
        let w1 = routes[0].gates[1].1;
        assert!((w0 + w1 - 1.0).abs() < 1e-6);
        assert!((w0 - 0.625).abs() < 1e-6); // 0.5 / 0.8
    }

    #[test]
    fn padded_rows_are_ignored() {
        let p = probs(vec![vec![0.9, 0.1], vec![0.1, 0.9]]);
        let routes = select_top_k(&p, 1, 1);
        assert_eq!(routes.len(), 1);
    }

    #[test]
    fn grouping_collects_rows_per_expert() {
        let p = probs(vec![
            vec![0.6, 0.3, 0.05, 0.05], // -> e0, e1
            vec![0.1, 0.6, 0.25, 0.05], // -> e1, e2
            vec![0.5, 0.05, 0.05, 0.4], // -> e0, e3
        ]);
        let routes = select_top_k(&p, 3, 2);
        let g = ExpertGroups::from_routes(&routes);
        assert_eq!(g.num_assignments(), 6);
        assert_eq!(g.groups[&0].iter().map(|(r, _)| *r).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(g.groups[&1].len(), 2);
        assert_eq!(g.groups[&3].len(), 1);
        assert_eq!(g.batch_sizes(), vec![(0, 2), (1, 2), (2, 1), (3, 1)]);
    }

    #[test]
    fn hotspot_skew_routes_every_row_to_the_expert() {
        let p = probs(vec![
            vec![0.6, 0.3, 0.05, 0.05], // natural: e0, e1
            vec![0.1, 0.6, 0.25, 0.05], // natural: e1, e2
        ]);
        let routes = select_top_k_hotspot(&p, 2, 2, Some(3));
        for r in &routes {
            assert!(r.gates.iter().any(|&(e, _)| e == 3), "hotspot missing: {r:?}");
            assert_eq!(r.gates.len(), 2);
            let sum: f32 = r.gates.iter().map(|(_, w)| w).sum();
            assert!((sum - 1.0).abs() < 1e-5);
            // Descending weights preserved after the swap.
            assert!(r.gates.windows(2).all(|w| w[0].1 >= w[1].1));
        }
        // Row 0 keeps its top pick; the weaker e1 was displaced.
        assert_eq!(routes[0].gates[0].0, 0);
        // Already-selected hotspot rows are untouched.
        let natural = select_top_k(&p, 2, 2);
        let skewed = select_top_k_hotspot(&p, 2, 2, Some(1));
        assert_eq!(natural[0].gates.len(), skewed[0].gates.len());
        assert_eq!(natural[1], skewed[1], "row already routing to e1 must not change");
        // Out-of-range hotspot is ignored.
        assert_eq!(select_top_k_hotspot(&p, 2, 2, Some(99)), natural);
    }

    #[test]
    fn ties_break_to_lowest_expert() {
        let p = probs(vec![vec![0.25, 0.25, 0.25, 0.25]]);
        let routes = select_top_k(&p, 1, 2);
        assert_eq!(routes[0].gates[0].0, 0);
        assert_eq!(routes[0].gates[1].0, 1);
    }
}
