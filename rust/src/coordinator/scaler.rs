//! Elastic expert-worker scaling policy (DESIGN.md §11).
//!
//! The EWs accumulate per-expert activation counters (tokens routed per
//! expert per `[scaler]` window) and beacon them to the orchestrator as
//! [`EwStatus`](crate::proto::ClusterMsg::EwStatus) — the expert-tier
//! sibling of the AW load beacon. This module is the pure *policy* side
//! consuming those beacons:
//!
//! - a **hot** expert (window tokens at/above `hot_threshold`) scales
//!   out: its least-loaded live shadow replica is promoted to primary
//!   (warm — the weights are already resident, so nothing is uploaded on
//!   the critical path), or a fresh EW is provisioned when no alternate
//!   candidate exists;
//! - a **cold** EW (window tokens strictly below `cold_threshold`)
//!   scales in: its primaries are remapped onto the remaining candidates
//!   and the EW is retired — rejected up front if it is the last replica
//!   of any expert, so tokens can never be stranded;
//! - `cooldown` spaces actions out (flap damping), and an all-idle
//!   cluster never scales in (there is nothing to learn from silence).
//!
//! The *mechanism* lives with its owners: the orchestrator edits the ERT
//! through [`promote`]/[`retire`] (version bump + broadcast), the EW
//! serves straddling dispatches routed under pre-retirement versions and
//! answers newer ones with `Stale`, and the REFE re-resolves stale slots
//! once its table catches up. Everything here is deterministic: ordered
//! maps, ascending iteration, ties toward the lowest id.

use crate::config::ScalerConfig;
use crate::proto::ErtTable;
use std::collections::BTreeMap;
use std::time::Duration;

/// One scaling decision, executed by the orchestrator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScalePlan {
    /// Reorder `expert`'s candidate list so `to` (a live shadow) leads.
    PromoteShadow { expert: usize, to: u32 },
    /// No alternate replica exists: provision a fresh EW for `expert`.
    ProvisionFresh { expert: usize },
    /// Remap `ew`'s primaries onto the remaining candidates, retire it.
    Retire { ew: u32 },
}

/// Move `ew` to the front of `expert`'s candidate list. Returns false if
/// `ew` is not a candidate or already primary (nothing to do).
pub fn promote(table: &mut ErtTable, expert: usize, ew: u32) -> bool {
    let Some(cands) = table.get_mut(expert) else { return false };
    if cands.first() == Some(&ew) || !cands.contains(&ew) {
        return false;
    }
    cands.retain(|&c| c != ew);
    cands.insert(0, ew);
    true
}

/// Would removing `ew` leave some expert with no candidate at all? The
/// last-replica guard shared by [`retire`] and the planner's cold-EW
/// screening (which must not pay a table clone per candidate).
pub fn retire_strands(table: &ErtTable, ew: u32) -> bool {
    table.iter().any(|c| !c.is_empty() && c.iter().all(|&x| x == ew))
}

/// Remove `ew` from every candidate list. Refuses (table untouched) if
/// that would leave any expert with no candidate — the last-replica
/// guard: a retirement can demote, never strand.
pub fn retire(table: &mut ErtTable, ew: u32) -> bool {
    if retire_strands(table, ew) {
        return false;
    }
    for cands in table.iter_mut() {
        cands.retain(|&c| c != ew);
    }
    true
}

/// The utilization-driven scaling policy.
pub struct Scaler {
    cfg: ScalerConfig,
    /// Latest window's per-expert counts, per reporting EW.
    counts: BTreeMap<u32, BTreeMap<u16, u64>>,
    last_action: Option<Duration>,
    /// expert -> (the EW it was last promoted *off*, when). A still-hot
    /// expert must not be promoted straight back where it just came
    /// from — that is the A<->B flip-flop, which moves load in a circle
    /// while bumping the ERT version every cooldown. The entry expires
    /// after a few cooldowns so a *persistently* lopsided expert (e.g. a
    /// two-replica ring) can still rebalance, just at a bounded cadence.
    last_moved_from: BTreeMap<usize, (u32, Duration)>,
    /// expert -> when a fresh-EW provision was issued for it: spawning +
    /// integration outlast a cooldown, so without this a hot expert
    /// would be re-provisioned every period until the first EW lands.
    /// Cleared once the expert shows an alternate candidate (the fresh
    /// EW integrated into the table); expires after a few cooldowns so a
    /// failed spawn — or a fresh EW that integrated and then died — does
    /// not block provisioning for that expert forever.
    pending_fresh: BTreeMap<usize, Duration>,
}

impl Scaler {
    pub fn new(cfg: ScalerConfig) -> Scaler {
        Scaler {
            cfg,
            counts: BTreeMap::new(),
            last_action: None,
            last_moved_from: BTreeMap::new(),
            pending_fresh: BTreeMap::new(),
        }
    }

    /// Record an EW's window beacon (replaces its previous window).
    pub fn ingest(&mut self, ew: u32, tokens: Vec<(u16, u64)>) {
        self.counts.insert(ew, tokens.into_iter().collect());
    }

    /// Drop a departed EW's counts (failure or retirement).
    pub fn forget(&mut self, ew: u32) {
        self.counts.remove(&ew);
    }

    /// Evaluate the latest windows against the current ERT and live EW
    /// set. Issuing a plan starts the cooldown and clears the windows —
    /// deliberately even if the orchestrator then rejects the plan
    /// (e.g. its fabric-liveness cross-checks fire during a failure
    /// window): the cooldown doubles as reject backoff, one retry per
    /// period instead of one per beacon, until cluster state converges.
    pub fn plan(&mut self, now: Duration, table: &ErtTable, live: &[u32]) -> Option<ScalePlan> {
        if let Some(t) = self.last_action {
            if now.saturating_sub(t) < self.cfg.cooldown {
                return None;
            }
        }
        // Per-expert and per-EW totals over the live reporters.
        let mut expert_totals: BTreeMap<u16, u64> = BTreeMap::new();
        let mut ew_totals: BTreeMap<u32, u64> = BTreeMap::new();
        for (&ew, window) in &self.counts {
            if !live.contains(&ew) {
                continue;
            }
            let mut total = 0u64;
            for (&e, &n) in window {
                *expert_totals.entry(e).or_insert(0) += n;
                total += n;
            }
            ew_totals.insert(ew, total);
        }

        // Hot expert: highest window total at/above the threshold
        // (ties break toward the lowest expert id).
        let hot = expert_totals
            .iter()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(a.0)))
            .map(|(&e, &n)| (e as usize, n));
        // Both memories expire on the same patience horizon: long enough
        // to outlast a spawn + integration, short enough that a failed
        // spawn or a genuinely persistent imbalance unblocks again.
        let patience = self.cfg.cooldown * 4;
        if let Some((expert, n)) = hot {
            if n >= self.cfg.hot_threshold {
                if let Some(cands) = table.get(expert) {
                    let has_live_alternate =
                        cands.iter().skip(1).any(|c| live.contains(c));
                    if has_live_alternate {
                        // A *live* alternate is visible: any in-flight
                        // fresh provision for this expert has integrated
                        // (mere table membership of a dead shadow does
                        // not count — that is exactly the lagging-table
                        // window the latch exists for).
                        self.pending_fresh.remove(&expert);
                    }
                    // Least-loaded live alternate candidate (ties: lowest
                    // id); its weights are already resident — promotion
                    // is a pure table edit. The EW this expert was just
                    // promoted off is excluded while the damping window
                    // lasts (flip-flop damping); afterwards it becomes a
                    // candidate again so a persistent imbalance can still
                    // rebalance, at a bounded cadence.
                    let moved_from = self
                        .last_moved_from
                        .get(&expert)
                        .and_then(|&(ew, t0)| {
                            (now.saturating_sub(t0) < patience).then_some(ew)
                        });
                    let alt = cands
                        .iter()
                        .skip(1)
                        .filter(|&&c| live.contains(&c) && Some(c) != moved_from)
                        .min_by_key(|&&c| (ew_totals.get(&c).copied().unwrap_or(0), c))
                        .copied();
                    if let Some(to) = alt {
                        if let Some(&primary) = cands.first() {
                            self.last_moved_from.insert(expert, (primary, now));
                        }
                        self.last_action = Some(now);
                        self.counts.clear();
                        return Some(ScalePlan::PromoteShadow { expert, to });
                    }
                    let latched = self
                        .pending_fresh
                        .get(&expert)
                        .is_some_and(|&t0| now.saturating_sub(t0) < patience);
                    if !has_live_alternate && !latched {
                        self.pending_fresh.insert(expert, now);
                        self.last_action = Some(now);
                        self.counts.clear();
                        return Some(ScalePlan::ProvisionFresh { expert });
                    }
                    // Alternates exist but are all damped, or a fresh EW
                    // is already on its way: hold position.
                }
            }
        }

        // Cold EWs: window totals strictly below the threshold, coldest
        // first — the first one whose retirement keeps every expert
        // covered wins, so a last-replica-guarded coldest EW cannot
        // head-of-line-block shedding the others. An all-idle cluster is
        // not "cold" — silence carries no load signal.
        let grand: u64 = ew_totals.values().sum();
        if self.cfg.cold_threshold > 0 && grand > 0 && live.len() > 1 {
            let mut cold: Vec<(u64, u32)> = ew_totals
                .iter()
                .filter(|kv| *kv.1 < self.cfg.cold_threshold)
                .map(|kv| (*kv.1, *kv.0))
                .collect();
            cold.sort_unstable();
            for (_, ew) in cold {
                if !retire_strands(table, ew) {
                    self.last_action = Some(now);
                    self.counts.clear();
                    return Some(ScalePlan::Retire { ew });
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ScalerConfig {
        ScalerConfig {
            enabled: true,
            window: Duration::from_millis(10),
            hot_threshold: 10,
            cold_threshold: 2,
            cooldown: Duration::from_millis(100),
            retire_linger: Duration::from_millis(20),
        }
    }

    /// 4 experts over 2 EWs, ring shadows (the small_test layout).
    fn table2() -> ErtTable {
        vec![vec![0, 1], vec![1, 0], vec![0, 1], vec![1, 0]]
    }

    #[test]
    fn promote_reorders_and_rejects_non_candidates() {
        let mut t = table2();
        assert!(promote(&mut t, 1, 0));
        assert_eq!(t[1], vec![0, 1]);
        assert!(!promote(&mut t, 1, 0), "already primary");
        assert!(!promote(&mut t, 1, 7), "not a candidate");
        assert!(!promote(&mut t, 99, 0), "unknown expert");
    }

    #[test]
    fn retire_remaps_or_refuses_last_replica() {
        let mut t = table2();
        assert!(retire(&mut t, 0));
        assert_eq!(t, vec![vec![1], vec![1], vec![1], vec![1]]);
        // Now EW1 is the last replica everywhere: retirement must refuse
        // and leave the table untouched.
        let before = t.clone();
        assert!(!retire(&mut t, 1));
        assert_eq!(t, before);
    }

    #[test]
    fn hot_expert_promotes_least_loaded_live_shadow() {
        let mut s = Scaler::new(cfg());
        s.ingest(0, vec![(0, 3), (2, 2)]);
        s.ingest(1, vec![(1, 12), (3, 1)]);
        let plan = s.plan(Duration::from_millis(10), &table2(), &[0, 1]);
        assert_eq!(plan, Some(ScalePlan::PromoteShadow { expert: 1, to: 0 }));
        // Cooldown gates the next action.
        s.ingest(1, vec![(1, 50)]);
        assert_eq!(s.plan(Duration::from_millis(20), &table2(), &[0, 1]), None);
        // ...and expires.
        s.ingest(1, vec![(1, 50)]);
        assert!(s.plan(Duration::from_millis(200), &table2(), &[0, 1]).is_some());
    }

    #[test]
    fn hot_expert_without_live_alternate_provisions_fresh_once() {
        let mut s = Scaler::new(cfg());
        s.ingest(1, vec![(1, 12)]);
        // Only EW1 is live: expert 1's shadow (EW0) is down.
        let plan = s.plan(Duration::from_millis(10), &table2(), &[1]);
        assert_eq!(plan, Some(ScalePlan::ProvisionFresh { expert: 1 }));
        // Still hot past the cooldown, fresh EW still spawning: no
        // duplicate provision.
        s.ingest(1, vec![(1, 12)]);
        assert_eq!(s.plan(Duration::from_millis(200), &table2(), &[1]), None);
        // The latch expires (failed spawn / fresh EW died) after a few
        // cooldowns: provisioning unblocks rather than sticking forever.
        s.ingest(1, vec![(1, 12)]);
        let plan = s.plan(Duration::from_millis(1500), &table2(), &[1]);
        assert_eq!(plan, Some(ScalePlan::ProvisionFresh { expert: 1 }));
        // The fresh EW integrated (an alternate is visible again): the
        // pending latch clears and promotion takes over.
        let integrated: ErtTable = vec![vec![0, 1], vec![2, 1], vec![0, 1], vec![1, 0]];
        s.ingest(1, vec![(1, 12)]);
        let plan = s.plan(Duration::from_millis(1700), &integrated, &[1, 2]);
        assert_eq!(plan, Some(ScalePlan::PromoteShadow { expert: 1, to: 1 }));
    }

    #[test]
    fn promotion_never_flips_straight_back() {
        let mut s = Scaler::new(cfg());
        s.ingest(0, vec![(0, 2)]);
        s.ingest(1, vec![(1, 12)]);
        let mut table = table2();
        let plan = s.plan(Duration::from_millis(10), &table, &[0, 1]);
        assert_eq!(plan, Some(ScalePlan::PromoteShadow { expert: 1, to: 0 }));
        assert!(promote(&mut table, 1, 0));
        // Expert 1 stays hot on its new primary (EW0): promoting it
        // straight back to EW1 would be the flip-flop — hold position.
        // (EW1 keeps enough traffic to stay above the cold threshold.)
        s.ingest(0, vec![(1, 12)]);
        s.ingest(1, vec![(3, 5)]);
        assert_eq!(s.plan(Duration::from_millis(200), &table, &[0, 1]), None);
    }

    #[test]
    fn cold_ew_retires_but_idle_cluster_does_not() {
        let mut s = Scaler::new(cfg());
        // All idle: no scale-in from silence.
        s.ingest(0, vec![]);
        s.ingest(1, vec![]);
        assert_eq!(s.plan(Duration::from_millis(10), &table2(), &[0, 1]), None);
        // EW0 busy, EW1 cold: retire EW1.
        s.ingest(0, vec![(0, 5), (2, 4)]);
        s.ingest(1, vec![(1, 1)]);
        let plan = s.plan(Duration::from_millis(20), &table2(), &[0, 1]);
        assert_eq!(plan, Some(ScalePlan::Retire { ew: 1 }));
    }

    #[test]
    fn cold_retire_respects_last_replica_guard() {
        let mut s = Scaler::new(cfg());
        // Single-candidate table (no shadows): EW1 cold but irreplaceable.
        let t: ErtTable = vec![vec![0], vec![1]];
        s.ingest(0, vec![(0, 5)]);
        s.ingest(1, vec![(1, 1)]);
        assert_eq!(s.plan(Duration::from_millis(10), &t, &[0, 1]), None);
    }

    #[test]
    fn guarded_coldest_ew_does_not_block_other_cold_retirements() {
        let mut s = Scaler::new(cfg());
        // EW2 is the coldest but the sole replica of expert 2; EW1 is
        // also cold and fully covered. Shedding must skip past EW2.
        let t: ErtTable = vec![vec![0, 1], vec![1, 0], vec![2]];
        s.ingest(0, vec![(0, 6)]);
        s.ingest(1, vec![(1, 1)]);
        s.ingest(2, vec![(2, 0)]);
        let plan = s.plan(Duration::from_millis(10), &t, &[0, 1, 2]);
        assert_eq!(plan, Some(ScalePlan::Retire { ew: 1 }));
    }

    #[test]
    fn dead_reporters_are_excluded() {
        let mut s = Scaler::new(cfg());
        s.ingest(0, vec![(0, 50)]);
        s.forget(0);
        assert_eq!(s.plan(Duration::from_millis(10), &table2(), &[0, 1]), None);
        // Live filter also excludes stale counts from departed EWs.
        s.ingest(7, vec![(0, 50)]);
        assert_eq!(s.plan(Duration::from_millis(20), &table2(), &[0, 1]), None);
    }
}
