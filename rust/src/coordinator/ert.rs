//! Expert Routing Table (§4.2): logical expert id -> ordered candidate EW
//! list (primary first, then shadows). Each AW holds its own versioned
//! copy, updated by the orchestrator; lookups additionally filter through
//! the AW's *local* dead-set so self-healing can reroute before the
//! orchestrator's update arrives (§5.1).

use crate::proto::ErtTable;
use std::collections::HashSet;

#[derive(Debug, Clone)]
pub struct Ert {
    version: u64,
    table: ErtTable,
    /// EWs this holder has locally observed as failed (probe-confirmed);
    /// cleared when an orchestrator update supersedes local knowledge.
    local_dead: HashSet<u32>,
}

impl Ert {
    pub fn new(version: u64, table: ErtTable) -> Ert {
        Ert { version, table, local_dead: HashSet::new() }
    }

    /// The canonical initial layout: experts spread round-robin over EWs,
    /// each expert's shadow on the next EW in the ring (§5.3).
    pub fn initial(num_experts: usize, num_ews: usize, with_shadows: bool) -> Ert {
        let mut table: ErtTable = Vec::with_capacity(num_experts);
        for e in 0..num_experts {
            let primary = (e % num_ews) as u32;
            let mut cands = vec![primary];
            if with_shadows && num_ews > 1 {
                cands.push(((e + 1) % num_ews) as u32);
            }
            table.push(cands);
        }
        Ert::new(1, table)
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn table(&self) -> &ErtTable {
        &self.table
    }

    pub fn num_experts(&self) -> usize {
        self.table.len()
    }

    /// Resolve an expert to the best live candidate.
    pub fn resolve(&self, expert: usize) -> Option<u32> {
        self.table
            .get(expert)?
            .iter()
            .copied()
            .find(|ew| !self.local_dead.contains(ew))
    }

    /// All candidates of an expert (for diagnostics/tests).
    pub fn candidates(&self, expert: usize) -> &[u32] {
        &self.table[expert]
    }

    /// Experts whose primary is the given EW.
    pub fn primaries_of(&self, ew: u32) -> Vec<usize> {
        self.table
            .iter()
            .enumerate()
            .filter(|(_, c)| c.first() == Some(&ew))
            .map(|(e, _)| e)
            .collect()
    }

    /// Mark an EW dead locally (probe-confirmed failure); subsequent
    /// resolves skip it immediately — the "localized remapping" of §4.2.
    pub fn mark_dead(&mut self, ew: u32) {
        self.local_dead.insert(ew);
    }

    pub fn is_dead(&self, ew: u32) -> bool {
        self.local_dead.contains(&ew)
    }

    /// Apply an orchestrator update (monotonic in version). Local dead-set
    /// is cleared: the orchestrator's table already reflects the failure
    /// (and possibly a replacement EW reusing the index).
    ///
    /// Scaling updates (DESIGN.md §11) can broadcast a table that still
    /// lists an EW this holder probe-confirmed dead moments ago (the
    /// failure report is still in flight). Clearing is deliberate even
    /// then: the mark cannot distinguish "orchestrator doesn't know yet"
    /// from "the EW was respawned on its slot", and keeping it would
    /// permanently blind this AW to a recovered worker. Re-resolving to
    /// a still-dead EW just re-pays one silence-window probe before the
    /// local mark returns — bounded latency, never wrong output.
    pub fn apply(&mut self, version: u64, table: ErtTable) -> bool {
        if version <= self.version {
            return false;
        }
        self.version = version;
        self.table = table;
        self.local_dead.clear();
        true
    }

    /// Every EW referenced by the table (the datapath peers an AW needs).
    pub fn all_ews(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.table.iter().flatten().copied().collect();
        v.sort();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_layout_round_robin_with_ring_shadows() {
        let ert = Ert::initial(8, 4, true);
        assert_eq!(ert.resolve(0), Some(0));
        assert_eq!(ert.resolve(5), Some(1));
        assert_eq!(ert.candidates(3), &[3, 0]);
        assert_eq!(ert.primaries_of(2), vec![2, 6]);
        assert_eq!(ert.all_ews(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn no_shadows_means_single_candidate() {
        let ert = Ert::initial(8, 4, false);
        assert_eq!(ert.candidates(0).len(), 1);
    }

    #[test]
    fn local_dead_reroutes_to_shadow() {
        let mut ert = Ert::initial(8, 4, true);
        ert.mark_dead(1);
        assert_eq!(ert.resolve(1), Some(2)); // expert 1: primary ew1 -> shadow ew2
        assert_eq!(ert.resolve(5), Some(2));
        assert_eq!(ert.resolve(0), Some(0)); // unaffected
        // Both candidates dead -> unroutable
        ert.mark_dead(2);
        assert_eq!(ert.resolve(1), None);
    }

    #[test]
    fn apply_is_monotonic_and_clears_local_dead() {
        let mut ert = Ert::initial(4, 2, true);
        ert.mark_dead(0);
        assert!(ert.is_dead(0));
        // Stale update rejected
        assert!(!ert.apply(1, vec![vec![1]; 4]));
        assert!(ert.is_dead(0));
        // Fresh update applies and clears
        assert!(ert.apply(2, vec![vec![1], vec![1], vec![0], vec![0]]));
        assert_eq!(ert.version(), 2);
        assert!(!ert.is_dead(0));
        assert_eq!(ert.resolve(0), Some(1));
        assert_eq!(ert.resolve(2), Some(0));
    }
}
