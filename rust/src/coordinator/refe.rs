//! REFE — the Reconfigurable Forwarding Engine (§4.2).
//!
//! AW-side runtime that mediates all AW-EW communication:
//! `expert_io(layer, rows, routes)` scatters token rows to the EWs
//! currently bound to their experts (via the local ERT copy), gathers the
//! outputs, and transparently self-heals around EW failures (§5.1):
//! a response gap beyond the silence window triggers a control-plane
//! probe; a probe-confirmed-dead EW is marked in the local ERT, the
//! orchestrator is notified, and the affected rows are *replayed* as
//! urgent dispatches to the next candidate (healthy primary or shadow).
//!
//! Dispatches are sent to every known EW each layer — zero-row dispatches
//! carry the implicit heartbeat + layer-sync signal the paper describes.

use super::ert::Ert;
use super::router::ExpertGroups;
use crate::config::ResilienceConfig;
use crate::metrics::trace::{SpanKind, TraceHandle};
use crate::metrics::{EventKind, EventLog};
use crate::proto::{ClusterMsg, DispatchEntry, DispatchMsg, ErtTable, HDR_BYTES};
use crate::tensor::{ops, Tensor};
use crate::transport::{link::TrafficClass, Envelope, Fabric, Inbox, NodeId, Plane, Qp, QpError};
use crate::util::clock::Clock;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug)]
pub enum RefeError {
    /// No live candidate EW for an expert: with a static ERT this is the
    /// global stall (baseline); with dynamic ERT it means primary+shadows
    /// all died before reprovisioning.
    Unroutable { expert: usize },
    /// The collective wait exceeded the CCL abort budget (baselines).
    CclAbort(Duration),
    /// The local node died (fail-stop of this AW).
    LocalDown,
}

impl std::fmt::Display for RefeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RefeError::Unroutable { expert } => {
                write!(f, "expert {expert} unroutable (candidates exhausted)")
            }
            RefeError::CclAbort(d) => write!(f, "communicator timeout after {d:?}"),
            RefeError::LocalDown => write!(f, "local node down"),
        }
    }
}

impl std::error::Error for RefeError {}

/// Reusable per-REFE gather state: cleared at each `expert_io`, never
/// reallocated across layers/steps (capacities are retained), so the
/// steady-state dispatch path does not touch the allocator. The old code
/// rebuilt every one of these per layer — and `slot_out` additionally
/// copied each returned row.
#[derive(Default)]
struct IoScratch {
    /// slot -> (row index, gate weight); slots are per-call dense ids.
    slot_info: Vec<(usize, f32)>,
    /// slot -> (expert, first-dispatch EW), retained for failure replay.
    entry_of_slot: Vec<(usize, u32)>,
    done: Vec<bool>,
    /// slot -> returned expert-output row: a view into the EW's output
    /// tensor (zero copy), applied in slot order after the gather.
    slot_out: Vec<Option<Tensor>>,
    /// Recycled u32 vectors backing the outstanding-slots bookkeeping.
    u32_pool: Vec<Vec<u32>>,
}

/// Take a recycled vector with at least `want` capacity. A pool
/// underflow used to hand out `Vec::default()` — zero capacity, so the
/// caller's first `extend` broke the zero-alloc decode contract with a
/// silent realloc-and-grow. Now the miss allocates *once*, sized from
/// the shape the caller is about to fill, and is counted so the
/// contract stays observable (`Refe::pool_misses`).
fn take_u32(pool: &mut Vec<Vec<u32>>, want: usize, misses: &mut u64) -> Vec<u32> {
    match pool.pop() {
        Some(v) if v.capacity() >= want => v,
        Some(mut v) => {
            // Recycled but undersized for this shape: one sized growth,
            // counted. (Vectors are given back cleared, so `reserve`
            // targets the full `want`.)
            *misses += 1;
            v.reserve(want);
            v
        }
        None => {
            *misses += 1;
            Vec::with_capacity(want)
        }
    }
}

fn give_u32(pool: &mut Vec<Vec<u32>>, mut v: Vec<u32>) {
    v.clear();
    pool.push(v);
}

pub struct Refe {
    aw: u32,
    node: NodeId,
    pub ert: Ert,
    resilience: ResilienceConfig,
    fabric: Arc<Fabric<ClusterMsg>>,
    clock: Clock,
    data_qps: HashMap<u32, Qp<ClusterMsg>>,
    ctrl_qps: HashMap<u32, Qp<ClusterMsg>>,
    orch_qp: Option<Qp<ClusterMsg>>,
    round: u64,
    io: IoScratch,
    /// Cluster event log (failure-lifecycle events, unconditional).
    events: Arc<EventLog>,
    /// Owning AW's span recorder (`None` unless `[trace]` is enabled).
    trace: Option<TraceHandle>,
    // Self-healing counters (§7 ablations / Fig. 9 analysis).
    pub ew_failovers: u64,
    pub rows_replayed: u64,
    pub probes_sent: u64,
    pub dispatch_bytes: u64,
    /// Scratch-pool misses: dispatches that had to allocate because the
    /// recycled-vector pool underflowed (or held only undersized
    /// vectors). Zero in steady state — the zero-alloc decode gauge.
    pub pool_misses: u64,
}

impl Refe {
    pub fn new(
        aw: u32,
        ert: Ert,
        resilience: ResilienceConfig,
        fabric: Arc<Fabric<ClusterMsg>>,
        events: Arc<EventLog>,
        trace: Option<TraceHandle>,
    ) -> Refe {
        let clock = fabric.clock().clone();
        Refe {
            aw,
            node: NodeId::Aw(aw),
            ert,
            resilience,
            fabric,
            clock,
            data_qps: HashMap::new(),
            ctrl_qps: HashMap::new(),
            orch_qp: None,
            round: 0,
            io: IoScratch::default(),
            events,
            trace,
            ew_failovers: 0,
            rows_replayed: 0,
            probes_sent: 0,
            dispatch_bytes: 0,
            pool_misses: 0,
        }
    }

    /// Scatter `groups`' rows (taken from `g`, the post-attention normed
    /// activations) to EWs, gather expert outputs, and accumulate
    /// `gate_weight * expert_out` into `h`'s rows. Non-Return messages
    /// received while waiting are pushed to `deferred` for the AW loop.
    ///
    /// This is the paper's `expert_io(expert_id, layer_id, tokens)` API,
    /// batched per layer.
    pub fn expert_io(
        &mut self,
        layer: u32,
        g: &Tensor,
        groups: &ExpertGroups,
        h: &mut Tensor,
        inbox: &Inbox<ClusterMsg>,
        deferred: &mut Vec<Envelope<ClusterMsg>>,
    ) -> Result<(), RefeError> {
        // Move the reusable gather state out so `&mut self` methods stay
        // callable while it is borrowed; put it back whatever happens.
        let span_t0 = self.trace.as_ref().map(|t| t.start());
        let mut io = std::mem::take(&mut self.io);
        let result = self.expert_io_inner(layer, g, groups, h, inbox, deferred, &mut io);
        self.io = io;
        if let (Some(tr), Some(t0)) = (&self.trace, span_t0) {
            tr.record(SpanKind::DispatchRound, 0, layer as u64, t0);
        }
        result
    }

    #[allow(clippy::too_many_arguments)]
    fn expert_io_inner(
        &mut self,
        layer: u32,
        g: &Tensor,
        groups: &ExpertGroups,
        h: &mut Tensor,
        inbox: &Inbox<ClusterMsg>,
        deferred: &mut Vec<Envelope<ClusterMsg>>,
        io: &mut IoScratch,
    ) -> Result<(), RefeError> {
        self.round += 1;
        let round = self.round;
        let IoScratch { slot_info, entry_of_slot, done, slot_out, u32_pool } = io;
        slot_info.clear();
        entry_of_slot.clear();

        // Slots are assigned iterating the expert groups (a BTreeMap), so
        // slot order is expert-ascending — the canonical accumulation
        // order below. Entry rows are *views* into `g` (refcount bumps):
        // no token floats are copied onto the dispatch path.
        let mut per_ew: BTreeMap<u32, Vec<DispatchEntry>> = BTreeMap::new();
        for (&expert, rows) in &groups.groups {
            let ew = self
                .ert
                .resolve(expert)
                .ok_or(RefeError::Unroutable { expert })?;
            let mut slots = Vec::with_capacity(rows.len());
            let mut row_views = Vec::with_capacity(rows.len());
            for &(row, w) in rows {
                let slot = slot_info.len() as u32;
                slot_info.push((row, w));
                entry_of_slot.push((expert, ew));
                slots.push(slot);
                row_views.push(g.row_tensor(row));
            }
            per_ew.entry(ew).or_default().push(DispatchEntry {
                expert: expert as u16,
                rows: row_views,
                slots,
            });
        }

        // Post to every known EW; empty dispatches are the heartbeat.
        let mut outstanding: BTreeMap<u32, Vec<u32>> = BTreeMap::new(); // ew -> slots
        for ew in self.ert.all_ews() {
            if self.ert.is_dead(ew) {
                continue;
            }
            let entries = per_ew.remove(&ew).unwrap_or_default();
            if !entries.is_empty() {
                // Borrow each entry's slot list; the old code cloned every
                // one of them just to flatten (doubling the dispatch-path
                // allocations), and the vector itself is recycled now.
                let want: usize = entries.iter().map(|e| e.slots.len()).sum();
                let mut slots = take_u32(u32_pool, want, &mut self.pool_misses);
                slots.extend(entries.iter().flat_map(|e| e.slots.iter().copied()));
                outstanding.insert(ew, slots);
            }
            let msg = DispatchMsg {
                layer,
                round,
                ert_version: self.ert.version(),
                entries,
                urgent: false,
            };
            let bytes = msg.wire_bytes();
            self.dispatch_bytes += bytes as u64;
            let qp = self.data_qp(ew);
            if qp
                .post(ClusterMsg::Dispatch(msg), bytes, TrafficClass::ExpertDispatch)
                .is_err()
            {
                return Err(RefeError::LocalDown);
            }
        }

        // Gather with self-healing. Expert outputs are *buffered* per slot
        // and applied after the last one arrives, in slot order — the sum
        // into each row is then independent of return arrival order (so
        // failover replays and scheduling jitter cannot perturb f32
        // accumulation). Each buffered output is a view into the EW's
        // return tensor — the floats are only read once, by the final
        // accumulation below.
        done.clear();
        done.resize(slot_info.len(), false);
        slot_out.clear();
        slot_out.resize_with(slot_info.len(), || None);
        let mut remaining = slot_info.len();
        // Slots bounced by a retired EW (`Stale`) whose replacement route
        // is not visible yet: parked until an `ErtUpdate` at/after the
        // bounce version arrives (applied right here in the gather loop —
        // deferring it to the AW main loop would deadlock the round).
        let mut parked: Vec<(u64, Vec<u32>)> = Vec::new();
        let start = self.clock.now();
        let mut last_progress = start;
        while remaining > 0 {
            match inbox.recv(Duration::from_millis(2)) {
                Ok(env) => match env.msg {
                    ClusterMsg::Return(ret) if ret.layer == layer && ret.round == round => {
                        for e in &ret.entries {
                            for (i, &slot) in e.slots.iter().enumerate() {
                                let s = slot as usize;
                                if s < done.len() && !done[s] {
                                    done[s] = true;
                                    remaining -= 1;
                                    slot_out[s] = Some(e.rows[i].clone());
                                }
                            }
                        }
                        // Clear per-EW bookkeeping for fully-served EWs.
                        if let NodeId::Ew(ew) = env.from {
                            let served = outstanding
                                .get(&ew)
                                .is_some_and(|slots| slots.iter().all(|&s| done[s as usize]));
                            if served {
                                if let Some(v) = outstanding.remove(&ew) {
                                    give_u32(u32_pool, v);
                                }
                            }
                        }
                        last_progress = self.clock.now();
                    }
                    ClusterMsg::Return(_) => {} // stale round/layer
                    ClusterMsg::ErtUpdate { version, table } => {
                        // Applied inside the gather so parked replays (and
                        // retirement reroutes) cannot wait on the AW loop.
                        if self.apply_ert(version, table) {
                            let v = self.ert.version();
                            let mut i = 0;
                            while i < parked.len() {
                                if parked[i].0 <= v {
                                    let (_, pending) = parked.swap_remove(i);
                                    let res = self.replay(
                                        layer,
                                        round,
                                        &pending,
                                        entry_of_slot,
                                        slot_info,
                                        g,
                                        &mut outstanding,
                                        u32_pool,
                                    );
                                    give_u32(u32_pool, pending);
                                    res?;
                                } else {
                                    i += 1;
                                }
                            }
                        }
                    }
                    ClusterMsg::Stale { layer: l, round: r, version, slots }
                        if l == layer && r == round =>
                    {
                        // A retired EW bounced this round's dispatch: the
                        // listed slots re-resolve against a table at/after
                        // the retirement version (§11). The EW is alive —
                        // no dead-mark, no failure report. Its per-EW
                        // bookkeeping is retired alongside it.
                        let NodeId::Ew(ew) = env.from else { continue };
                        let mut pending = take_u32(u32_pool, slots.len(), &mut self.pool_misses);
                        pending.extend(slots.iter().copied().filter(|&s| {
                            (s as usize) < done.len() && !done[s as usize]
                        }));
                        if let Some(owed) = outstanding.remove(&ew) {
                            give_u32(u32_pool, owed);
                        }
                        if pending.is_empty() {
                            give_u32(u32_pool, pending);
                        } else if self.ert.version() >= version {
                            let res = self.replay(
                                layer,
                                round,
                                &pending,
                                entry_of_slot,
                                slot_info,
                                g,
                                &mut outstanding,
                                u32_pool,
                            );
                            give_u32(u32_pool, pending);
                            res?;
                        } else {
                            parked.push((version, pending));
                        }
                        last_progress = self.clock.now();
                    }
                    ClusterMsg::Stale { .. } => {} // stale round/layer
                    _ => deferred.push(env),
                },
                Err(QpError::Timeout) => {}
                Err(_) => return Err(RefeError::LocalDown),
            }
            if remaining == 0 {
                break;
            }

            let waited = self.clock.now().saturating_sub(last_progress);
            if self.resilience.detection && waited > self.resilience.silence_window {
                // Probe EWs that still owe us rows; replay onto shadows.
                let suspects: Vec<u32> = outstanding.keys().copied().collect();
                let mut any_dead = false;
                for ew in suspects {
                    if self.probe_ew(ew) {
                        continue; // alive, just batching/slow
                    }
                    any_dead = true;
                    self.on_ew_death(ew);
                    // The detection window ran from the last gather
                    // progress to the probe verdict just rendered.
                    if let Some(tr) = &self.trace {
                        let end = tr.start();
                        tr.record_span(
                            SpanKind::DetectionWindow,
                            0,
                            ew as u64,
                            end.saturating_sub(waited),
                            end,
                        );
                    }
                    let owed = outstanding.get(&ew).map_or(0, |s| s.len());
                    let mut pending = take_u32(u32_pool, owed, &mut self.pool_misses);
                    if let Some(slots) = outstanding.remove(&ew) {
                        pending.extend(slots.iter().copied().filter(|&s| !done[s as usize]));
                        give_u32(u32_pool, slots);
                    }
                    let replayed = self.replay(
                        layer,
                        round,
                        &pending,
                        entry_of_slot,
                        slot_info,
                        g,
                        &mut outstanding,
                        u32_pool,
                    );
                    give_u32(u32_pool, pending);
                    replayed?;
                    // Rows are back on the wire toward live candidates:
                    // the reroute for this EW's loss is complete.
                    self.events.record(EventKind::Rerouted, ew as u64, 0, self.aw);
                }
                if !any_dead {
                    // All owers are alive; reset the window so we don't
                    // re-probe in a tight loop while they batch.
                    last_progress = self.clock.now();
                }
            } else if !self.resilience.detection
                && self.clock.now().saturating_sub(start) > self.resilience.ccl_abort_timeout
            {
                // Baselines: fatal communicator error (NCCL-style abort).
                let node = self.node;
                if let Some(qp) = self.orch() {
                    let _ = qp.post(
                        // A self-blaming report = "communicator error".
                        ClusterMsg::FailureReport { suspect: node, reporter: node },
                        HDR_BYTES,
                        TrafficClass::Control,
                    );
                }
                return Err(RefeError::CclAbort(self.clock.now().saturating_sub(start)));
            }
        }
        // Recycle the bookkeeping of EWs whose last return raced the exit.
        let drained: Vec<u32> = outstanding.keys().copied().collect();
        for ew in drained {
            if let Some(v) = outstanding.remove(&ew) {
                give_u32(u32_pool, v);
            }
        }
        // Canonical accumulation: slot order (expert-ascending, rows in
        // group order). Every replica of an expert computes bitwise-equal
        // outputs, so failover replays cannot change the result either.
        for (s, out) in slot_out.iter().enumerate() {
            if let Some(out) = out {
                let (row, w) = slot_info[s];
                ops::axpy_row(h.row_mut(row), w, out.data());
            }
        }
        Ok(())
    }

    /// Re-dispatch pending slots to the next live candidates as urgent
    /// replays (§5.1). Expert computation is stateless and deterministic,
    /// so replaying the same rows yields identical results. The replay
    /// fires exactly when an EW has just died — i.e. when latency matters
    /// most — so it carries row *views* and moves its slot list instead
    /// of the old copy-everything path (which doubled dispatch
    /// allocations at the worst possible moment).
    #[allow(clippy::too_many_arguments)]
    fn replay(
        &mut self,
        layer: u32,
        round: u64,
        pending: &[u32],
        entry_of_slot: &[(usize, u32)],
        slot_info: &[(usize, f32)],
        g: &Tensor,
        outstanding: &mut BTreeMap<u32, Vec<u32>>,
        u32_pool: &mut Vec<Vec<u32>>,
    ) -> Result<(), RefeError> {
        // Group pending slots by expert, resolve to the next candidate.
        let mut by_expert: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        for &s in pending {
            by_expert
                .entry(entry_of_slot[s as usize].0)
                // `pending.len()` bounds any one expert's share of the
                // replayed slots — the pool converges on right-sized
                // vectors instead of growing them push by push.
                .or_insert_with(|| take_u32(u32_pool, pending.len(), &mut self.pool_misses))
                .push(s);
        }
        for (expert, slots) in by_expert {
            let ew = self
                .ert
                .resolve(expert)
                .ok_or(RefeError::Unroutable { expert })?;
            let rows: Vec<Tensor> =
                slots.iter().map(|&s| g.row_tensor(slot_info[s as usize].0)).collect();
            // Record the new owers first, then *move* the slot list into
            // the message — no clone on the failover path.
            outstanding
                .entry(ew)
                .or_insert_with(|| take_u32(u32_pool, slots.len(), &mut self.pool_misses))
                .extend(&slots);
            self.rows_replayed += slots.len() as u64;
            let msg = DispatchMsg {
                layer,
                round,
                ert_version: self.ert.version(),
                entries: vec![DispatchEntry { expert: expert as u16, rows, slots }],
                urgent: true,
            };
            let bytes = msg.wire_bytes();
            self.dispatch_bytes += bytes as u64;
            let qp = self.data_qp(ew);
            qp.post(ClusterMsg::Dispatch(msg), bytes, TrafficClass::ExpertDispatch)
                .map_err(|_| RefeError::LocalDown)?;
        }
        Ok(())
    }

    fn probe_ew(&mut self, ew: u32) -> bool {
        let timeout = self.resilience.probe_timeout;
        let retries = self.resilience.probe_retries.max(1);
        self.probes_sent += 1;
        let qp = self.ctrl_qp(ew);
        for _ in 0..retries {
            if qp.probe(timeout).is_ok() {
                return true;
            }
        }
        false
    }

    /// Apply an ERT update, recording an `ErtRemap` span when the table
    /// actually changed. Shared by the AW admin path and the in-gather
    /// update path.
    pub fn apply_ert(&mut self, version: u64, table: ErtTable) -> bool {
        let span_t0 = self.trace.as_ref().map(|t| t.start());
        let applied = self.ert.apply(version, table);
        if let (true, Some(tr), Some(t0)) = (applied, &self.trace, span_t0) {
            tr.record(SpanKind::ErtRemap, 0, version, t0);
        }
        applied
    }

    fn on_ew_death(&mut self, ew: u32) {
        self.ew_failovers += 1;
        self.ert.mark_dead(ew);
        // token_index 1 = EW failure class (RecoveryReport reads it). The
        // orchestrator records its own `Detected` on confirmation; the
        // report's merge window folds the two into one incident.
        self.events.record(EventKind::Detected, 0, 1, ew);
        let node = self.node;
        if let Some(qp) = self.orch() {
            let _ = qp.post(
                ClusterMsg::FailureReport { suspect: NodeId::Ew(ew), reporter: node },
                HDR_BYTES,
                TrafficClass::Control,
            );
        }
    }

    fn data_qp(&mut self, ew: u32) -> &Qp<ClusterMsg> {
        let fabric = &self.fabric;
        let node = self.node;
        self.data_qps
            .entry(ew)
            .or_insert_with(|| fabric.qp(node, NodeId::Ew(ew), Plane::Data).expect("qp"))
    }

    fn ctrl_qp(&mut self, ew: u32) -> &Qp<ClusterMsg> {
        let fabric = &self.fabric;
        let node = self.node;
        self.ctrl_qps
            .entry(ew)
            .or_insert_with(|| fabric.qp(node, NodeId::Ew(ew), Plane::Control).expect("qp"))
    }

    fn orch(&mut self) -> Option<&Qp<ClusterMsg>> {
        if self.orch_qp.is_none() {
            self.orch_qp = self
                .fabric
                .qp(self.node, NodeId::Orchestrator, Plane::Control)
                .ok();
        }
        self.orch_qp.as_ref()
    }

    /// Broadcast the AW's activity state to all EWs (batching membership).
    pub fn broadcast_active(&mut self, active: bool) {
        for ew in self.ert.all_ews() {
            let qp = self.data_qp(ew);
            let _ = qp.post(
                ClusterMsg::ActiveBeacon { active },
                HDR_BYTES,
                TrafficClass::Control,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_miss_allocates_sized_and_is_counted() {
        // Regression: an underflowing pool handed out `Vec::default()`
        // (capacity 0), so the caller's extend reallocated silently.
        let mut pool: Vec<Vec<u32>> = Vec::new();
        let mut misses = 0u64;
        let v = take_u32(&mut pool, 48, &mut misses);
        assert_eq!(misses, 1, "underflow must be counted");
        assert!(v.capacity() >= 48, "miss must be sized from the shape, got {}", v.capacity());
        assert!(v.is_empty());
        // Recycled with enough capacity: a hit, no growth, no count.
        give_u32(&mut pool, v);
        let v = take_u32(&mut pool, 32, &mut misses);
        assert_eq!(misses, 1);
        assert!(v.capacity() >= 48, "recycled capacity must be retained");
        // Recycled but undersized for a bigger shape: counted, regrown.
        give_u32(&mut pool, v);
        let v = take_u32(&mut pool, 4096, &mut misses);
        assert_eq!(misses, 2, "undersized recycle is a miss too");
        assert!(v.capacity() >= 4096);
    }

    #[test]
    fn give_take_roundtrip_clears_but_keeps_capacity() {
        let mut pool: Vec<Vec<u32>> = Vec::new();
        let mut misses = 0u64;
        let mut v = take_u32(&mut pool, 8, &mut misses);
        v.extend([1, 2, 3]);
        let cap = v.capacity();
        give_u32(&mut pool, v);
        let v = take_u32(&mut pool, 8, &mut misses);
        assert!(v.is_empty(), "recycled vectors must come back cleared");
        assert_eq!(v.capacity(), cap);
        assert_eq!(misses, 1, "only the initial underflow is a miss");
    }
}
