//! Attention Worker (AW): the stateful side of the decoupled deployment.
//!
//! Owns a PJRT device with the attention/router/lm-head artifacts, the
//! per-request KV caches, a [`Refe`] forwarding engine for all EW traffic,
//! and the asynchronous checkpoint streamer (§6.1).
//!
//! Execution is layer-wise synchronized (§2.2.1): one prefill or one
//! batched decode step walks all L layers, calling the attention artifact
//! then scattering/gathering expert work through REFE at every layer.
//! After each generated token the AW queues one KV segment per layer plus
//! a commit record; the streamer flushes them into link idle gaps.
//!
//! Recovery paths:
//! - *adopting* a failed AW's request (§6.2): `AdoptRequest` → pull from
//!   the checkpoint store → install KV prefix → resume decoding from the
//!   committed token, in-place, without touching other requests;
//! - replay-based baselines for Fig. 12 are implemented here too
//!   (`install_replayed`): sequential (prefill + token-by-token decode)
//!   and parallel (one prefill over prompt+generated) reconstruction.

use super::refe::{Refe, RefeError};
use super::router::{self, ExpertGroups};
use super::sched;
use crate::config::Config;
use crate::coordinator::ert::Ert;
use crate::kvcache::{page_hash_seed, page_hash_update, BatchAssembler, KvPool, RequestKv};
use crate::modelcfg::{weights::Weights, Buckets, Manifest};
use crate::proto::{AwStatus, ClusterMsg, CommitMeta, RequestMeta, SegmentMsg, HDR_BYTES};
use crate::runtime::{ArgValue, Device, DeviceRole};
use crate::tensor::{ops, Tensor};
use crate::transport::{link::TrafficClass, Envelope, Fabric, Inbox, NodeHandle, NodeId, Plane, Qp};
use crate::checkpoint::CkptStreamer;
use crate::metrics::trace::{SpanKind, TraceHandle};
use crate::metrics::{EventKind, EventLog};
use crate::util::clock::{self, Clock};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Precomputed artifact names and weight-argument templates for the
/// decode hot path: every `execute_shared` call clones refcounted
/// handles instead of formatting strings (DESIGN.md §10).
struct HotNames {
    attn_prefill: HashMap<usize, Arc<str>>,
    attn_decode: HashMap<usize, Arc<str>>,
    router: HashMap<usize, Arc<str>>,
    lm_head: HashMap<usize, Arc<str>>,
    /// Per layer: [wq, wk, wv, wo, ln1, ln2].
    attn_weights: Vec<[ArgValue; 6]>,
    /// Per layer: the router gate weight.
    router_weights: Vec<ArgValue>,
    lm_head_weights: [ArgValue; 2],
}

fn names_by_bucket(prefix: &str, buckets: &[usize]) -> HashMap<usize, Arc<str>> {
    buckets.iter().map(|&b| (b, Arc::from(format!("{prefix}{b}")))).collect()
}

impl HotNames {
    fn new(m: &Manifest) -> HotNames {
        HotNames {
            attn_prefill: names_by_bucket("attn_prefill_t", &m.buckets.prefill_t),
            attn_decode: names_by_bucket("attn_decode_b", &m.buckets.decode_b),
            router: names_by_bucket("router_b", &m.buckets.router_b),
            lm_head: names_by_bucket("lm_head_b", &m.buckets.lm_head_b),
            attn_weights: (0..m.model.layers)
                .map(|l| {
                    [
                        ArgValue::weight(format!("layer{l}.wq")),
                        ArgValue::weight(format!("layer{l}.wk")),
                        ArgValue::weight(format!("layer{l}.wv")),
                        ArgValue::weight(format!("layer{l}.wo")),
                        ArgValue::weight(format!("layer{l}.ln1")),
                        ArgValue::weight(format!("layer{l}.ln2")),
                    ]
                })
                .collect(),
            router_weights: (0..m.model.layers)
                .map(|l| ArgValue::weight(format!("layer{l}.router")))
                .collect(),
            lm_head_weights: [ArgValue::weight("ln_f"), ArgValue::weight("lm_head")],
        }
    }
}

pub struct AwParams {
    pub idx: u32,
    pub cfg: Config,
    pub ert: Ert,
    pub manifest: Arc<Manifest>,
    pub weights: Weights,
    pub fabric: Arc<Fabric<ClusterMsg>>,
    /// KV page arena. Owned by the host slot, not the worker thread, so a
    /// respawned AW (coarse restart, provisioning) starts with a warm
    /// arena instead of re-growing it.
    pub pool: Arc<KvPool>,
    pub stop: Arc<AtomicBool>,
    /// Cluster event log — failure-lifecycle events (`RestoreStarted`,
    /// `Restored`) are recorded unconditionally, like every other event.
    pub events: Arc<EventLog>,
    /// Per-worker span recorder; `None` unless `[trace]` is enabled, so
    /// the hot paths take no clock reads when tracing is off.
    pub trace: Option<TraceHandle>,
    /// Cluster-wide REFE scratch-pool miss counter (owned by the
    /// `Spawner`); the worker flushes its local count here on exit.
    pub pool_misses: Arc<AtomicU64>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ReqPhase {
    Prefill,
    Decode,
}

/// Per-request token history (multi-gateway deployments only): every
/// token this AW emitted for the request, from `base` onward. When a
/// gateway shard dies, tokens in flight to it are lost on the wire; on
/// the orchestrator's `GatewaySet` the AW re-emits the history of every
/// request whose owner shard changed, and the surviving shards' gap-fill
/// dedup drops what they already saw. Retained after finish (the
/// re-emission must be able to close the stream on the new owner).
struct TokenLog {
    base: u32,
    tokens: Vec<u32>,
    finished: bool,
}

struct Req {
    meta: RequestMeta,
    kv: RequestKv,
    phase: ReqPhase,
    /// Token id to embed next (last emitted token during decode).
    next_input: u32,
    generated: u32,
    /// Original prompt length — survives restores (whose `meta.prompt` is
    /// empty) so re-preemption commits stay faithful.
    prompt_len: u32,
    /// Whether the request has produced at least one token *on this AW*
    /// since arrival/restore. Preemption victims must have progressed —
    /// this is the anti-livelock guarantee that a freshly restored
    /// request cannot be re-evicted before decoding anything.
    progressed: bool,
}

pub struct AwWorker {
    idx: u32,
    node: NodeId,
    cfg: Config,
    manifest: Arc<Manifest>,
    weights: Weights,
    device: Device,
    inbox: Inbox<ClusterMsg>,
    handle: NodeHandle,
    clock: Clock,
    refe: Refe,
    streamer: CkptStreamer,
    /// One data-plane QP per checkpoint-store replica: segments, commits
    /// and page refs fan out to every replica (`Arc`-shared payloads, so
    /// replication costs refcount bumps, not float copies).
    store_qps: Vec<Qp<ClusterMsg>>,
    /// One control-plane QP per gateway shard, indexed by shard id
    /// (shards never respawn, so the index is stable for the run).
    gw_qps: Vec<Qp<ClusterMsg>>,
    /// Live gateway shards (orchestrator `GatewaySet` keeps it current).
    /// Request ownership is `chash::owner(request_id, &gateways)`.
    gateways: Vec<u32>,
    /// Token history per request; maintained only when `gw_qps.len() > 1`
    /// (single-gateway runs have no failover to replay into).
    token_log: BTreeMap<u64, TokenLog>,
    orch_qp: Qp<ClusterMsg>,
    pool: Arc<KvPool>,
    /// Ordered map: iteration order (PCR snapshots, diagnostics) must be
    /// deterministic for scenario replay.
    reqs: BTreeMap<u64, Req>,
    prefill_q: VecDeque<u64>,
    active: VecDeque<u64>,
    deferred: Vec<Envelope<ClusterMsg>>,
    asm: BatchAssembler,
    names: HotNames,
    was_active: bool,
    stop: Arc<AtomicBool>,
    /// Set by `PreemptAll` (planned drain): this worker is closed to new
    /// work. Requests that still arrive (dispatched against a stale
    /// routing set) are bounced straight back instead of served, so a
    /// drain eventually empties the worker even under backlog.
    draining: bool,
    /// Workload-shaping router skew (scenario `hotspot e<K>`): every
    /// token routes to this expert in addition to its natural picks.
    hotspot: Option<usize>,
    /// Load-beacon cadence. `Periodic` keeps "never posted" as a real
    /// state: a respawned/late-provisioned AW arms on its first tick
    /// instead of treating the clock epoch as a previous post and
    /// beaconing immediately.
    status_beacon: clock::Periodic,
    events: Arc<EventLog>,
    trace: Option<TraceHandle>,
    /// Restore pulls in flight: request -> pull start (tracing only; the
    /// `RestorePull` span closes when the store's `Restore` data lands).
    pull_started: HashMap<u64, Duration>,
    pub steps: u64,
    /// Requests preempted by this worker (pressure shedding + drains).
    pub preemptions: u64,
    /// Cluster-wide scratch-pool miss counter; REFE's local count is
    /// flushed here when the worker exits (normal drain *or* fail-stop —
    /// the thread leaves its loop either way before `finish` joins it).
    pool_misses: Arc<AtomicU64>,
}

/// Spawn an AW worker thread; blocks until initialized (T_w) and returns
/// (thread handle, device handle).
pub fn spawn(params: AwParams) -> Result<(std::thread::JoinHandle<()>, Device), String> {
    let worker_clock = params.fabric.clock().clone();
    let (tx, rx) = clock::channel(&worker_clock);
    let idx = params.idx;
    let h = clock::spawn_participant(&worker_clock, format!("aw-{idx}"), move || {
        let mut w = match AwWorker::init(params) {
            Ok(w) => w,
            Err(e) => {
                let _ = tx.send(Err(e));
                return;
            }
        };
        let _ = tx.send(Ok(w.device.clone()));
        w.run();
    })
    .map_err(|e| format!("spawn aw thread: {e}"))?;
    let device = rx.recv().map_err(|_| "aw init channel closed".to_string())??;
    Ok((h, device))
}

impl AwWorker {
    fn init(p: AwParams) -> Result<AwWorker, String> {
        let node = NodeId::Aw(p.idx);
        let clock = p.fabric.clock().clone();
        let (inbox, handle) = p.fabric.register(node);
        let device = Device::spawn_kernel(
            format!("aw{}", p.idx),
            p.manifest.clone(),
            p.weights.clone(),
            DeviceRole::Attention.plan(&p.manifest),
            p.cfg.transport.worker_extra_init,
            clock.clone(),
            p.cfg.kernels.backend,
        )
        .map_err(|e| e.to_string())?;
        let refe = Refe::new(
            p.idx,
            p.ert,
            p.cfg.resilience.clone(),
            p.fabric.clone(),
            p.events.clone(),
            p.trace.clone(),
        );
        let store_qps = (0..p.cfg.cluster.num_stores.max(1) as u32)
            .map(|k| p.fabric.qp(node, NodeId::Store(k), Plane::Data))
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| e.to_string())?;
        let gw_qps = (0..p.cfg.cluster.num_gateways.max(1) as u32)
            .map(|g| p.fabric.qp(node, NodeId::Gateway(g), Plane::Control))
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| e.to_string())?;
        let gateways: Vec<u32> = (0..gw_qps.len() as u32).collect();
        let orch_qp =
            p.fabric.qp(node, NodeId::Orchestrator, Plane::Control).map_err(|e| e.to_string())?;
        let streamer = CkptStreamer::new(p.cfg.resilience.checkpointing, 4096);
        let asm = BatchAssembler::new(&p.manifest.model);
        let names = HotNames::new(&p.manifest);
        let hotspot = p.cfg.workload.hotspot_expert;
        let status_beacon = clock::Periodic::new(p.cfg.sched.status_interval);
        Ok(AwWorker {
            idx: p.idx,
            node,
            cfg: p.cfg,
            manifest: p.manifest,
            weights: p.weights,
            device,
            inbox,
            handle,
            clock,
            refe,
            streamer,
            store_qps,
            gw_qps,
            gateways,
            token_log: BTreeMap::new(),
            orch_qp,
            pool: p.pool,
            reqs: BTreeMap::new(),
            prefill_q: VecDeque::new(),
            active: VecDeque::new(),
            deferred: Vec::new(),
            asm,
            names,
            was_active: false,
            stop: p.stop,
            draining: false,
            hotspot,
            status_beacon,
            events: p.events,
            trace: p.trace,
            pull_started: HashMap::new(),
            steps: 0,
            preemptions: 0,
            pool_misses: p.pool_misses,
        })
    }

    fn alive(&self) -> bool {
        !self.stop.load(Ordering::Relaxed) && self.handle.is_alive() && !self.device.is_dead()
    }

    fn run(&mut self) {
        while self.alive() {
            // 1. Handle everything pending (admin, new requests, restores).
            let deferred = std::mem::take(&mut self.deferred);
            for env in deferred {
                self.handle_msg(env);
            }
            while let Ok(env) = self.inbox.recv(Duration::ZERO) {
                self.handle_msg(env);
            }

            // 2. Activity beacon on transitions (EW batching membership)
            //    and the periodic load beacon (pressure + queue depth).
            let is_active = !self.prefill_q.is_empty() || !self.active.is_empty();
            if is_active != self.was_active {
                self.refe.broadcast_active(is_active);
                self.was_active = is_active;
            }
            self.post_status_if_due();

            // 2b. Pressure shedding (§9): over the high watermark, evict
            //     the lowest-progress request before the arena hard-fills.
            self.maybe_shed_pressure();

            // 3. Work: prefill first (admission, headroom-gated), then
            //    one decode step.
            let result = if !self.prefill_q.is_empty() {
                self.try_prefill_front()
            } else if !self.active.is_empty() {
                self.decode_step()
            } else {
                // Idle: flush checkpoints, nap briefly.
                self.flush_ckpt();
                match self.inbox.recv(Duration::from_millis(2)) {
                    Ok(env) => self.handle_msg(env),
                    Err(_) => {}
                }
                Ok(())
            };

            match result {
                Ok(()) => {}
                Err(StepError::Fatal) => break,
                Err(StepError::Stalled) => {
                    // Unroutable/CCL abort: the orchestrator decides what
                    // happens next (coarse restart in baseline mode). Hold
                    // position; retry after a beat.
                    self.clock.sleep(Duration::from_millis(20));
                }
            }
            // 4. Opportunistic checkpoint flush (§6.1).
            self.flush_ckpt();
            // §7.4 baseline: Pause-Checkpoint-Resume (global synchronous
            // snapshot every N decode steps; blocks token generation while
            // the full KV state drains over the link).
            let every = self.cfg.resilience.pause_ckpt_every;
            if every > 0 && self.steps > 0 && self.steps % every as u64 == 0 {
                self.pause_checkpoint_resume();
            }
        }
        self.pool_misses.fetch_add(self.refe.pool_misses, Ordering::Relaxed);
        self.device.kill();
    }

    /// Whether per-request token histories are maintained (sharded
    /// gateway deployments only).
    fn track_tokens(&self) -> bool {
        self.gw_qps.len() > 1
    }

    /// The QP of the gateway shard that owns `id` under the current live
    /// set (falls back to shard 0 — the orchestrator never removes the
    /// last gateway, so the live set is non-empty in practice).
    fn gw_owner_qp(&self, id: u64) -> &Qp<ClusterMsg> {
        let shard = crate::util::chash::owner(id, &self.gateways).unwrap_or(0);
        &self.gw_qps[shard as usize]
    }

    /// Gateway failover repair: for every request whose owner shard
    /// changed between `old` and the current live set, re-emit the full
    /// token history (and the final `Finished`, if reached) to the new
    /// owner. Tokens the old owner already recorded into the shared
    /// stream are deduplicated by the gateways' gap-fill logic; only the
    /// window that was in flight to the dead shard is actually new.
    fn replay_moved_streams(&mut self, old: &[u32]) {
        for (&id, log) in &self.token_log {
            if crate::util::chash::owner(id, old) == crate::util::chash::owner(id, &self.gateways)
            {
                continue;
            }
            let qp = self.gw_owner_qp(id);
            for (i, &token) in log.tokens.iter().enumerate() {
                let _ = qp.post(
                    ClusterMsg::Token {
                        request: id,
                        index: log.base + i as u32,
                        token,
                        worker: self.idx,
                    },
                    HDR_BYTES,
                    TrafficClass::Control,
                );
            }
            if log.finished {
                let _ = qp.post(
                    ClusterMsg::Finished { request: id, worker: self.idx },
                    HDR_BYTES,
                    TrafficClass::Control,
                );
            }
        }
    }

    fn flush_ckpt(&mut self) {
        let span_t0 = self.trace.as_ref().map(|t| t.start());
        let posted = self.streamer.flush(&self.store_qps, self.handle.egress());
        // Only flushes that moved data produce spans — the opportunistic
        // no-op calls on every loop iteration would drown the trace.
        if let (true, Some(tr), Some(t0)) = (posted > 0, &self.trace, span_t0) {
            tr.record(SpanKind::CkptEmit, 0, posted as u64, t0);
        }
    }

    // ---------------------------------------------------------------------
    // Overload scheduling (DESIGN.md §9): load beacon, KV-pressure
    // headroom, checkpoint-backed preemption, planned drains.
    // ---------------------------------------------------------------------

    /// Periodic load beacon: KV pressure + queue depth to the gateway
    /// (routing/admission) and the orchestrator (parked re-admission).
    fn post_status_if_due(&mut self) {
        let now = self.clock.now();
        if !self.status_beacon.due(now) {
            return;
        }
        let msg = ClusterMsg::Status(AwStatus {
            aw: self.idx,
            pages_in_use: self.pool.pages_in_use() as u32,
            pages_budget: self.pool.budget_pages() as u32,
            queue_depth: (self.prefill_q.len() + self.active.len()) as u32,
            resident: self.reqs.len() as u32,
        });
        for &g in &self.gateways {
            let _ = self.gw_qps[g as usize].post(msg.clone(), HDR_BYTES, TrafficClass::Admin);
        }
        let _ = self.orch_qp.post(msg, HDR_BYTES, TrafficClass::Admin);
    }

    /// High-watermark shedding: above the mark, preempt the lowest-
    /// progress request so the arena recovers headroom before it
    /// hard-fills. Re-admission happens at the orchestrator once some AW
    /// drops below the *low* watermark (hysteresis).
    fn maybe_shed_pressure(&mut self) {
        if self.active.len() <= 1 {
            return; // never starve the last active request
        }
        if self.pool.pressure() >= self.cfg.sched.high_watermark {
            self.preempt_one_victim();
        }
    }

    /// Make room for `needed` fresh pages, preempting lowest-progress
    /// actives while more than `min_active` remain. Returns whether the
    /// headroom now exists (always true for an unbounded arena).
    fn ensure_headroom(&mut self, needed: usize, min_active: usize) -> bool {
        loop {
            let free = match self.pool.free_pages() {
                None => return true,
                Some(f) => f,
            };
            if free >= needed {
                return true;
            }
            if self.active.len() <= min_active || !self.preempt_one_victim() {
                return false;
            }
        }
    }

    /// Preempt the lowest-progress active request that has produced at
    /// least one token here (fresh restores are never re-evicted before
    /// decoding — the anti-livelock rule). Returns false if there was no
    /// eligible candidate.
    fn preempt_one_victim(&mut self) -> bool {
        if !self.streamer.enabled {
            return false; // no checkpoints: nothing durable to restore from
        }
        let victim = sched::pick_victim(
            self.active
                .iter()
                .map(|id| (*id, &self.reqs[id]))
                .filter(|(_, r)| r.progressed)
                .map(|(id, r)| (id, r.generated)),
        );
        match victim {
            Some(id) => {
                self.preempt(id);
                true
            }
            None => false,
        }
    }

    /// Checkpoint-backed preemption: the request's full committed state
    /// (segments per token + commit) is already queued on the streamer —
    /// force it onto the wire, evict the KV pages, and hand the commit
    /// meta to the orchestrator, which re-admits the request later via
    /// the same `AdoptRequest`/restore path that heals AW failures.
    fn preempt(&mut self, id: u64) {
        self.streamer.flush_now(&self.store_qps);
        self.active.retain(|&r| r != id);
        let req = self.reqs.remove(&id).expect("preempt of unknown request");
        let meta = CommitMeta {
            request: id,
            committed_pos: req.kv.len() as u32,
            last_token: req.next_input,
            generated: req.generated,
            max_new_tokens: req.meta.max_new_tokens,
            prompt_len: req.prompt_len,
        };
        drop(req); // KV pages return to the arena here
        self.preemptions += 1;
        let msg = ClusterMsg::Preempted { aw: self.idx, meta };
        let _ = self.orch_qp.post(msg.clone(), HDR_BYTES, TrafficClass::Control);
        // Informational copy for the owning gateway's event log.
        let _ = self.gw_owner_qp(id).post(msg, HDR_BYTES, TrafficClass::Control);
    }

    /// Planned drain/migration: evict everything. Committed requests go
    /// via the checkpoint path; requests with no durable state yet are
    /// bounced to the orchestrator for resubmission from the prompt.
    fn preempt_all(&mut self) {
        let mut uncommitted: Vec<u64> = Vec::new();
        let actives: Vec<u64> = self.active.iter().copied().collect();
        for id in actives {
            if self.streamer.enabled && self.reqs[&id].kv.len() > 0 {
                self.preempt(id);
            } else {
                self.active.retain(|&r| r != id);
                self.reqs.remove(&id);
                uncommitted.push(id);
            }
        }
        let queued: Vec<u64> = self.prefill_q.drain(..).collect();
        for id in queued {
            self.reqs.remove(&id);
            uncommitted.push(id);
        }
        if !uncommitted.is_empty() {
            uncommitted.sort_unstable();
            let msg = ClusterMsg::PreemptedUncommitted { aw: self.idx, requests: uncommitted };
            let bytes = msg.wire_bytes();
            let _ = self.orch_qp.post(msg, bytes, TrafficClass::Control);
        }
    }

    /// Re-park a restore this worker cannot take (draining, or no
    /// headroom even after shedding): the durable state is already in the
    /// store, so this is just another preemption — posted to both the
    /// orchestrator (authoritative) and the gateway (event log), keeping
    /// every preemption counter consistent.
    fn bounce_restore(&mut self, meta: CommitMeta) {
        let id = meta.request;
        let msg = ClusterMsg::Preempted { aw: self.idx, meta };
        let _ = self.orch_qp.post(msg.clone(), HDR_BYTES, TrafficClass::Control);
        let _ = self.gw_owner_qp(id).post(msg, HDR_BYTES, TrafficClass::Control);
    }

    /// Reject a request that can never be served here, surfacing a
    /// stream-level error through the gateway instead of dropping it
    /// silently (the old admission bug).
    fn reject(&mut self, id: u64, reason: String) {
        self.reqs.remove(&id);
        self.prefill_q.retain(|&r| r != id);
        let _ = self.gw_owner_qp(id).post(
            ClusterMsg::Rejected { request: id, worker: self.idx, reason },
            HDR_BYTES,
            TrafficClass::Control,
        );
    }

    /// Training-style global snapshot (§7.4 baseline): serialize every
    /// resident request's entire KV cache to the store and *wait* for the
    /// link to drain before resuming decode.
    fn pause_checkpoint_resume(&mut self) {
        let ids: Vec<u64> = self.reqs.keys().copied().collect();
        for id in ids {
            let (len, layers) = {
                let req = &self.reqs[&id];
                (req.kv.len(), req.kv.layers())
            };
            for layer in 0..layers {
                for pos in 0..len {
                    let data = self.reqs[&id].kv.segment_payload(layer, pos);
                    let msg = ClusterMsg::CkptSegment(SegmentMsg {
                        request: id,
                        pos: pos as u32,
                        layer: layer as u16,
                        data,
                    });
                    let bytes = msg.wire_bytes();
                    for qp in &self.store_qps {
                        let _ = qp.post(msg.clone(), bytes, TrafficClass::Checkpoint);
                    }
                }
            }
            let req = &self.reqs[&id];
            let msg = ClusterMsg::CkptCommit(CommitMeta {
                request: id,
                committed_pos: req.kv.len() as u32,
                last_token: req.next_input,
                generated: req.generated,
                max_new_tokens: req.meta.max_new_tokens,
                prompt_len: req.prompt_len,
            });
            let bytes = msg.wire_bytes();
            for qp in &self.store_qps {
                let _ = qp.post(msg.clone(), bytes, TrafficClass::Checkpoint);
            }
        }
        // Pause until the snapshot is fully on the wire.
        let busy = self.handle.egress().busy_for();
        if !busy.is_zero() {
            self.clock.sleep(busy);
        }
    }

    fn handle_msg(&mut self, env: Envelope<ClusterMsg>) {
        match env.msg {
            ClusterMsg::NewRequest(meta) => {
                let id = meta.id;
                if self.draining {
                    // Dispatched against a stale routing set after a
                    // drain: bounce for resubmission elsewhere.
                    let msg =
                        ClusterMsg::PreemptedUncommitted { aw: self.idx, requests: vec![id] };
                    let bytes = msg.wire_bytes();
                    let _ = self.orch_qp.post(msg, bytes, TrafficClass::Control);
                    return;
                }
                let prompt_len = meta.prompt.len() as u32;
                if self.track_tokens() {
                    // Fresh submission (or resubmission from the prompt):
                    // generation restarts deterministically from token 0,
                    // so any stale history is superseded wholesale.
                    self.token_log.insert(
                        id,
                        TokenLog { base: 0, tokens: Vec::new(), finished: false },
                    );
                }
                let kv = RequestKv::new(&self.manifest.model, &self.pool);
                self.reqs.insert(
                    id,
                    Req {
                        meta,
                        kv,
                        phase: ReqPhase::Prefill,
                        next_input: 0,
                        generated: 0,
                        prompt_len,
                        progressed: false,
                    },
                );
                self.prefill_q.push_back(id);
            }
            ClusterMsg::ErtUpdate { version, table } => {
                self.refe.apply_ert(version, table);
            }
            ClusterMsg::AdoptRequest { meta } => {
                // §6.2: pull the request's durable state from the store.
                self.events.record(EventKind::RestoreStarted, meta.request, 0, self.idx);
                if let Some(tr) = &self.trace {
                    self.pull_started.insert(meta.request, tr.start());
                }
                // Pull from every replica: the first complete answer wins
                // (duplicate `Restore`s are idempotent) and a replica that
                // died or lost the request simply never replies.
                for qp in &self.store_qps {
                    let _ = qp.post(
                        ClusterMsg::RestorePull { request: meta.request },
                        HDR_BYTES,
                        TrafficClass::Control,
                    );
                }
            }
            ClusterMsg::Restore(data) => self.install_restored(data),
            ClusterMsg::GatewaySet { gateways } => {
                if gateways != self.gateways && !gateways.is_empty() {
                    let old = std::mem::replace(&mut self.gateways, gateways);
                    if self.track_tokens() {
                        self.replay_moved_streams(&old);
                    }
                }
            }
            ClusterMsg::PreemptAll => {
                self.draining = true;
                self.preempt_all();
            }
            ClusterMsg::Return(_) => {} // stale (failover already handled)
            _ => {}
        }
    }

    /// §6.2 request-level restoration: install the committed KV prefix and
    /// resume decoding as if the request had always been here.
    fn install_restored(&mut self, data: crate::proto::RestoreData) {
        let m = self.manifest.model.clone();
        let meta = data.meta;
        // Close the RestorePull span (store round-trip) and open the
        // install span, regardless of whether the install succeeds.
        let install_t0 = if let Some(tr) = &self.trace {
            let t0 = tr.start();
            if let Some(pull_t0) = self.pull_started.remove(&meta.request) {
                tr.record_span(SpanKind::RestorePull, meta.request, 0, pull_t0, t0);
            }
            Some(t0)
        } else {
            None
        };
        if self.reqs.contains_key(&meta.request) {
            return; // duplicate restore (idempotent)
        }
        // A draining worker takes no new residents — re-park immediately.
        if self.draining {
            self.bounce_restore(meta);
            return;
        }
        // Pages are allocated for exactly the committed prefix — restore
        // cost scales with the sequence, not with `max_seq`.
        let mut kv = RequestKv::new(&m, &self.pool);
        let committed = meta.committed_pos as usize;
        let pt = self.pool.page_tokens();
        let layers = m.layers;
        let full_pages = committed / pt;
        // Share-aware install (DESIGN.md §13): hash each full page of the
        // restored prefix and take references on pages the arena already
        // holds sealed, instead of re-allocating and re-writing them. The
        // shared run is installed *before* the headroom check so its
        // refcounts pin the pages — shedding during `ensure_headroom`
        // cannot unseal them underneath us. `data.segments` is ordered
        // pos-major, layer-minor (restore_data), so the segment for
        // (pos, layer) sits at `pos * layers + layer`.
        let mut hashes: Vec<Vec<u64>> = vec![Vec::with_capacity(full_pages); layers];
        for (layer, row) in hashes.iter_mut().enumerate() {
            for page in 0..full_pages {
                let mut h = page_hash_seed(layer);
                for t in 0..pt {
                    let seg = &data.segments[(page * pt + t) * layers + layer].2;
                    h = page_hash_update(h, seg.as_slice());
                }
                row.push(h);
            }
        }
        for layer in 0..layers {
            for page in 0..full_pages {
                let hit = kv.try_share_page(layer, hashes[layer][page], |raw| {
                    (0..pt).all(|t| {
                        let seg = &data.segments[(page * pt + t) * layers + layer].2;
                        let sl = seg.len();
                        raw[t * sl..(t + 1) * sl] == seg[..]
                    })
                });
                if !hit {
                    break; // only a *leading* run can be shared in order
                }
            }
        }
        // Headroom for the remaining prefix (+1 decode step), shedding if
        // needed. Shared pages are already in the tables, so
        // `pages_to_extend` only counts what must still be allocated. If
        // the arena cannot take it even after shedding, bounce the
        // request back to the orchestrator — its durable state is already
        // in the store, so this is just a re-park (the dropped `kv`
        // returns the shared references).
        let needed = kv.pages_to_extend(committed + 1);
        if !self.ensure_headroom(needed, 0) {
            self.bounce_restore(meta);
            return;
        }
        // Reserve the prefix *and the next decode position* now, so the
        // headroom just checked cannot be stolen by a later install — a
        // fresh restore is guaranteed its first decode step.
        kv.reserve(committed + 1);
        for (pos, layer, seg) in &data.segments {
            // Positions covered by the shared run are already resident.
            if (*pos as usize) / pt < kv.shared_prefix_pages(*layer as usize) {
                continue;
            }
            kv.write_segment(*layer as usize, *pos as usize, seg.as_slice());
        }
        // Seal the full pages we did write, so the next restore or prefill
        // with this prefix shares instead of re-materializing.
        for layer in 0..layers {
            for page in kv.shared_prefix_pages(layer)..full_pages {
                kv.seal_page(layer, page, hashes[layer][page]);
            }
        }
        kv.set_len(committed);
        let id = meta.request;
        if self.track_tokens() {
            // Adopt the history if it is contiguous with the committed
            // state (this AW preempted the request earlier and is now
            // readopting it); otherwise start a fresh log at the restore
            // point — earlier tokens already live in another AW's log.
            let keep = self
                .token_log
                .get(&id)
                .map_or(false, |l| l.base + l.tokens.len() as u32 == meta.generated);
            if !keep {
                self.token_log.insert(
                    id,
                    TokenLog { base: meta.generated, tokens: Vec::new(), finished: false },
                );
            }
        }
        self.reqs.insert(
            id,
            Req {
                meta: RequestMeta {
                    id,
                    prompt: Vec::new(), // not needed: KV is restored
                    max_new_tokens: meta.max_new_tokens,
                },
                kv,
                phase: ReqPhase::Decode,
                next_input: meta.last_token,
                generated: meta.generated,
                prompt_len: meta.prompt_len,
                progressed: false,
            },
        );
        self.active.push_back(id);
        self.events.record(EventKind::Restored, id, 0, self.idx);
        if let (Some(tr), Some(t0)) = (&self.trace, install_t0) {
            tr.record(SpanKind::RestoreInstall, id, committed as u64, t0);
        }
    }

    // ---------------------------------------------------------------------
    // Prefill
    // ---------------------------------------------------------------------

    /// Admit the next queued prefill if the arena has headroom for its
    /// whole prompt; otherwise keep decoding (the queue waits). The
    /// gateway's fit check guarantees a lone request always fits, so an
    /// un-preemptable shortfall with an empty arena means the request is
    /// oversized for the budget — reject it.
    fn try_prefill_front(&mut self) -> Result<(), StepError> {
        let id = match self.prefill_q.front() {
            Some(&id) => id,
            None => return Ok(()),
        };
        let needed = match self.reqs.get(&id) {
            Some(r) => r.kv.pages_to_extend(r.meta.prompt.len().max(1)),
            None => {
                self.prefill_q.pop_front(); // evicted while queued
                return Ok(());
            }
        };
        if self.ensure_headroom(needed, 0) {
            self.prefill_q.pop_front();
            return self.prefill(id);
        }
        if self.active.is_empty() && self.pool.pages_in_use() == 0 {
            self.reject(id, "prompt KV footprint exceeds the arena page budget".into());
            return Ok(());
        }
        if !self.active.is_empty() {
            return self.decode_step();
        }
        // Nothing to preempt and the arena is draining elsewhere: retry.
        self.clock.sleep(Duration::from_millis(2));
        Ok(())
    }

    fn prefill(&mut self, id: u64) -> Result<(), StepError> {
        let span_t0 = self.trace.as_ref().map(|t| t.start());
        let m = self.manifest.model.clone();
        let req = match self.reqs.get(&id) {
            Some(r) => r,
            None => return Ok(()),
        };
        let prompt = req.meta.prompt.clone();
        let p_len = prompt.len();
        let bucket = match Buckets::fit(&self.manifest.buckets.prefill_t, p_len) {
            Some(b) => b,
            None => {
                // Oversized prompts are rejected at the gateway; if one
                // still reaches us (defense in depth), surface the error
                // instead of dropping the request silently.
                self.reject(
                    id,
                    format!("prompt length {p_len} exceeds the largest prefill bucket"),
                );
                return Ok(());
            }
        };

        // Embed prompt (+ zero pad rows).
        let mut x = Tensor::zeros(vec![bucket, m.hidden]);
        for (i, &tok) in prompt.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.weights.embed_row(tok as usize));
        }

        for layer in 0..m.layers {
            let mut args = Vec::with_capacity(7);
            args.push(ArgValue::f32(x.clone()));
            args.extend(self.names.attn_weights[layer].iter().cloned());
            let outs = self
                .device
                .execute_shared(&self.names.attn_prefill[&bucket], args)
                .map_err(|_| StepError::Fatal)?;
            let (h, g, k, v) = unpack4(outs);
            // KV cache + checkpoint traffic for all prompt positions.
            // Full pages whose content is already sealed in the arena are
            // *shared* (refcount bump, no write-back); the store learns of
            // them through one header-sized page ref instead of
            // `page_tokens` segments (DESIGN.md §13).
            {
                let req = self.reqs.get_mut(&id).unwrap();
                let out = req.kv.write_prompt_layer(layer, p_len, &k, &v);
                // Materializing a payload costs a pool read-back +
                // allocation — skip it entirely when not checkpointing.
                // Refs and segments are queued in *positional* order: a
                // prompt can self-share (page N repeats page M < N), and
                // the store can only resolve that ref after the earlier
                // page's segments arrived and indexed the hash.
                if self.streamer.enabled {
                    let (mut si, mut wi) = (0, 0);
                    while si < out.shared.len() || wi < out.written.len() {
                        let ns = out.shared.get(si).map_or(usize::MAX, |&(p, _)| p);
                        let nw = out.written.get(wi).copied().unwrap_or(usize::MAX);
                        if ns < nw {
                            let (first_pos, hash) = out.shared[si];
                            si += 1;
                            self.streamer.push_page_ref(id, layer as u16, first_pos as u32, hash);
                        } else {
                            wi += 1;
                            self.streamer.push_segment(SegmentMsg {
                                request: id,
                                pos: nw as u32,
                                layer: layer as u16,
                                data: req.kv.segment_payload(layer, nw),
                            });
                        }
                    }
                }
            }
            // Route + expert I/O on the valid rows.
            let probs = self
                .device
                .execute_shared(
                    &self.names.router[&bucket],
                    vec![ArgValue::f32(g.clone()), self.names.router_weights[layer].clone()],
                )
                .map_err(|_| StepError::Fatal)?;
            let routes = router::select_top_k_hotspot(&probs[0], p_len, m.top_k, self.hotspot);
            let groups = ExpertGroups::from_routes(&routes);
            let mut h = h;
            self.expert_io(layer as u32, &g, &groups, &mut h)?;
            // Zero the pad rows to keep them inert for the next layer.
            for pos in p_len..bucket {
                h.row_mut(pos).fill(0.0);
            }
            x = h;
            self.flush_ckpt();
        }

        // First token from the last prompt position (a zero-copy view).
        let last = x.row_tensor(p_len - 1);
        let token = self.lm_head(&[last])?[0];
        {
            let req = self.reqs.get_mut(&id).unwrap();
            req.kv.set_len(p_len);
            req.phase = ReqPhase::Decode;
            req.next_input = token;
            req.generated = 1;
            req.progressed = true;
        }
        self.emit_token(id, 0, token);
        self.commit(id);
        if let (Some(tr), Some(t0)) = (&self.trace, span_t0) {
            tr.record(SpanKind::Prefill, id, p_len as u64, t0);
        }
        let req = &self.reqs[&id];
        if req.generated >= req.meta.max_new_tokens {
            self.finish(id);
        } else {
            self.active.push_back(id);
        }
        Ok(())
    }

    // ---------------------------------------------------------------------
    // Decode
    // ---------------------------------------------------------------------

    /// Pre-step admission: the arena must absorb the batch's worst-case
    /// growth (one position per request, a fresh page per layer at page
    /// boundaries). Preempt lowest-progress requests until it fits; the
    /// last active request always proceeds (admission guaranteed its fit).
    fn reserve_decode_headroom(&mut self) {
        loop {
            if self.pool.free_pages().is_none() {
                return; // unbounded arena
            }
            let batch: Vec<u64> = self
                .active
                .iter()
                .copied()
                .take(self.cfg.cluster.decode_batch)
                .collect();
            if batch.is_empty() {
                return;
            }
            let needed: usize = batch
                .iter()
                .map(|id| {
                    let kv = &self.reqs[id].kv;
                    kv.pages_to_extend(kv.len() + 1)
                })
                .sum();
            let free = self.pool.free_pages().unwrap_or(usize::MAX);
            if free >= needed || self.active.len() <= 1 {
                return;
            }
            if !self.preempt_one_victim() {
                return;
            }
        }
    }

    fn decode_step(&mut self) -> Result<(), StepError> {
        // Span bookkeeping is two clock reads and a write into a
        // preallocated ring — the zero-allocation decode contract
        // (`tests/alloc.rs`) holds with tracing on.
        let span_t0 = self.trace.as_ref().map(|t| t.start());
        self.reserve_decode_headroom();
        self.steps += 1;
        let m = self.manifest.model.clone();
        // Fit-aware batch: take actives in order while their worst-case
        // page growth fits the remaining headroom (the head of the queue
        // always decodes — a lone request's admission-time fit guarantees
        // it). Skipped requests simply wait for a later step.
        let batch: Vec<u64> = {
            let free = self.pool.free_pages();
            let mut need = 0usize;
            let mut batch = Vec::new();
            for id in self.active.iter().copied() {
                if batch.len() >= self.cfg.cluster.decode_batch {
                    break;
                }
                let kv = &self.reqs[&id].kv;
                let n = kv.pages_to_extend(kv.len() + 1);
                if let Some(f) = free {
                    if !batch.is_empty() && need + n > f {
                        continue;
                    }
                }
                need += n;
                batch.push(id);
            }
            batch
        };
        let b = batch.len();
        if b == 0 {
            return Ok(());
        }
        // Rotate so other actives get the next step.
        for _ in 0..b {
            let id = self.active.pop_front().unwrap();
            self.active.push_back(id);
        }
        let bucket = Buckets::fit(&self.manifest.buckets.decode_b, b).ok_or(StepError::Fatal)?;

        // Embed last tokens.
        let mut x = Tensor::zeros(vec![bucket, m.hidden]);
        for (i, id) in batch.iter().enumerate() {
            let tok = self.reqs[id].next_input as usize;
            x.row_mut(i).copy_from_slice(self.weights.embed_row(tok));
        }

        for layer in 0..m.layers {
            // Copy-free KV gather: the artifact receives page tables plus
            // the shared arena and reads rows in place — no `[B, S, kv, d]`
            // staging copy per layer per step.
            let (paged, pos) = {
                let mut pos = Vec::new();
                let kvs: Vec<&RequestKv> = batch.iter().map(|id| &self.reqs[id].kv).collect();
                let view = self.asm.gather_paged(&self.pool, &kvs, layer, bucket, &mut pos);
                (view, pos)
            };
            let mut args = Vec::with_capacity(9);
            args.push(ArgValue::f32(x.clone()));
            args.push(ArgValue::paged_kv(paged));
            args.push(ArgValue::I32(pos, vec![bucket]));
            args.extend(self.names.attn_weights[layer].iter().cloned());
            let outs = self
                .device
                .execute_shared(&self.names.attn_decode[&bucket], args)
                .map_err(|_| StepError::Fatal)?;
            let (h, g, k_new, v_new) = unpack4(outs);
            // Append KV + queue segments.
            for (i, id) in batch.iter().enumerate() {
                let req = self.reqs.get_mut(id).unwrap();
                let cur = req.kv.len();
                req.kv.write(layer, cur, k_new.row(i), v_new.row(i));
                if self.streamer.enabled {
                    self.streamer.push_segment(SegmentMsg {
                        request: *id,
                        pos: cur as u32,
                        layer: layer as u16,
                        data: req.kv.segment_payload(layer, cur),
                    });
                }
            }
            // Route + expert I/O.
            let probs = self
                .device
                .execute_shared(
                    &self.names.router[&bucket],
                    vec![ArgValue::f32(g.clone()), self.names.router_weights[layer].clone()],
                )
                .map_err(|_| StepError::Fatal)?;
            let routes = router::select_top_k_hotspot(&probs[0], b, m.top_k, self.hotspot);
            let groups = ExpertGroups::from_routes(&routes);
            let mut h = h;
            self.expert_io(layer as u32, &g, &groups, &mut h)?;
            for i in b..bucket {
                h.row_mut(i).fill(0.0);
            }
            x = h;
        }

        // Advance lengths, emit tokens, commit.
        let rows: Vec<Tensor> = (0..b).map(|i| x.row_tensor(i)).collect();
        let tokens = self.lm_head(&rows)?;
        for (i, id) in batch.iter().enumerate() {
            let (index, token) = {
                let req = self.reqs.get_mut(id).unwrap();
                let new_len = req.kv.len() + 1;
                req.kv.set_len(new_len);
                let index = req.generated;
                req.next_input = tokens[i];
                req.generated += 1;
                req.progressed = true;
                (index, tokens[i])
            };
            self.emit_token(*id, index, token);
            self.commit(*id);
            let req = &self.reqs[id];
            if req.generated >= req.meta.max_new_tokens {
                self.finish(*id);
            }
        }
        if let (Some(tr), Some(t0)) = (&self.trace, span_t0) {
            // One span per batched step; `request` is the batch head and
            // `aux` carries the batch size.
            tr.record(SpanKind::DecodeStep, batch[0], b as u64, t0);
        }
        Ok(())
    }

    fn expert_io(
        &mut self,
        layer: u32,
        g: &Tensor,
        groups: &ExpertGroups,
        h: &mut Tensor,
    ) -> Result<(), StepError> {
        match self.refe.expert_io(layer, g, groups, h, &self.inbox, &mut self.deferred) {
            Ok(()) => Ok(()),
            Err(RefeError::LocalDown) => Err(StepError::Fatal),
            Err(RefeError::Unroutable { .. }) | Err(RefeError::CclAbort(_)) => {
                Err(StepError::Stalled)
            }
        }
    }

    /// lm_head over single-row tensors (bucketed as one batch).
    fn lm_head(&mut self, rows: &[Tensor]) -> Result<Vec<u32>, StepError> {
        let m = &self.manifest.model;
        let b = rows.len();
        let bucket = Buckets::fit(&self.manifest.buckets.lm_head_b, b).ok_or(StepError::Fatal)?;
        let mut x = Tensor::zeros(vec![bucket, m.hidden]);
        for (i, r) in rows.iter().enumerate() {
            x.row_mut(i).copy_from_slice(r.row(0));
        }
        let args = vec![
            ArgValue::f32(x),
            self.names.lm_head_weights[0].clone(),
            self.names.lm_head_weights[1].clone(),
        ];
        let outs = self
            .device
            .execute_shared(&self.names.lm_head[&bucket], args)
            .map_err(|_| StepError::Fatal)?;
        Ok((0..b).map(|i| ops::argmax(outs[0].row(i)) as u32).collect())
    }

    fn emit_token(&mut self, id: u64, index: u32, token: u32) {
        if self.track_tokens() {
            if let Some(log) = self.token_log.get_mut(&id) {
                log.tokens.push(token);
            }
        }
        let _ = self.gw_owner_qp(id).post(
            ClusterMsg::Token { request: id, index, token, worker: self.idx },
            HDR_BYTES,
            TrafficClass::Control,
        );
    }

    fn commit(&mut self, id: u64) {
        let span_t0 = self.trace.as_ref().map(|t| t.start());
        let req = &self.reqs[&id];
        let committed_pos = req.kv.len() as u32;
        self.streamer.push_commit(CommitMeta {
            request: id,
            committed_pos,
            last_token: req.next_input,
            generated: req.generated,
            max_new_tokens: req.meta.max_new_tokens,
            prompt_len: req.prompt_len,
        });
        if let (Some(tr), Some(t0)) = (&self.trace, span_t0) {
            tr.record(SpanKind::CkptCommit, id, committed_pos as u64, t0);
        }
    }

    fn finish(&mut self, id: u64) {
        if self.track_tokens() {
            if let Some(log) = self.token_log.get_mut(&id) {
                log.finished = true;
            }
        }
        let _ = self.gw_owner_qp(id).post(
            ClusterMsg::Finished { request: id, worker: self.idx },
            HDR_BYTES,
            TrafficClass::Control,
        );
        self.active.retain(|&r| r != id);
        self.reqs.remove(&id);
    }
}

#[derive(Debug)]
enum StepError {
    /// This worker is dead (device or node killed).
    Fatal,
    /// Forward progress blocked (unroutable expert / CCL abort).
    Stalled,
}

fn unpack4(mut outs: Vec<Tensor>) -> (Tensor, Tensor, Tensor, Tensor) {
    assert_eq!(outs.len(), 4);
    let v = outs.pop().unwrap();
    let k = outs.pop().unwrap();
    let g = outs.pop().unwrap();
    let h = outs.pop().unwrap();
    (h, g, k, v)
}
