//! Cluster assembly: builds the fabric, spawns the checkpoint-store
//! replicas, orchestrator (plus optional warm standby), gateway shards,
//! AWs and EWs, and exposes the fault-injection and reporting API the
//! experiments use.
//!
//! Every service thread registers with the cluster's [`Clock`] and blocks
//! only through it, so the whole cluster runs unchanged on wall time or —
//! for the scenario harness — on a deterministic virtual clock.

use super::aw::{self, AwParams};
use super::ert::Ert;
use super::ew::{self, EwParams};
use super::gateway::{self, GatewayParams, GatewayShared};
use super::orchestrator::{self, OrchParams, OrchState, RecoveryMode, StandbyParams};
use super::sched::AdmissionLimits;
use crate::checkpoint::store::CkptStore;
use crate::config::Config;
use crate::kvcache::{KvPool, PoolConfig};
use crate::metrics::trace::{Tracer, EW_TID_OFFSET, GATEWAY_TID};
use crate::metrics::{EventLog, RunAnalysis, SharingStats};
use crate::modelcfg::{weights::Weights, Manifest};
use crate::proto::ClusterMsg;
use crate::runtime::Device;
use crate::transport::{link::TrafficClass, Fabric, Inbox, NodeHandle, NodeId, Plane};
use crate::util::clock::{self, Clock};
use crate::workload::Request;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Spawner: creates workers on demand (initial bring-up, background
/// provisioning, coarse restarts). Owned by the cluster, shared with the
/// orchestrator.
pub struct Spawner {
    pub fabric: Arc<Fabric<ClusterMsg>>,
    pub manifest: Arc<Manifest>,
    pub weights: Weights,
    pub cfg: Config,
    pub stop: Arc<AtomicBool>,
    /// Cluster event log: workers record failure-lifecycle events here
    /// (the gateway records the request lifecycle through its own Arc).
    pub events: Arc<EventLog>,
    /// Span tracer, present only with `[trace] enabled = true`; workers
    /// register a preallocated ring at spawn (DESIGN.md §14).
    pub tracer: Option<Arc<Tracer>>,
    registry: Mutex<HashMap<NodeId, WorkerCtl>>,
    /// Per-AW-slot KV page arenas. The arena belongs to the host slot,
    /// not the worker thread: a respawned AW (coarse restart,
    /// provisioning) reuses the already-grown arena — warm restore.
    kv_pools: Mutex<HashMap<u32, Arc<KvPool>>>,
    /// REFE scratch-pool misses summed over all AW workers (alive and
    /// dead): each worker flushes its local counter here on thread exit,
    /// and `finish` joins every worker before reading — the zero-alloc
    /// decode gauge survives worker death and coarse restarts.
    pool_misses: Arc<AtomicU64>,
}

struct WorkerCtl {
    device: Device,
    thread: std::thread::JoinHandle<()>,
}

impl Spawner {
    /// Spawn + initialize an AW (blocking; the block *is* T_w).
    pub fn spawn_aw(&self, idx: u32, ert: Ert) -> Result<Device, String> {
        if self.stop.load(Ordering::Relaxed) {
            return Err("cluster stopping".into());
        }
        let pool = self
            .kv_pools
            .lock()
            .unwrap()
            .entry(idx)
            .or_insert_with(|| {
                // The arena carries the configured hard page budget — the
                // serving scheduler's model of per-AW GPU memory.
                KvPool::bounded(
                    PoolConfig::from_model(&self.manifest.model),
                    self.cfg.sched.kv_budget_pages,
                )
            })
            .clone();
        let (thread, device) = aw::spawn(AwParams {
            idx,
            cfg: self.cfg.clone(),
            ert,
            manifest: self.manifest.clone(),
            weights: self.weights.clone(),
            fabric: self.fabric.clone(),
            pool,
            stop: self.stop.clone(),
            events: self.events.clone(),
            trace: self.tracer.as_ref().map(|t| t.handle(idx)),
            pool_misses: self.pool_misses.clone(),
        })?;
        self.registry
            .lock()
            .unwrap()
            .insert(NodeId::Aw(idx), WorkerCtl { device: device.clone(), thread });
        Ok(device)
    }

    pub fn spawn_ew(
        &self,
        idx: u32,
        primaries: Vec<usize>,
        shadows: Vec<usize>,
        aws: Vec<u32>,
    ) -> Result<Device, String> {
        if self.stop.load(Ordering::Relaxed) {
            return Err("cluster stopping".into());
        }
        let (thread, device) = ew::spawn(EwParams {
            idx,
            primaries,
            shadows,
            initial_aws: aws,
            cfg: self.cfg.clone(),
            manifest: self.manifest.clone(),
            weights: self.weights.clone(),
            fabric: self.fabric.clone(),
            stop: self.stop.clone(),
            trace: self.tracer.as_ref().map(|t| t.handle(EW_TID_OFFSET + idx)),
        })?;
        self.registry
            .lock()
            .unwrap()
            .insert(NodeId::Ew(idx), WorkerCtl { device: device.clone(), thread });
        Ok(device)
    }

    /// Fail-stop a worker: node goes silent on the fabric and its device
    /// dies. (Both the injection path and the coarse-restart teardown.)
    pub fn kill(&self, node: NodeId) {
        self.fabric.kill(node);
        if let Some(ctl) = self.registry.lock().unwrap().get(&node) {
            ctl.device.kill();
        }
    }

    pub fn device_of(&self, node: NodeId) -> Option<Device> {
        self.registry.lock().unwrap().get(&node).map(|c| c.device.clone())
    }

    /// The KV page arena of an AW slot (experiments/introspection).
    pub fn kv_pool_of(&self, idx: u32) -> Option<Arc<KvPool>> {
        self.kv_pools.lock().unwrap().get(&idx).cloned()
    }

    /// Peak pages-in-use per AW slot arena — the budget-invariant probe
    /// the overload tests assert against.
    pub fn kv_peaks(&self) -> BTreeMap<u32, usize> {
        self.kv_pools
            .lock()
            .unwrap()
            .iter()
            .map(|(&i, p)| (i, p.peak_pages()))
            .collect()
    }

    /// Prefix-sharing counters summed across all AW slot arenas
    /// (DESIGN.md §13).
    pub fn sharing_totals(&self) -> SharingStats {
        let pools = self.kv_pools.lock().unwrap();
        let mut s = SharingStats::default();
        for p in pools.values() {
            s.prefix_hits += p.prefix_hits();
            s.cow_breaks += p.cow_breaks();
            s.pages_shared += p.pages_shared_peak() as u64;
        }
        s
    }

    /// Post an admin message as the orchestrator (provisioning threads).
    pub fn post_admin(&self, to: NodeId, msg: ClusterMsg) {
        if let Ok(qp) = self.fabric.qp(NodeId::Orchestrator, to, Plane::Control) {
            let bytes = msg.wire_bytes();
            let _ = qp.post(msg, bytes, TrafficClass::Admin);
        }
    }

    fn join_all(&self) {
        let mut reg = self.registry.lock().unwrap();
        for (_, ctl) in reg.drain() {
            ctl.device.kill();
            let _ = ctl.thread.join();
        }
    }
}

/// Launch options beyond `Config`.
#[derive(Clone)]
pub struct LaunchOptions {
    pub mode: RecoveryMode,
    pub http_port: Option<u16>,
    /// How long the gateway waits for stragglers after the last arrival.
    pub drain_timeout: Duration,
    /// Record the AW egress links' traffic (Fig. 8).
    pub record_traffic: bool,
    /// Time source for the whole cluster. `Clock::wall()` (the default)
    /// preserves real-time behavior; a virtual clock makes the run a
    /// deterministic discrete-event simulation — the caller must then be
    /// a registered clock participant before calling `Cluster::launch`.
    pub clock: Clock,
}

impl Default for LaunchOptions {
    fn default() -> Self {
        LaunchOptions {
            mode: RecoveryMode::Tarragon,
            http_port: None,
            drain_timeout: Duration::from_secs(120),
            record_traffic: false,
            clock: Clock::wall(),
        }
    }
}

pub struct Cluster {
    pub fabric: Arc<Fabric<ClusterMsg>>,
    pub spawner: Arc<Spawner>,
    pub state: Arc<OrchState>,
    pub events: Arc<EventLog>,
    /// Present only with `[trace] enabled = true`.
    pub tracer: Option<Arc<Tracer>>,
    pub gw: Arc<GatewayShared>,
    /// Checkpoint-store replicas (DESIGN.md §15); `store` aliases replica
    /// 0 for the single-store callers.
    pub stores: Vec<Arc<Mutex<CkptStore>>>,
    pub store: Arc<Mutex<CkptStore>>,
    clock: Clock,
    stop: Arc<AtomicBool>,
    /// Service threads (stores, orchestrator(+standby), gateways). Behind
    /// a mutex so `respawn_store` can add the rebuilt replica's thread.
    service_threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    pub initial_aws: Vec<u32>,
    pub initial_ews: Vec<u32>,
    /// Initial (ew, primaries, shadows) layout — the respawn template.
    ew_specs: Vec<(u32, Vec<usize>, Vec<usize>)>,
    num_stores: usize,
    num_gateways: usize,
}

/// Summary returned by `Cluster::finish`.
pub struct ClusterReport {
    pub analysis: RunAnalysis,
    pub submitted: usize,
    pub finished: usize,
    pub aw_failures: u64,
    pub ew_failures: u64,
    pub restarts: u64,
    /// Requests preempted under KV pressure or planned drains.
    pub preemptions: u64,
    /// Requests rejected at admission (oversized).
    pub rejected: usize,
    /// Elastic EW scaling (DESIGN.md §11): fresh EWs provisioned, EWs
    /// retired, shadow promotions, and scale-in refusals of any kind —
    /// last-replica guard, dead/unknown target, or the fabric-liveness
    /// coverage check.
    pub scale_outs: u64,
    pub scale_ins: u64,
    pub shadow_promotions: u64,
    pub scale_rejected: u64,
    /// Control-plane failovers survived (DESIGN.md §15): store-replica
    /// deaths, gateway-shard deaths, standby orchestrator promotions.
    pub store_failovers: u64,
    pub gateway_failovers: u64,
    pub orch_promotions: u64,
    /// Accepted-commit spread (max − min) across live store replicas at
    /// run end — 0 when the replicas agree (or K = 1).
    pub store_replica_lag: u64,
    /// KV prefix-sharing counters summed over all AW arenas (§13):
    /// prefill page hits, CoW privatizations, peak pages shared.
    pub sharing: SharingStats,
    /// REFE scratch-pool misses summed over all AW workers — dispatches
    /// that had to allocate because the recycled-vector pool underflowed
    /// (or held only undersized vectors). Zero in steady state: the
    /// zero-alloc decode gauge.
    pub pool_misses: u64,
}

/// Service loop of one checkpoint-store replica: handle messages, post
/// the replies the store computed. Shared by initial bring-up and the
/// `respawn_store` rebuild path.
fn spawn_store_thread(
    idx: u32,
    store: Arc<Mutex<CkptStore>>,
    inbox: Inbox<ClusterMsg>,
    handle: NodeHandle,
    fabric: Arc<Fabric<ClusterMsg>>,
    stop: Arc<AtomicBool>,
    clock: &Clock,
) -> std::thread::JoinHandle<()> {
    clock::spawn_participant(clock, format!("ckpt-store{idx}"), move || {
        let mut qps: HashMap<NodeId, crate::transport::Qp<ClusterMsg>> = HashMap::new();
        while !stop.load(Ordering::Relaxed) && handle.is_alive() {
            match inbox.recv(Duration::from_millis(2)) {
                Ok(env) => {
                    let replies = store.lock().unwrap().handle(env.from, env.msg);
                    for (to, msg) in replies {
                        let class = match &msg {
                            ClusterMsg::Restore(_) => TrafficClass::Restore,
                            _ => TrafficClass::Admin,
                        };
                        let bytes = msg.wire_bytes();
                        let qp = qps.entry(to).or_insert_with(|| {
                            fabric.qp(NodeId::Store(idx), to, Plane::Data).expect("qp")
                        });
                        let _ = qp.post(msg, bytes, class);
                    }
                }
                Err(crate::transport::QpError::Timeout) => {}
                Err(_) => break,
            }
        }
    })
    .expect("store thread")
}

impl Cluster {
    /// Build and start the full cluster; returns once every worker is
    /// initialized and the gateways are running the schedule.
    pub fn launch(
        cfg: Config,
        manifest: Arc<Manifest>,
        weights: Weights,
        schedule: Vec<Request>,
        opts: LaunchOptions,
    ) -> Cluster {
        let clock = opts.clock.clone();
        let fabric: Arc<Fabric<ClusterMsg>> =
            Fabric::with_clock(cfg.transport.clone(), clock.clone());
        let stop = Arc::new(AtomicBool::new(false));
        let gw_shared = Arc::new(GatewayShared::default());
        // Event log and span tracer exist before any worker spawns, so
        // every role holds its recording handle from birth. The epoch is
        // rebased to the schedule start below — bring-up records nothing,
        // so run timelines are unchanged by the early creation.
        let events = Arc::new(EventLog::with_clock_capacity(
            clock.clone(),
            cfg.trace.event_capacity,
        ));
        let tracer =
            cfg.trace.enabled.then(|| Tracer::new(clock.clone(), cfg.trace.ring_capacity));
        let spawner = Arc::new(Spawner {
            fabric: fabric.clone(),
            manifest: manifest.clone(),
            weights: weights.clone(),
            cfg: cfg.clone(),
            stop: stop.clone(),
            events: events.clone(),
            tracer: tracer.clone(),
            registry: Mutex::new(HashMap::new()),
            kv_pools: Mutex::new(HashMap::new()),
            pool_misses: Arc::new(AtomicU64::new(0)),
        });

        let num_stores = cfg.cluster.num_stores.max(1);
        let num_gateways = cfg.cluster.num_gateways.max(1);

        // --- checkpoint store replicas (their own nodes, §7.1/§15) -----
        // Each replica's page content index must use the same page
        // geometry as the AW arenas, or prefill page refs never resolve.
        // AWs fan commits out to every replica, so each holds the full
        // durable state independently.
        let mut service_threads = Vec::new();
        let mut stores = Vec::new();
        for k in 0..num_stores as u32 {
            let store = Arc::new(Mutex::new(CkptStore::with_page_tokens(
                manifest.model.layers,
                PoolConfig::from_model(&manifest.model).page_tokens,
            )));
            let (inbox, handle) = fabric.register(NodeId::Store(k));
            service_threads.push(spawn_store_thread(
                k,
                store.clone(),
                inbox,
                handle,
                fabric.clone(),
                stop.clone(),
                &clock,
            ));
            stores.push(store);
        }

        // Pre-register the static service nodes so workers can create QPs
        // toward them during their own init.
        let (orch_inbox, _orch_handle) = fabric.register(NodeId::Orchestrator);
        let mut gw_inboxes = Vec::new();
        for g in 0..num_gateways as u32 {
            let (inbox, _handle) = fabric.register(NodeId::Gateway(g));
            gw_inboxes.push(inbox);
        }

        // --- expert layout + initial ERT --------------------------------
        let e = manifest.model.experts;
        let n_ews = cfg.cluster.num_ews;
        let ert = Ert::initial(e, n_ews, cfg.resilience.shadow_experts);
        let initial_aws: Vec<u32> = (0..cfg.cluster.num_aws as u32).collect();
        let mut ew_specs: Vec<(u32, Vec<usize>, Vec<usize>)> = Vec::new();
        for i in 0..n_ews as u32 {
            let primaries: Vec<usize> = (0..e).filter(|x| x % n_ews == i as usize).collect();
            // Ring shadows: EW i shadows the primaries of EW (i-1).
            let prev = ((i as usize + n_ews) - 1) % n_ews;
            let shadows: Vec<usize> = if cfg.resilience.shadow_experts {
                (0..e).filter(|x| x % n_ews == prev).collect()
            } else {
                Vec::new()
            };
            ew_specs.push((i, primaries, shadows));
        }

        // --- orchestrator (+ optional warm standby) ----------------------
        let state = Arc::new(OrchState::default());
        service_threads.push(orchestrator::spawn(OrchParams {
            inbox: orch_inbox,
            mode: opts.mode,
            spawner: spawner.clone(),
            state: state.clone(),
            initial_ert: ert.clone(),
            initial_aws: initial_aws.clone(),
            initial_ews: ew_specs.clone(),
            num_stores,
            num_gateways,
            sync_standby: cfg.resilience.orch_standby,
            stop: stop.clone(),
            http_port: opts.http_port,
        }));
        if cfg.resilience.orch_standby {
            let (standby_inbox, _standby_handle) = fabric.register(NodeId::OrchStandby);
            service_threads.push(orchestrator::spawn_standby(StandbyParams {
                inbox: standby_inbox,
                mode: opts.mode,
                spawner: spawner.clone(),
                state: state.clone(),
                stop: stop.clone(),
            }));
        }

        // --- workers (parallel bring-up) ---------------------------------
        // Helper threads report through a clock channel (a raw `join` on a
        // clock participant would deadlock virtual time), then are joined
        // once their result is in.
        let (done_tx, done_rx) = clock::channel::<Result<(), String>>(&clock);
        let mut joins = Vec::new();
        for (i, prim, shad) in ew_specs.clone() {
            let spawner = spawner.clone();
            let aws = initial_aws.clone();
            let tx = done_tx.clone();
            joins.push(
                clock::spawn_participant(&clock, format!("bringup-ew{i}"), move || {
                    let _ = tx.send(spawner.spawn_ew(i, prim, shad, aws).map(|_| ()));
                })
                .expect("bring-up thread"),
            );
        }
        for &i in &initial_aws {
            let spawner = spawner.clone();
            let e = ert.clone();
            let tx = done_tx.clone();
            joins.push(
                clock::spawn_participant(&clock, format!("bringup-aw{i}"), move || {
                    let _ = tx.send(spawner.spawn_aw(i, e).map(|_| ()));
                })
                .expect("bring-up thread"),
            );
        }
        drop(done_tx);
        for _ in 0..joins.len() {
            done_rx.recv().expect("bring-up thread").expect("worker init");
        }
        for j in joins {
            let _ = j.join();
        }

        if opts.record_traffic {
            for &i in &initial_aws {
                if let Some(l) = fabric.egress_of(NodeId::Aw(i)) {
                    l.enable_recording();
                }
            }
        }

        // --- gateway shards ------------------------------------------------
        // The event epoch starts here: t=0 is the schedule start (worker
        // bring-up above is excluded from run timelines; T_w is reported
        // separately via InitStats). Every shard sees the full schedule
        // and admits only the requests it owns under the consistent hash;
        // all shards merge into one `GatewayShared`.
        events.rebase();
        if let Some(t) = &tracer {
            t.rebase();
        }
        state.attach_events(events.clone());
        let pool_cfg = PoolConfig::from_model(&manifest.model);
        let limits = AdmissionLimits {
            max_prompt: manifest
                .buckets
                .prefill_t
                .iter()
                .copied()
                .max()
                .unwrap_or(manifest.model.max_seq),
            max_seq: manifest.model.max_seq,
            layers: manifest.model.layers,
            page_tokens: pool_cfg.page_tokens,
            budget_pages: cfg.sched.kv_budget_pages,
        };
        for (g, inbox) in gw_inboxes.into_iter().enumerate() {
            service_threads.push(gateway::spawn(GatewayParams {
                shard: g as u32,
                num_shards: num_gateways,
                num_stores,
                inbox,
                schedule: schedule.clone(),
                initial_aws: initial_aws.clone(),
                fabric: fabric.clone(),
                events: events.clone(),
                trace: tracer.as_ref().map(|t| t.handle(GATEWAY_TID + g as u32)),
                shared: gw_shared.clone(),
                stop: stop.clone(),
                drain_timeout: opts.drain_timeout,
                sched: cfg.sched.clone(),
                limits: limits.clone(),
                max_per_aw: cfg.cluster.max_resident,
            }));
        }

        let store = stores[0].clone();
        Cluster {
            fabric,
            spawner,
            state,
            events,
            tracer,
            gw: gw_shared,
            stores,
            store,
            clock,
            stop,
            service_threads: Mutex::new(service_threads),
            initial_aws,
            initial_ews: ew_specs.iter().map(|(i, _, _)| *i).collect(),
            ew_specs,
            num_stores,
            num_gateways,
        }
    }

    /// The cluster's time source.
    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Fail-stop injection (the SIGINT of §7.2).
    pub fn kill_aw(&self, idx: u32) {
        self.spawner.kill(NodeId::Aw(idx));
    }

    /// Gracefully drain an AW: stop routing new requests to it and
    /// migrate every resident request off it through the checkpoint path
    /// (scale-in / maintenance — the scenario DSL's `drain aw<N>`).
    pub fn drain_aw(&self, idx: u32) {
        self.post_admin_verb(ClusterMsg::DrainAw { aw: idx, target: None });
    }

    /// Drain `from`, steering every migrated request onto `to`
    /// (the scenario DSL's `migrate aw<A> aw<B>`).
    pub fn migrate_aw(&self, from: u32, to: u32) {
        self.post_admin_verb(ClusterMsg::DrainAw { aw: from, target: Some(to) });
    }

    /// The lowest live gateway shard — the cluster's external entry point
    /// for admin verbs (falls back to shard 0 if none is live).
    fn entry_gateway(&self) -> NodeId {
        (0..self.num_gateways as u32)
            .map(NodeId::Gateway)
            .find(|&g| self.fabric.is_alive(g))
            .unwrap_or(NodeId::Gateway(0))
    }

    fn post_as_gateway(&self, to: NodeId, msg: ClusterMsg) {
        if let Ok(qp) = self.fabric.qp(self.entry_gateway(), to, Plane::Control) {
            let bytes = msg.wire_bytes();
            let _ = qp.post(msg, bytes, TrafficClass::Admin);
        }
    }

    /// Post an admin-plane verb to the orchestrator (as a gateway node,
    /// the cluster's external entry point).
    fn post_admin_verb(&self, msg: ClusterMsg) {
        self.post_as_gateway(NodeId::Orchestrator, msg);
    }

    pub fn kill_ew(&self, idx: u32) {
        self.spawner.kill(NodeId::Ew(idx));
    }

    /// Fail-stop a checkpoint-store replica (DESIGN.md §15): the node
    /// goes silent; AWs keep committing to the survivors and parked
    /// restore pulls are re-driven against them.
    pub fn kill_store(&self, idx: u32) {
        self.fabric.kill(NodeId::Store(idx));
    }

    /// Fail-stop a gateway shard. Its recorded streams live in the
    /// shared gateway state; the orchestrator rebinds its in-flight
    /// requests and the survivors re-admit the rest.
    pub fn kill_gateway(&self, idx: u32) {
        self.fabric.kill(NodeId::Gateway(idx));
    }

    /// Fail-stop the active orchestrator. With `orch_standby` enabled the
    /// standby detects the silence and promotes itself.
    pub fn kill_orch(&self) {
        self.fabric.kill(NodeId::Orchestrator);
    }

    /// Planned orchestrator handover (the scenario DSL's `promote orch`):
    /// ask the standby to take over; it demotes the active first and only
    /// assumes the role once the demotion is acked.
    pub fn promote_orch(&self) {
        self.post_as_gateway(NodeId::OrchStandby, ClusterMsg::PromoteOrch);
    }

    /// Drop replica `idx`'s sealed-page content index (keeps the commit
    /// log) — the `page_refs_missed` degradation fault: restores fall
    /// back to recompute/resubmit instead of page-ref resolution.
    pub fn corrupt_store_index(&self, idx: u32) {
        if let Some(s) = self.stores.get(idx as usize) {
            s.lock().unwrap().log.drop_page_index();
        }
    }

    /// Rebuild a previously killed store replica on its original slot:
    /// fresh empty state, new service thread (re-registration swaps a new
    /// inbox under every existing QP toward the node id), then an
    /// anti-entropy pull from the lowest live peer re-syncs the full
    /// durable state.
    pub fn respawn_store(&self, idx: u32) -> Result<(), String> {
        if (idx as usize) >= self.num_stores {
            return Err(format!("store{idx} was not part of the initial layout"));
        }
        let store = self.stores[idx as usize].clone();
        *store.lock().unwrap() = CkptStore::with_page_tokens(
            self.spawner.manifest.model.layers,
            PoolConfig::from_model(&self.spawner.manifest.model).page_tokens,
        );
        let (inbox, handle) = self.fabric.register(NodeId::Store(idx));
        self.service_threads.lock().unwrap().push(spawn_store_thread(
            idx,
            store,
            inbox,
            handle,
            self.fabric.clone(),
            self.stop.clone(),
            &self.clock,
        ));
        // Anti-entropy: pull the full snapshot from a surviving peer.
        if let Some(peer) = (0..self.num_stores as u32)
            .filter(|&p| p != idx)
            .find(|&p| self.fabric.is_alive(NodeId::Store(p)))
        {
            if let Ok(qp) =
                self.fabric.qp(NodeId::Store(idx), NodeId::Store(peer), Plane::Data)
            {
                let msg = ClusterMsg::StoreSyncPull { from: idx };
                let bytes = msg.wire_bytes();
                let _ = qp.post(msg, bytes, TrafficClass::Admin);
            }
        }
        self.state.set_store_alive(idx, true);
        self.state.clear_handled(NodeId::Store(idx));
        Ok(())
    }

    /// Manual scale-out (the scenario DSL's `scale_ew up`): provision one
    /// fresh EW as a warm tail candidate (shadow) for every expert.
    pub fn scale_ew_up(&self) {
        self.post_admin_verb(ClusterMsg::ScaleEwUp);
    }

    /// Manual scale-in (the scenario DSL's `scale_ew down ew<N>`): remap
    /// the EW's primaries onto the remaining candidates and retire it.
    /// Rejected by the orchestrator (reflected in
    /// [`ClusterReport::scale_rejected`]) if the EW is the last replica
    /// of any expert — a scale-in can demote, never strand.
    pub fn scale_ew_down(&self, idx: u32) {
        self.post_admin_verb(ClusterMsg::ScaleEwDown { ew: idx });
    }

    /// Respawn a previously killed AW on its original slot and integrate
    /// it (membership broadcast) — the scenario DSL's `respawn aw<i>`.
    pub fn respawn_aw(&self, idx: u32) -> Result<(), String> {
        let ert = self.state.current_ert().ok_or("orchestrator has no ERT yet")?;
        self.spawner.spawn_aw(idx, ert)?;
        let live = self.state.integrate_aw(idx);
        for e in self.state.live_ews() {
            self.spawner.post_admin(NodeId::Ew(e), ClusterMsg::AwSet { aws: live.clone() });
        }
        // The gateway's routing set excludes draining AWs.
        let gw_aws = self.state.gateway_aws();
        for g in self.state.live_gateways() {
            self.spawner
                .post_admin(NodeId::Gateway(g), ClusterMsg::AwSet { aws: gw_aws.clone() });
        }
        self.state.clear_handled(NodeId::Aw(idx));
        Ok(())
    }

    /// Respawn a previously killed EW on its original slot with its
    /// initial expert layout, re-promoting it in the ERT.
    pub fn respawn_ew(&self, idx: u32) -> Result<(), String> {
        let (_, primaries, shadows) = self
            .ew_specs
            .iter()
            .find(|(i, _, _)| *i == idx)
            .cloned()
            .ok_or_else(|| format!("ew{idx} was not part of the initial layout"))?;
        let aws = self.state.live_aws();
        self.spawner.spawn_ew(idx, primaries.clone(), shadows.clone(), aws)?;
        let (table, version, live_aws) = self
            .state
            .integrate_ew(idx, primaries, shadows)
            .ok_or("orchestrator has no ERT yet")?;
        for a in live_aws {
            self.spawner
                .post_admin(NodeId::Aw(a), ClusterMsg::ErtUpdate { version, table: table.clone() });
        }
        self.state.clear_handled(NodeId::Ew(idx));
        Ok(())
    }

    /// Wait until the gateway drains (or `timeout`). Returns whether the
    /// workload completed. Under a virtual clock the caller must be a
    /// registered participant; the timeout is virtual time.
    pub fn wait_done(&self, timeout: Duration) -> bool {
        let deadline = self.clock.now() + timeout;
        while self.clock.now() < deadline {
            if self.gw.done.load(Ordering::Acquire) {
                return true;
            }
            self.clock.sleep(Duration::from_millis(20));
        }
        self.gw.done.load(Ordering::Acquire)
    }

    /// Stop everything and produce the run report.
    pub fn finish(self, window_secs: f64) -> ClusterReport {
        self.stop.store(true, Ordering::Release);
        // Replica lag is sampled before teardown, over live replicas only
        // (a killed replica is not lag — its state died with it).
        let store_replica_lag = if self.num_stores > 1 {
            let accepted: Vec<u64> = (0..self.num_stores as u32)
                .filter(|&k| self.fabric.is_alive(NodeId::Store(k)))
                .map(|k| self.stores[k as usize].lock().unwrap().log.commits_accepted)
                .collect();
            match (accepted.iter().max(), accepted.iter().min()) {
                (Some(max), Some(min)) => max - min,
                _ => 0,
            }
        } else {
            0
        };
        // Free-run teardown: participants drain on real time from here.
        self.clock.shutdown();
        for t in self.service_threads.lock().unwrap().drain(..) {
            let _ = t.join();
        }
        self.spawner.join_all();
        ClusterReport {
            analysis: RunAnalysis::from_log(&self.events, window_secs),
            submitted: self.gw.submitted(),
            finished: self.gw.finished(),
            aw_failures: self.state.aw_failures.load(Ordering::Relaxed),
            ew_failures: self.state.ew_failures.load(Ordering::Relaxed),
            restarts: self.state.restarts.load(Ordering::Relaxed),
            preemptions: self.state.preemptions.load(Ordering::Relaxed),
            rejected: self.gw.rejected_count(),
            scale_outs: self.state.scale_outs.load(Ordering::Relaxed),
            scale_ins: self.state.scale_ins.load(Ordering::Relaxed),
            shadow_promotions: self.state.shadow_promotions.load(Ordering::Relaxed),
            scale_rejected: self.state.scale_rejected.load(Ordering::Relaxed),
            store_failovers: self.state.store_failovers.load(Ordering::Relaxed),
            gateway_failovers: self.state.gateway_failovers.load(Ordering::Relaxed),
            orch_promotions: self.state.orch_promotions.load(Ordering::Relaxed),
            store_replica_lag,
            sharing: self.spawner.sharing_totals(),
            pool_misses: self.spawner.pool_misses.load(Ordering::Relaxed),
        }
    }
}
