//! Overload-aware serving scheduler: policy + pressure bookkeeping
//! (DESIGN.md §9).
//!
//! The same machinery that masks failures — checkpoint, evict, adopt,
//! restore — doubles as a general request-mobility datapath for
//! steady-state load management. This module holds the *policy* side:
//!
//! - [`AwLoad`] / [`LoadMap`]: per-AW pressure + queue-depth bookkeeping,
//!   fed by the AWs' [`Status`](crate::proto::ClusterMsg::Status) beacons
//!   and optimistically bumped by the gateway between beacons;
//! - [`Router`]: the pluggable admission router (least-pressure /
//!   join-shortest-queue / round-robin fallback) with watermark-based
//!   backpressure — `pick` returns `None` when every candidate is
//!   saturated, and the request *waits at the gateway* instead of landing
//!   on a full AW;
//! - [`AdmissionLimits`]: the static fit checks that reject oversized
//!   prompts at admission instead of dropping them silently on the AW;
//! - [`pick_victim`]: the preemption policy (lowest progress first).
//!
//! The *mechanism* side lives with its owners: the AW preempts (flush
//! segments → evict pages → notify), the orchestrator parks and re-admits
//! via the existing `AdoptRequest`/restore path, and the gateway queues.
//! Everything here is deterministic: candidate sets iterate in ascending
//! AW order and every tie breaks toward the lowest id, so scenario
//! replays are byte-identical.

use crate::config::RouterPolicy;
use crate::proto::AwStatus;
use std::collections::BTreeMap;

/// One AW's load as last reported by its beacon, plus the gateway's
/// optimistic in-flight accounting between beacons.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AwLoad {
    pub pages_in_use: u32,
    /// Arena page budget (0 = unbounded).
    pub pages_budget: u32,
    /// Prefill queue + active decode set.
    pub queue_depth: u32,
    /// Resident requests (any phase).
    pub resident: u32,
}

impl AwLoad {
    pub fn from_status(st: &AwStatus) -> AwLoad {
        AwLoad {
            pages_in_use: st.pages_in_use,
            pages_budget: st.pages_budget,
            queue_depth: st.queue_depth,
            resident: st.resident,
        }
    }

    /// KV memory pressure (0.0 when the arena is unbounded).
    pub fn pressure(&self) -> f64 {
        crate::proto::kv_pressure(self.pages_in_use, self.pages_budget)
    }
}

/// One tracked AW: the beacon-reported baseline plus *signed* optimistic
/// deltas applied between beacons. Signed deltas make a double-release
/// observable — the old representation clamped each decrement with
/// `saturating_sub(1)` directly on the stored `u32`s, so an unpaired
/// departure silently vanished and a subsequent submit re-inflated the
/// estimate from the wrong floor, skewing load-based routing.
#[derive(Debug, Clone, Copy, Default)]
struct LoadEntry {
    reported: AwLoad,
    d_queue: i64,
    d_resident: i64,
    d_pages: i64,
}

fn clamp_add(base: u32, delta: i64) -> u32 {
    (base as i64 + delta).clamp(0, u32::MAX as i64) as u32
}

impl LoadEntry {
    /// Externally-visible estimate (clamped at zero, like the old map).
    fn view(&self) -> AwLoad {
        AwLoad {
            pages_in_use: clamp_add(self.reported.pages_in_use, self.d_pages),
            pages_budget: self.reported.pages_budget,
            queue_depth: clamp_add(self.reported.queue_depth, self.d_queue),
            resident: clamp_add(self.reported.resident, self.d_resident),
        }
    }
}

/// Per-AW load map. Ordered so iteration — and therefore every placement
/// decision derived from it — is deterministic.
#[derive(Debug, Default)]
pub struct LoadMap {
    loads: BTreeMap<u32, LoadEntry>,
    /// Assert release/submit pairing instead of merely counting it. Only
    /// sound where beacons cannot race optimistic bumps (the
    /// single-threaded macro-sim); in the threaded gateway a beacon
    /// snapshotted just before a dispatch legitimately resets the
    /// submit's delta, so the matching departure *looks* unpaired.
    strict: bool,
    /// Departures that could not be paired with a resident request or an
    /// optimistic submit — each one is a suspected double-release.
    unpaired_departures: u64,
}

impl LoadMap {
    /// Strict pairing mode for deterministic single-threaded drivers:
    /// any unpaired departure becomes a debug-assert failure.
    pub fn strict() -> LoadMap {
        LoadMap { strict: true, ..LoadMap::default() }
    }

    /// Suspected double-releases observed so far (see [`LoadMap::strict`]).
    pub fn unpaired_departures(&self) -> u64 {
        self.unpaired_departures
    }

    pub fn update(&mut self, aw: u32, load: AwLoad) {
        // A fresh beacon is authoritative: it already includes every
        // dispatch/departure the AW has seen, so the deltas reset.
        self.loads.insert(aw, LoadEntry { reported: load, ..LoadEntry::default() });
    }

    /// The last known load of an AW (zero/unknown if never reported —
    /// a fresh AW is assumed admissible until its first beacon).
    pub fn get(&self, aw: u32) -> AwLoad {
        self.loads.get(&aw).map(|e| e.view()).unwrap_or_default()
    }

    pub fn remove(&mut self, aw: u32) {
        self.loads.remove(&aw);
    }

    /// Optimistic bump between beacons: one request was just routed to
    /// `aw`. The next beacon overwrites the estimate.
    pub fn note_submit(&mut self, aw: u32) {
        let e = self.loads.entry(aw).or_default();
        e.d_queue += 1;
        e.d_resident += 1;
    }

    /// Optimistic decrement: a request on `aw` finished or was evicted.
    /// Flags (and in strict mode asserts) decrements that cannot pair
    /// with any tracked resident or optimistic submit.
    pub fn note_departure(&mut self, aw: u32) {
        match self.loads.get_mut(&aw) {
            Some(e) => {
                e.d_queue -= 1;
                e.d_resident -= 1;
                if e.reported.resident as i64 + e.d_resident < 0 {
                    self.unpaired_departures += 1;
                    debug_assert!(
                        !self.strict,
                        "unpaired departure on AW {aw}: more releases than \
                         residents + optimistic submits (double-release?)"
                    );
                }
            }
            None => {
                self.unpaired_departures += 1;
                debug_assert!(
                    !self.strict,
                    "departure for untracked AW {aw} (double-release after removal?)"
                );
            }
        }
    }

    /// Optimistic page bump: a restore with this footprint was just
    /// dispatched to `aw` (anti-thrash accounting between beacons).
    pub fn note_pages(&mut self, aw: u32, pages: u32) {
        self.loads.entry(aw).or_default().d_pages += pages as i64;
    }
}

/// Admission/preemption/re-admission hysteresis band: new work is gated
/// at `high`, preemption triggers at `high`, parked requests re-admit
/// below `low`.
#[derive(Debug, Clone, Copy)]
pub struct Watermarks {
    pub high: f64,
    pub low: f64,
}

/// The gateway's pluggable admission router.
pub struct Router {
    policy: RouterPolicy,
    marks: Watermarks,
    /// Per-AW resident cap (0 = uncapped) — the JSQ admission bound.
    max_per_aw: usize,
    rr: usize,
}

impl Router {
    pub fn new(policy: RouterPolicy, marks: Watermarks, max_per_aw: usize) -> Router {
        Router { policy, marks, max_per_aw, rr: 0 }
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Pick a target among `live` (ascending AW ids). Returns `None`
    /// when every candidate is saturated — backpressure: the request
    /// waits at the gateway and the caller retries after the next beacon.
    pub fn pick(&mut self, live: &[u32], loads: &LoadMap) -> Option<u32> {
        let cands: Vec<(u32, AwLoad)> = live
            .iter()
            .map(|&a| (a, loads.get(a)))
            .filter(|(_, l)| self.admissible(l))
            .collect();
        if cands.is_empty() {
            return None;
        }
        let aw = match self.policy {
            RouterPolicy::RoundRobin => cands[self.rr % cands.len()].0,
            RouterPolicy::LeastPressure => best_of(&cands, |a, b| {
                a.1.pressure()
                    .partial_cmp(&b.1.pressure())
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.1.queue_depth.cmp(&b.1.queue_depth))
                    .then(a.0.cmp(&b.0))
            }),
            RouterPolicy::JoinShortestQueue => best_of(&cands, |a, b| {
                a.1.queue_depth.cmp(&b.1.queue_depth).then(a.0.cmp(&b.0))
            }),
        };
        self.rr += 1;
        Some(aw)
    }

    fn admissible(&self, l: &AwLoad) -> bool {
        if self.max_per_aw > 0 && l.resident as usize >= self.max_per_aw {
            return false;
        }
        l.pages_budget == 0 || l.pressure() < self.marks.high
    }
}

fn best_of<F>(cands: &[(u32, AwLoad)], mut cmp: F) -> u32
where
    F: FnMut(&(u32, AwLoad), &(u32, AwLoad)) -> std::cmp::Ordering,
{
    cands
        .iter()
        .min_by(|a, b| cmp(a, b))
        .map(|(a, _)| *a)
        .expect("best_of over a non-empty candidate set")
}

/// Static admission limits the gateway enforces at arrival time (derived
/// from the model manifest + sched config when the cluster is built).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionLimits {
    /// Largest prefill bucket: longer prompts cannot be executed.
    pub max_prompt: usize,
    /// KV capacity in token positions.
    pub max_seq: usize,
    /// Model layers (for worst-case page math).
    pub layers: usize,
    /// KV pool page size in tokens.
    pub page_tokens: usize,
    /// Per-AW page budget (0 = unbounded).
    pub budget_pages: usize,
}

impl AdmissionLimits {
    /// Why this request can never be served, if oversized; `None` when it
    /// is admissible.
    pub fn reject_reason(&self, prompt_len: usize, max_new: usize) -> Option<String> {
        if prompt_len == 0 {
            return Some("empty prompt".into());
        }
        if prompt_len > self.max_prompt {
            return Some(format!(
                "prompt length {prompt_len} exceeds the largest prefill bucket ({})",
                self.max_prompt
            ));
        }
        if prompt_len + max_new > self.max_seq {
            return Some(format!(
                "prompt ({prompt_len}) + max_new_tokens ({max_new}) exceeds max_seq ({})",
                self.max_seq
            ));
        }
        if self.budget_pages > 0 {
            // Deliberately worst-case *physical* page math: prefix sharing
            // (DESIGN.md §13) may later satisfy part of the prompt with
            // refcount bumps, but admission cannot assume a hit — a shared
            // page can be privatized (CoW) or its last co-holder evicted at
            // any time, at which point the request must still fit alone.
            let pages =
                crate::kvcache::pages_for_tokens(prompt_len + max_new, self.page_tokens, self.layers);
            if pages > self.budget_pages {
                return Some(format!(
                    "worst-case KV footprint ({pages} pages) exceeds the per-AW budget ({})",
                    self.budget_pages
                ));
            }
        }
        None
    }
}

/// Preemption victim selection: the lowest-progress request — fewest
/// generated tokens, ties toward the lowest id (deterministic).
pub fn pick_victim<I: IntoIterator<Item = (u64, u32)>>(candidates: I) -> Option<u64> {
    candidates
        .into_iter()
        .min_by_key(|&(id, generated)| (generated, id))
        .map(|(id, _)| id)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn marks() -> Watermarks {
        Watermarks { high: 0.85, low: 0.60 }
    }

    fn load(pages: u32, budget: u32, depth: u32) -> AwLoad {
        AwLoad { pages_in_use: pages, pages_budget: budget, queue_depth: depth, resident: depth }
    }

    #[test]
    fn least_pressure_prefers_the_emptier_aw() {
        let mut loads = LoadMap::default();
        loads.update(0, load(8, 10, 3));
        loads.update(1, load(2, 10, 5));
        let mut r = Router::new(RouterPolicy::LeastPressure, marks(), 0);
        assert_eq!(r.pick(&[0, 1], &loads), Some(1));
    }

    #[test]
    fn least_pressure_ties_break_on_queue_then_id() {
        let mut loads = LoadMap::default();
        loads.update(0, load(0, 0, 4));
        loads.update(1, load(0, 0, 1));
        let mut r = Router::new(RouterPolicy::LeastPressure, marks(), 0);
        // Unbounded arenas: pressure ties at 0.0, queue depth decides.
        assert_eq!(r.pick(&[0, 1], &loads), Some(1));
        loads.update(1, load(0, 0, 4));
        assert_eq!(r.pick(&[0, 1], &loads), Some(0), "full tie goes to the lowest id");
    }

    #[test]
    fn jsq_picks_shortest_queue() {
        let mut loads = LoadMap::default();
        loads.update(0, load(9, 10, 1));
        loads.update(1, load(1, 10, 6));
        let mut r = Router::new(RouterPolicy::JoinShortestQueue, marks(), 0);
        assert_eq!(r.pick(&[0, 1], &loads), Some(0));
    }

    #[test]
    fn round_robin_rotates_over_admissible() {
        let loads = LoadMap::default();
        let mut r = Router::new(RouterPolicy::RoundRobin, marks(), 0);
        assert_eq!(r.pick(&[3, 5], &loads), Some(3));
        assert_eq!(r.pick(&[3, 5], &loads), Some(5));
        assert_eq!(r.pick(&[3, 5], &loads), Some(3));
    }

    #[test]
    fn high_watermark_gates_admission_and_backpressures() {
        let mut loads = LoadMap::default();
        loads.update(0, load(9, 10, 1)); // 0.9 >= 0.85: saturated
        loads.update(1, load(8, 10, 1)); // 0.8 < 0.85: admissible
        let mut r = Router::new(RouterPolicy::LeastPressure, marks(), 0);
        assert_eq!(r.pick(&[0, 1], &loads), Some(1));
        loads.update(1, load(9, 10, 1));
        assert_eq!(r.pick(&[0, 1], &loads), None, "all saturated: queue at the gateway");
        assert_eq!(r.pick(&[], &loads), None, "no live AWs: queue at the gateway");
    }

    #[test]
    fn resident_cap_gates_admission() {
        let mut loads = LoadMap::default();
        loads.update(0, load(0, 0, 2));
        let mut r = Router::new(RouterPolicy::LeastPressure, marks(), 2);
        assert_eq!(r.pick(&[0], &loads), None);
        loads.note_departure(0);
        assert_eq!(r.pick(&[0], &loads), Some(0));
    }

    #[test]
    fn optimistic_bumps_spread_between_beacons() {
        let mut loads = LoadMap::default();
        let mut r = Router::new(RouterPolicy::LeastPressure, marks(), 0);
        let a = r.pick(&[0, 1], &loads).unwrap();
        assert_eq!(a, 0);
        loads.note_submit(a);
        // Before any beacon arrives the bump steers the next request away.
        assert_eq!(r.pick(&[0, 1], &loads), Some(1));
    }

    #[test]
    fn double_release_is_flagged_not_masked() {
        // Regression: the old `saturating_sub(1)` representation clamped
        // the stored counters, so a double-release both vanished from the
        // estimate and was unobservable. Signed deltas keep the books and
        // surface the pairing violation.
        let mut loads = LoadMap::default();
        loads.update(0, load(0, 0, 1)); // one resident reported
        loads.note_departure(0); // pairs with the resident
        assert_eq!(loads.unpaired_departures(), 0);
        loads.note_departure(0); // double release
        assert_eq!(loads.unpaired_departures(), 1);
        // The visible estimate still clamps at zero (old external behavior).
        assert_eq!(loads.get(0).resident, 0);
        assert_eq!(loads.get(0).queue_depth, 0);
        // A later submit is not silently re-inflated from the wrong floor:
        // the ledger nets the spurious release against the new arrival.
        loads.note_submit(0);
        assert_eq!(loads.get(0).resident, 0);
        // Departure for an AW that was never tracked (or already removed).
        loads.remove(0);
        loads.note_departure(0);
        assert_eq!(loads.unpaired_departures(), 2);
    }

    #[test]
    fn submit_departure_pairing_balances() {
        let mut loads = LoadMap::strict();
        loads.update(3, load(0, 0, 0));
        loads.note_submit(3);
        loads.note_submit(3);
        assert_eq!(loads.get(3).resident, 2);
        loads.note_departure(3);
        loads.note_departure(3);
        assert_eq!(loads.get(3).resident, 0);
        assert_eq!(loads.unpaired_departures(), 0, "paired traffic must not be flagged");
        // A fresh beacon resets the optimistic deltas wholesale.
        loads.note_submit(3);
        loads.update(3, load(4, 8, 5));
        assert_eq!(loads.get(3), load(4, 8, 5));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "unpaired departure")]
    fn strict_mode_asserts_on_double_release() {
        let mut loads = LoadMap::strict();
        loads.update(0, load(0, 0, 0));
        loads.note_departure(0);
    }

    #[test]
    fn victim_is_lowest_progress_then_lowest_id() {
        assert_eq!(pick_victim(vec![(7, 5), (3, 2), (9, 2)]), Some(3));
        assert_eq!(pick_victim(vec![(7, 0)]), Some(7));
        assert_eq!(pick_victim(Vec::new()), None);
    }

    #[test]
    fn admission_limits_reject_oversized() {
        let lim = AdmissionLimits {
            max_prompt: 16,
            max_seq: 160,
            layers: 2,
            page_tokens: 16,
            budget_pages: 8,
        };
        assert!(lim.reject_reason(8, 24).is_none());
        assert!(lim.reject_reason(0, 8).is_some(), "empty prompt");
        assert!(lim.reject_reason(17, 8).is_some(), "prompt over the largest bucket");
        assert!(lim.reject_reason(16, 150).is_some(), "overflows max_seq");
        // 8 + 60 = 68 tokens -> ceil(68/16)*2 = 10 pages > budget 8.
        assert!(lim.reject_reason(8, 60).is_some(), "cannot ever fit the budget");
        let unbounded = AdmissionLimits { budget_pages: 0, ..lim };
        assert!(unbounded.reject_reason(8, 60).is_none());
    }
}
