//! Cluster gateway: admits requests to AWs through a pluggable,
//! load-aware router (DESIGN.md §9), collects output tokens, and records
//! the event log the experiment harnesses analyze.
//!
//! Admission is backpressured: arrivals wait in the gateway's queue until
//! some AW has headroom (below the high pressure watermark and under the
//! per-AW resident cap) instead of landing on a full worker — overload
//! shows up as queueing delay, never as a drop. Oversized requests
//! (prompt over the largest prefill bucket, or a worst-case KV footprint
//! that can never fit an AW's page budget) are rejected at admission with
//! a stream-level error surfaced through [`GatewayShared`].
//!
//! Deployments may run N gateway *shards* (DESIGN.md §15): every shard
//! holds the full arrival schedule but accepts only the requests it owns
//! under rendezvous hashing over the live shard set. All shards share one
//! [`GatewayShared`], so recorded tokens survive any single shard's death;
//! when the orchestrator shrinks the live set (`GatewaySet`), survivors
//! rescan the already-due prefix of the schedule and re-admit the dead
//! shard's unfinished requests through their own admission queues.
//!
//! Under coarse-grained restarts it re-submits unfinished requests and
//! de-duplicates re-emitted tokens, so the metrics see recomputation as a
//! token-stream *gap*, not as extra throughput.

use super::sched::{AdmissionLimits, AwLoad, LoadMap, Router, Watermarks};
use crate::config::SchedConfig;
use crate::metrics::trace::{SpanKind, TraceHandle};
use crate::metrics::{EventKind, EventLog};
use crate::proto::{ClusterMsg, RequestMeta};
use crate::transport::{link::TrafficClass, Fabric, Inbox, NodeId, Plane, Qp};
use crate::util::chash;
use crate::workload::Request;
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct GatewayParams {
    /// This shard's index (0-based; `NodeId::Gateway(shard)`).
    pub shard: u32,
    /// Total gateway shards at launch (shards never respawn, so the
    /// initial live set is `0..num_shards`).
    pub num_shards: usize,
    /// Checkpoint-store replica count (`ReqFinished` reclamation notices
    /// fan out to every replica).
    pub num_stores: usize,
    /// Pre-registered inbox (the cluster registers the gateway node before
    /// spawning workers, which create QPs toward it at init).
    pub inbox: Inbox<ClusterMsg>,
    pub schedule: Vec<Request>,
    pub initial_aws: Vec<u32>,
    pub fabric: Arc<Fabric<ClusterMsg>>,
    pub events: Arc<EventLog>,
    /// Span recording handle; `None` when tracing is disabled (the hot
    /// path then makes no clock reads for spans).
    pub trace: Option<TraceHandle>,
    pub shared: Arc<GatewayShared>,
    pub stop: Arc<AtomicBool>,
    /// Give up this long after the last scheduled arrival even if some
    /// requests never finish (worker failures in baseline runs).
    pub drain_timeout: Duration,
    /// Scheduler policy knobs (router, watermarks).
    pub sched: SchedConfig,
    /// Static admission fit checks (from the model manifest).
    pub limits: AdmissionLimits,
    /// Per-AW resident cap for admission (0 = uncapped).
    pub max_per_aw: usize,
}

/// State shared with the harness — and, in sharded deployments, *between*
/// the gateway shards. Keeping the token streams and the terminal-state
/// sets here (rather than per shard) is what makes a gateway death
/// non-destructive: everything a dead shard ever recorded is still
/// visible to the survivors and the harness.
#[derive(Default)]
pub struct GatewayShared {
    inner: Mutex<SharedInner>,
    pub done: AtomicBool,
}

#[derive(Default)]
struct SharedInner {
    /// request id -> generated token ids (deduped; `u32::MAX` marks a
    /// gap — a token index seen only via a later index — until the AW's
    /// failover replay fills it).
    generated: HashMap<u64, Vec<u32>>,
    /// Every request id any shard has accepted (dedup for `submitted`
    /// and the resubmit/admit distinction across shard failovers).
    known: HashSet<u64>,
    /// Requests that reached `Finished` (idempotent across duplicate
    /// notices and shard failovers).
    finished_ids: HashSet<u64>,
    submitted: usize,
    /// Per-shard admission-queue depths (backpressure gauge).
    queued: HashMap<u32, usize>,
    /// Preemption notices observed (cluster-wide).
    preempted: u64,
    /// request id -> stream-level error for rejected requests.
    rejected: BTreeMap<u64, String>,
}

impl GatewayShared {
    /// The generated token stream of `id` — `None` when the gateway has
    /// never recorded a token for that request. Callers must distinguish
    /// the two: an unknown id usually means a *lost* request (or a typo'd
    /// one), which the old `unwrap_or_default()` silently rendered as an
    /// empty-but-plausible stream.
    pub fn generated_of(&self, id: u64) -> Option<Vec<u32>> {
        self.inner.lock().unwrap().generated.get(&id).cloned()
    }

    pub fn finished(&self) -> usize {
        self.inner.lock().unwrap().finished_ids.len()
    }

    pub fn submitted(&self) -> usize {
        self.inner.lock().unwrap().submitted
    }

    /// Requests waiting in the admission queues right now (backpressure
    /// gauge; summed over shards).
    pub fn queued(&self) -> usize {
        self.inner.lock().unwrap().queued.values().sum()
    }

    /// Preemption notices observed so far.
    pub fn preempted(&self) -> u64 {
        self.inner.lock().unwrap().preempted
    }

    /// The stream-level error of a rejected request, if any.
    pub fn error_of(&self, id: u64) -> Option<String> {
        self.inner.lock().unwrap().rejected.get(&id).cloned()
    }

    /// All rejected requests with their errors.
    pub fn rejections(&self) -> BTreeMap<u64, String> {
        self.inner.lock().unwrap().rejected.clone()
    }

    pub fn rejected_count(&self) -> usize {
        self.inner.lock().unwrap().rejected.len()
    }
}

struct GwReq {
    meta: RequestMeta,
    finished: bool,
    rejected: bool,
    /// In the admission queue right now (dedup guard).
    queued: bool,
    /// The next dispatch is a resubmission (record Migrated, not Admitted).
    resubmit: bool,
    /// When the request entered the admission queue — set only while
    /// tracing, closed into a GatewayQueue span at dispatch.
    queued_since: Option<Duration>,
}

pub fn spawn(params: GatewayParams) -> std::thread::JoinHandle<()> {
    let clock = params.fabric.clock().clone();
    let name = format!("gateway{}", params.shard);
    crate::util::clock::spawn_participant(&clock, name, move || gateway_main(params))
        .expect("spawn gateway")
}

struct Gw {
    shard: u32,
    node: NodeId,
    fabric: Arc<Fabric<ClusterMsg>>,
    events: Arc<EventLog>,
    trace: Option<TraceHandle>,
    shared: Arc<GatewayShared>,
    qps: HashMap<u32, Qp<ClusterMsg>>,
    orch_qp: Option<Qp<ClusterMsg>>,
    store_qps: Vec<Qp<ClusterMsg>>,
    aws: Vec<u32>,
    /// Live gateway shards (kept current by the orchestrator's
    /// `GatewaySet`); request ownership is `chash::owner(id, &gateways)`.
    gateways: Vec<u32>,
    router: Router,
    loads: LoadMap,
    limits: AdmissionLimits,
    /// Ordered: RestartNotice resubmission order must be deterministic.
    reqs: BTreeMap<u64, GwReq>,
    /// Admission queue: due-but-unplaced requests (backpressure).
    admit_q: VecDeque<u64>,
    /// Full arrival schedule (shared by all shards) and its id index —
    /// failover rescans and `Rebind` adoption need arbitrary lookups.
    schedule: Vec<Request>,
    by_id: HashMap<u64, usize>,
    /// Arrivals due so far (schedule prefix already offered to `accept`).
    next: usize,
}

fn gateway_main(p: GatewayParams) {
    let clock = p.fabric.clock().clone();
    let inbox = &p.inbox;
    let node = NodeId::Gateway(p.shard);
    let by_id = p.schedule.iter().enumerate().map(|(i, r)| (r.id, i)).collect();
    let mut gw = Gw {
        shard: p.shard,
        node,
        fabric: p.fabric.clone(),
        events: p.events.clone(),
        trace: p.trace.clone(),
        shared: p.shared.clone(),
        qps: HashMap::new(),
        orch_qp: p.fabric.qp(node, NodeId::Orchestrator, Plane::Control).ok(),
        store_qps: (0..p.num_stores.max(1) as u32)
            .filter_map(|k| p.fabric.qp(node, NodeId::Store(k), Plane::Control).ok())
            .collect(),
        aws: p.initial_aws.clone(),
        gateways: (0..p.num_shards.max(1) as u32).collect(),
        router: Router::new(
            p.sched.policy,
            Watermarks { high: p.sched.high_watermark, low: p.sched.low_watermark },
            p.max_per_aw,
        ),
        loads: LoadMap::default(),
        limits: p.limits,
        reqs: BTreeMap::new(),
        admit_q: VecDeque::new(),
        schedule: p.schedule,
        by_id,
        next: 0,
    };
    let start = clock.now();
    let last_arrival = gw.schedule.last().map(|r| r.arrival_s).unwrap_or(0.0);
    let total = gw.schedule.len();
    // Whether this shard exited cleanly (run over / harness stop) rather
    // than dying — a killed shard must NOT mark the whole run done.
    let mut completed = true;

    loop {
        if p.stop.load(Ordering::Relaxed) {
            break;
        }
        let now = clock.now().saturating_sub(start).as_secs_f64();

        // 1. Accept due arrivals this shard owns: reject oversized ones
        //    outright, queue the rest for admission. Non-owned arrivals
        //    are skipped here; a failover rescan picks them up if their
        //    owner changes later.
        while gw.next < gw.schedule.len() && gw.schedule[gw.next].arrival_s <= now {
            let i = gw.next;
            gw.next += 1;
            if gw.owns(gw.schedule[i].id) {
                gw.accept_idx(i);
            }
        }

        // 2. Place queued requests while some AW has headroom.
        gw.pump_admissions();

        // 3. Collect tokens / notices.
        match inbox.recv(Duration::from_millis(1)) {
            Ok(env) => gw.handle(env.msg),
            Err(crate::transport::QpError::Timeout) => {}
            Err(_) => {
                completed = false; // this shard was killed
                break;
            }
        }
        // Keep the orchestrator QP fresh if it was unavailable at start.
        if gw.orch_qp.is_none() {
            gw.orch_qp = p.fabric.qp(node, NodeId::Orchestrator, Plane::Control).ok();
        }

        // 4. Exit conditions: everything finished cluster-wide (rejected
        //    requests are terminal), or drain timeout.
        if gw.next >= gw.schedule.len() {
            let terminal = {
                let inner = gw.shared.inner.lock().unwrap();
                inner.finished_ids.len() + inner.rejected.len()
            };
            if terminal >= total {
                break;
            }
            if now > last_arrival + p.drain_timeout.as_secs_f64() {
                break;
            }
        }
    }
    if completed {
        p.shared.done.store(true, Ordering::Release);
    }
}

impl Gw {
    fn owns(&self, id: u64) -> bool {
        chash::owner(id, &self.gateways) == Some(self.shard)
    }

    /// Accept the schedule entry at `i`: reject it if it can never be
    /// served, else queue it for admission. Requests another shard
    /// already accepted (failover re-admission) count as resubmissions
    /// and requests already terminal are only tracked, not re-dispatched.
    fn accept_idx(&mut self, i: usize) {
        let r = &self.schedule[i];
        let id = r.id;
        if self.reqs.contains_key(&id) {
            return; // already tracked by this shard
        }
        let meta = RequestMeta {
            id,
            prompt: r.prompt.clone(),
            max_new_tokens: r.max_new_tokens as u32,
        };
        let oversized = self.limits.reject_reason(r.prompt.len(), r.max_new_tokens);
        let (newly_known, already_finished, already_rejected) = {
            let mut inner = self.shared.inner.lock().unwrap();
            let newly = inner.known.insert(id);
            if newly {
                inner.submitted += 1;
            }
            (newly, inner.finished_ids.contains(&id), inner.rejected.contains_key(&id))
        };
        if newly_known {
            self.events.record(EventKind::Submitted, id, 0, self.shard);
        }
        self.reqs.insert(
            id,
            GwReq {
                meta,
                finished: already_finished,
                rejected: already_rejected,
                queued: false,
                // A request some other shard accepted first restarts from
                // the prompt here — that is a migration, not an admission.
                resubmit: !newly_known,
                queued_since: None,
            },
        );
        if already_finished || already_rejected {
            return;
        }
        match oversized {
            Some(reason) => {
                self.mark_rejected(id, 0, reason);
            }
            None => self.enqueue(id, false),
        }
    }

    /// Returns whether this was the first rejection notice for `id`.
    fn mark_rejected(&mut self, id: u64, worker: u32, reason: String) -> bool {
        let was_queued = match self.reqs.get_mut(&id) {
            Some(r) => {
                r.rejected = true;
                let q = r.queued;
                r.queued = false;
                q
            }
            None => false,
        };
        if was_queued {
            self.admit_q.retain(|&q| q != id);
        }
        let mut inner = self.shared.inner.lock().unwrap();
        let newly = !inner.rejected.contains_key(&id);
        inner.rejected.entry(id).or_insert(reason);
        inner.queued.insert(self.shard, self.admit_q.len());
        drop(inner);
        if newly {
            self.events.record(EventKind::Rejected, id, 0, worker);
        }
        newly
    }

    /// Queue a request for (re)admission; `resubmit` marks dispatches
    /// that restart from the prompt (failure recovery, drains).
    fn enqueue(&mut self, id: u64, resubmit: bool) {
        let Some(r) = self.reqs.get_mut(&id) else { return };
        if r.finished || r.rejected || r.queued {
            return;
        }
        r.queued = true;
        r.resubmit = r.resubmit || resubmit;
        if let Some(tr) = &self.trace {
            r.queued_since = Some(tr.start());
        }
        self.admit_q.push_back(id);
        let mut inner = self.shared.inner.lock().unwrap();
        let depth = self.admit_q.len();
        inner.queued.insert(self.shard, depth);
    }

    /// Place queued requests until the router backpressures.
    fn pump_admissions(&mut self) {
        while let Some(&id) = self.admit_q.front() {
            let stale = match self.reqs.get(&id) {
                Some(r) => r.finished || r.rejected,
                None => true,
            };
            if stale {
                self.admit_q.pop_front();
                continue;
            }
            let Some(aw) = self.router.pick(&self.aws, &self.loads) else {
                break; // every AW saturated: wait for the next beacon
            };
            self.admit_q.pop_front();
            self.dispatch(id, aw);
        }
        let mut inner = self.shared.inner.lock().unwrap();
        let depth = self.admit_q.len();
        inner.queued.insert(self.shard, depth);
    }

    /// Send a request to an AW and account for it.
    fn dispatch(&mut self, id: u64, aw: u32) {
        let (meta, resubmit, queued_since) = {
            let r = self.reqs.get_mut(&id).expect("dispatch of unknown request");
            r.queued = false;
            let resubmit = r.resubmit;
            r.resubmit = false;
            (r.meta.clone(), resubmit, r.queued_since.take())
        };
        if let (Some(tr), Some(t0)) = (&self.trace, queued_since) {
            tr.record(SpanKind::GatewayQueue, id, aw as u64, t0);
        }
        let fabric = self.fabric.clone();
        let node = self.node;
        let qp = self.qps.entry(aw).or_insert_with(|| {
            fabric.qp(node, NodeId::Aw(aw), Plane::Control).expect("gw qp")
        });
        let bytes = meta.wire_bytes();
        // Optimistic page estimate (the prompt's prefill footprint) so a
        // burst within one beacon interval spreads instead of dogpiling
        // the least-pressure AW; the next beacon corrects the estimate.
        let est_pages = crate::kvcache::pages_for_tokens(
            meta.prompt.len(),
            self.limits.page_tokens,
            self.limits.layers,
        ) as u32;
        let _ = qp.post(ClusterMsg::NewRequest(meta), bytes, TrafficClass::Admin);
        if let Some(q) = self.orch_qp.as_ref() {
            let _ = q.post(
                ClusterMsg::Bound { request: id, aw },
                crate::proto::HDR_BYTES,
                TrafficClass::Admin,
            );
        }
        let kind = if resubmit { EventKind::Migrated } else { EventKind::Admitted };
        self.events.record(kind, id, 0, aw);
        self.loads.note_submit(aw);
        self.loads.note_pages(aw, est_pages);
    }

    /// Gateway failover: the orchestrator shrank the live shard set.
    /// Rescan the already-due schedule prefix for requests this shard now
    /// owns but does not track — the dead shard's accepted-but-unfinished
    /// work — and pull them through the normal accept path (terminal
    /// requests are only tracked; live ones re-enter admission). Requests
    /// the dead shard had *dispatched* arrive as `Rebind`s on the same
    /// FIFO QP before this message, so they are tracked already and are
    /// not re-dispatched here.
    fn rescan_owned(&mut self) {
        for i in 0..self.next {
            let id = self.schedule[i].id;
            if self.owns(id) && !self.reqs.contains_key(&id) {
                self.accept_idx(i);
            }
        }
    }

    fn handle(&mut self, msg: ClusterMsg) {
        match msg {
            ClusterMsg::Token { request, index, token, worker } => {
                let mut inner = self.shared.inner.lock().unwrap();
                let gen = inner.generated.entry(request).or_default();
                if (index as usize) < gen.len() {
                    if gen[index as usize] == u32::MAX {
                        // Filling a gap left by an out-of-order failover
                        // replay: this index was never recorded, only
                        // skipped over. (Decode emits strictly increasing
                        // indices, so a real token is never u32::MAX —
                        // argmax over the vocab cannot produce it.)
                        gen[index as usize] = token;
                        drop(inner);
                        self.events.record(EventKind::Token, request, index, worker);
                    }
                    // else: re-emitted during replay/restart —
                    // recomputation, not new output. Keep the original.
                } else {
                    gen.resize(index as usize, u32::MAX);
                    gen.push(token);
                    drop(inner);
                    self.events.record(EventKind::Token, request, index, worker);
                }
            }
            ClusterMsg::Finished { request, worker } => {
                let newly = self.shared.inner.lock().unwrap().finished_ids.insert(request);
                if let Some(r) = self.reqs.get_mut(&request) {
                    r.finished = true;
                }
                if newly {
                    self.events.record(EventKind::Finished, request, 0, worker);
                    self.loads.note_departure(worker);
                    // Let the checkpoint store replicas reclaim the
                    // request's segment log (bounded memory).
                    for q in &self.store_qps {
                        let _ = q.post(
                            ClusterMsg::ReqFinished { request },
                            crate::proto::HDR_BYTES,
                            TrafficClass::Admin,
                        );
                    }
                }
            }
            ClusterMsg::Status(st) => {
                self.loads.update(st.aw, AwLoad::from_status(&st));
            }
            ClusterMsg::Rejected { request, worker, reason } => {
                // AW-side defense in depth: terminal, surfaced as an
                // error. The request was dispatched (submit-bumped), so
                // the first notice pairs the departure — otherwise the
                // rejecting AW carries a phantom resident until its next
                // beacon.
                if self.mark_rejected(request, worker, reason) {
                    self.loads.note_departure(worker);
                }
            }
            ClusterMsg::Preempted { aw, meta } => {
                // Informational: the orchestrator owns re-admission.
                self.events.record(EventKind::Preempted, meta.request, 0, aw);
                self.shared.inner.lock().unwrap().preempted += 1;
                self.loads.note_departure(aw);
            }
            ClusterMsg::AwSet { aws: new_aws } => {
                self.aws = new_aws;
            }
            ClusterMsg::GatewaySet { gateways } => {
                if gateways != self.gateways && !gateways.is_empty() {
                    self.gateways = gateways;
                    self.rescan_owned();
                }
            }
            ClusterMsg::Rebind { request, new_aw } => {
                // A request resumed on a different AW (restore) or moved
                // to this shard (gateway failover): make sure it is
                // tracked here, and record the migration unless it is
                // already terminal.
                let terminal = {
                    let inner = self.shared.inner.lock().unwrap();
                    (
                        inner.finished_ids.contains(&request),
                        inner.rejected.contains_key(&request),
                    )
                };
                if !self.reqs.contains_key(&request) {
                    if let Some(&i) = self.by_id.get(&request) {
                        let r = &self.schedule[i];
                        self.reqs.insert(
                            request,
                            GwReq {
                                meta: RequestMeta {
                                    id: request,
                                    prompt: r.prompt.clone(),
                                    max_new_tokens: r.max_new_tokens as u32,
                                },
                                finished: terminal.0,
                                rejected: terminal.1,
                                queued: false,
                                resubmit: false,
                                queued_since: None,
                            },
                        );
                    }
                }
                if !terminal.0 && !terminal.1 {
                    self.events.record(EventKind::Migrated, request, 0, new_aw);
                    // The restored request is now resident on `new_aw`,
                    // but it never went through `dispatch` here — without
                    // this bump its eventual Finished/Preempted departure
                    // has no matching submit and the decrement used to be
                    // silently clamped away, making rebind targets look
                    // emptier than they are.
                    self.loads.note_submit(new_aw);
                }
            }
            ClusterMsg::Resubmit { requests } => {
                // Lost before any checkpoint: restart from the prompt
                // (through the admission queue — backpressure applies).
                for id in requests {
                    if !self.reqs.contains_key(&id) {
                        if let Some(&i) = self.by_id.get(&id) {
                            self.accept_idx(i);
                            continue; // accept_idx already enqueued it
                        }
                    }
                    self.enqueue(id, true);
                }
            }
            ClusterMsg::RestartNotice => {
                // Coarse restart: all in-flight work was lost.
                // Re-submit every unfinished request from scratch.
                let ids: Vec<u64> = self
                    .reqs
                    .iter()
                    .filter(|(_, r)| !r.finished && !r.rejected)
                    .map(|(&id, _)| id)
                    .collect();
                for id in ids {
                    self.enqueue(id, true);
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_request_is_none_not_empty_stream() {
        // Regression: `generated_of` used to `unwrap_or_default()`, so a
        // lost (or mistyped) request id was indistinguishable from a
        // request that finished with an empty stream.
        let shared = GatewayShared::default();
        assert_eq!(shared.generated_of(7), None, "unknown id must not look finished-empty");
        shared.inner.lock().unwrap().generated.insert(7, vec![11, 12]);
        assert_eq!(shared.generated_of(7), Some(vec![11, 12]));
        // A tracked-but-tokenless request (entry created, nothing emitted
        // yet) is `Some(empty)` — the distinction the fix preserves.
        shared.inner.lock().unwrap().generated.insert(8, Vec::new());
        assert_eq!(shared.generated_of(8), Some(Vec::new()));
    }
}
