//! Cluster gateway: admits requests to AWs through a pluggable,
//! load-aware router (DESIGN.md §9), collects output tokens, and records
//! the event log the experiment harnesses analyze.
//!
//! Admission is backpressured: arrivals wait in the gateway's queue until
//! some AW has headroom (below the high pressure watermark and under the
//! per-AW resident cap) instead of landing on a full worker — overload
//! shows up as queueing delay, never as a drop. Oversized requests
//! (prompt over the largest prefill bucket, or a worst-case KV footprint
//! that can never fit an AW's page budget) are rejected at admission with
//! a stream-level error surfaced through [`GatewayShared`].
//!
//! Under coarse-grained restarts it re-submits unfinished requests and
//! de-duplicates re-emitted tokens, so the metrics see recomputation as a
//! token-stream *gap*, not as extra throughput.

use super::sched::{AdmissionLimits, AwLoad, LoadMap, Router, Watermarks};
use crate::config::SchedConfig;
use crate::metrics::trace::{SpanKind, TraceHandle};
use crate::metrics::{EventKind, EventLog};
use crate::proto::{ClusterMsg, RequestMeta};
use crate::transport::{link::TrafficClass, Fabric, Inbox, NodeId, Plane, Qp};
use crate::workload::Request;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct GatewayParams {
    /// Pre-registered inbox (the cluster registers the gateway node before
    /// spawning workers, which create QPs toward it at init).
    pub inbox: Inbox<ClusterMsg>,
    pub schedule: Vec<Request>,
    pub initial_aws: Vec<u32>,
    pub fabric: Arc<Fabric<ClusterMsg>>,
    pub events: Arc<EventLog>,
    /// Span recording handle; `None` when tracing is disabled (the hot
    /// path then makes no clock reads for spans).
    pub trace: Option<TraceHandle>,
    pub shared: Arc<GatewayShared>,
    pub stop: Arc<AtomicBool>,
    /// Give up this long after the last scheduled arrival even if some
    /// requests never finish (worker failures in baseline runs).
    pub drain_timeout: Duration,
    /// Scheduler policy knobs (router, watermarks).
    pub sched: SchedConfig,
    /// Static admission fit checks (from the model manifest).
    pub limits: AdmissionLimits,
    /// Per-AW resident cap for admission (0 = uncapped).
    pub max_per_aw: usize,
}

/// State shared with the harness (inspectable during/after the run).
#[derive(Default)]
pub struct GatewayShared {
    inner: Mutex<SharedInner>,
    pub done: AtomicBool,
}

#[derive(Default)]
struct SharedInner {
    /// request id -> generated token ids (deduped).
    generated: HashMap<u64, Vec<u32>>,
    finished: usize,
    submitted: usize,
    /// Requests currently waiting in the admission queue.
    queued: usize,
    /// Preemption notices observed (cluster-wide).
    preempted: u64,
    /// request id -> stream-level error for rejected requests.
    rejected: BTreeMap<u64, String>,
}

impl GatewayShared {
    pub fn generated_of(&self, id: u64) -> Vec<u32> {
        self.inner.lock().unwrap().generated.get(&id).cloned().unwrap_or_default()
    }

    pub fn finished(&self) -> usize {
        self.inner.lock().unwrap().finished
    }

    pub fn submitted(&self) -> usize {
        self.inner.lock().unwrap().submitted
    }

    /// Requests waiting in the admission queue right now (backpressure
    /// gauge).
    pub fn queued(&self) -> usize {
        self.inner.lock().unwrap().queued
    }

    /// Preemption notices observed so far.
    pub fn preempted(&self) -> u64 {
        self.inner.lock().unwrap().preempted
    }

    /// The stream-level error of a rejected request, if any.
    pub fn error_of(&self, id: u64) -> Option<String> {
        self.inner.lock().unwrap().rejected.get(&id).cloned()
    }

    /// All rejected requests with their errors.
    pub fn rejections(&self) -> BTreeMap<u64, String> {
        self.inner.lock().unwrap().rejected.clone()
    }

    pub fn rejected_count(&self) -> usize {
        self.inner.lock().unwrap().rejected.len()
    }
}

struct GwReq {
    meta: RequestMeta,
    finished: bool,
    rejected: bool,
    /// In the admission queue right now (dedup guard).
    queued: bool,
    /// The next dispatch is a resubmission (record Migrated, not Admitted).
    resubmit: bool,
    /// When the request entered the admission queue — set only while
    /// tracing, closed into a GatewayQueue span at dispatch.
    queued_since: Option<Duration>,
}

pub fn spawn(params: GatewayParams) -> std::thread::JoinHandle<()> {
    let clock = params.fabric.clock().clone();
    crate::util::clock::spawn_participant(&clock, "gateway", move || gateway_main(params))
        .expect("spawn gateway")
}

struct Gw {
    fabric: Arc<Fabric<ClusterMsg>>,
    events: Arc<EventLog>,
    trace: Option<TraceHandle>,
    shared: Arc<GatewayShared>,
    qps: HashMap<u32, Qp<ClusterMsg>>,
    orch_qp: Option<Qp<ClusterMsg>>,
    store_qp: Option<Qp<ClusterMsg>>,
    aws: Vec<u32>,
    router: Router,
    loads: LoadMap,
    limits: AdmissionLimits,
    /// Ordered: RestartNotice resubmission order must be deterministic.
    reqs: BTreeMap<u64, GwReq>,
    /// Admission queue: due-but-unplaced requests (backpressure).
    admit_q: VecDeque<u64>,
}

fn gateway_main(p: GatewayParams) {
    let clock = p.fabric.clock().clone();
    let inbox = &p.inbox;
    let mut gw = Gw {
        fabric: p.fabric.clone(),
        events: p.events.clone(),
        trace: p.trace.clone(),
        shared: p.shared.clone(),
        qps: HashMap::new(),
        orch_qp: p.fabric.qp(NodeId::Gateway, NodeId::Orchestrator, Plane::Control).ok(),
        store_qp: p.fabric.qp(NodeId::Gateway, NodeId::Store, Plane::Control).ok(),
        aws: p.initial_aws.clone(),
        router: Router::new(
            p.sched.policy,
            Watermarks { high: p.sched.high_watermark, low: p.sched.low_watermark },
            p.max_per_aw,
        ),
        loads: LoadMap::default(),
        limits: p.limits,
        reqs: BTreeMap::new(),
        admit_q: VecDeque::new(),
    };
    let start = clock.now();
    let mut next = 0usize;
    let last_arrival = p.schedule.last().map(|r| r.arrival_s).unwrap_or(0.0);

    loop {
        if p.stop.load(Ordering::Relaxed) {
            break;
        }
        let now = clock.now().saturating_sub(start).as_secs_f64();

        // 1. Accept due arrivals: reject oversized ones outright, queue
        //    the rest for admission.
        while next < p.schedule.len() && p.schedule[next].arrival_s <= now {
            let r = &p.schedule[next];
            next += 1;
            gw.accept(r);
        }

        // 2. Place queued requests while some AW has headroom.
        gw.pump_admissions();

        // 3. Collect tokens / notices.
        match inbox.recv(Duration::from_millis(1)) {
            Ok(env) => gw.handle(env.msg),
            Err(crate::transport::QpError::Timeout) => {}
            Err(_) => break,
        }
        // Keep the orchestrator QP fresh if it was unavailable at start.
        if gw.orch_qp.is_none() {
            gw.orch_qp = p.fabric.qp(NodeId::Gateway, NodeId::Orchestrator, Plane::Control).ok();
        }

        // 4. Exit conditions: everything finished (rejected requests are
        //    terminal), or drain timeout.
        if next >= p.schedule.len() {
            let unfinished =
                gw.reqs.values().filter(|r| !r.finished && !r.rejected).count();
            if unfinished == 0 {
                break;
            }
            if now > last_arrival + p.drain_timeout.as_secs_f64() {
                break;
            }
        }
    }
    p.shared.done.store(true, Ordering::Release);
}

impl Gw {
    /// Accept one arrival: reject it if it can never be served, else
    /// queue it for admission.
    fn accept(&mut self, r: &Request) {
        let meta = RequestMeta {
            id: r.id,
            prompt: r.prompt.clone(),
            max_new_tokens: r.max_new_tokens as u32,
        };
        self.events.record(EventKind::Submitted, r.id, 0, 0);
        self.shared.inner.lock().unwrap().submitted += 1;
        let rejected = self.limits.reject_reason(r.prompt.len(), r.max_new_tokens);
        self.reqs.insert(
            r.id,
            GwReq {
                meta,
                finished: false,
                rejected: rejected.is_some(),
                queued: false,
                resubmit: false,
                queued_since: None,
            },
        );
        match rejected {
            Some(reason) => self.mark_rejected(r.id, 0, reason),
            None => self.enqueue(r.id, false),
        }
    }

    fn mark_rejected(&mut self, id: u64, worker: u32, reason: String) {
        let was_queued = match self.reqs.get_mut(&id) {
            Some(r) => {
                r.rejected = true;
                let q = r.queued;
                r.queued = false;
                q
            }
            None => false,
        };
        if was_queued {
            self.admit_q.retain(|&q| q != id);
        }
        self.events.record(EventKind::Rejected, id, 0, worker);
        let mut inner = self.shared.inner.lock().unwrap();
        inner.rejected.entry(id).or_insert(reason);
        inner.queued = self.admit_q.len();
    }

    /// Queue a request for (re)admission; `resubmit` marks dispatches
    /// that restart from the prompt (failure recovery, drains).
    fn enqueue(&mut self, id: u64, resubmit: bool) {
        let Some(r) = self.reqs.get_mut(&id) else { return };
        if r.finished || r.rejected || r.queued {
            return;
        }
        r.queued = true;
        r.resubmit = r.resubmit || resubmit;
        if let Some(tr) = &self.trace {
            r.queued_since = Some(tr.start());
        }
        self.admit_q.push_back(id);
        self.shared.inner.lock().unwrap().queued = self.admit_q.len();
    }

    /// Place queued requests until the router backpressures.
    fn pump_admissions(&mut self) {
        while let Some(&id) = self.admit_q.front() {
            let stale = match self.reqs.get(&id) {
                Some(r) => r.finished || r.rejected,
                None => true,
            };
            if stale {
                self.admit_q.pop_front();
                continue;
            }
            let Some(aw) = self.router.pick(&self.aws, &self.loads) else {
                break; // every AW saturated: wait for the next beacon
            };
            self.admit_q.pop_front();
            self.dispatch(id, aw);
        }
        self.shared.inner.lock().unwrap().queued = self.admit_q.len();
    }

    /// Send a request to an AW and account for it.
    fn dispatch(&mut self, id: u64, aw: u32) {
        let (meta, resubmit, queued_since) = {
            let r = self.reqs.get_mut(&id).expect("dispatch of unknown request");
            r.queued = false;
            let resubmit = r.resubmit;
            r.resubmit = false;
            (r.meta.clone(), resubmit, r.queued_since.take())
        };
        if let (Some(tr), Some(t0)) = (&self.trace, queued_since) {
            tr.record(SpanKind::GatewayQueue, id, aw as u64, t0);
        }
        let fabric = self.fabric.clone();
        let qp = self.qps.entry(aw).or_insert_with(|| {
            fabric.qp(NodeId::Gateway, NodeId::Aw(aw), Plane::Control).expect("gw qp")
        });
        let bytes = meta.wire_bytes();
        // Optimistic page estimate (the prompt's prefill footprint) so a
        // burst within one beacon interval spreads instead of dogpiling
        // the least-pressure AW; the next beacon corrects the estimate.
        let est_pages = crate::kvcache::pages_for_tokens(
            meta.prompt.len(),
            self.limits.page_tokens,
            self.limits.layers,
        ) as u32;
        let _ = qp.post(ClusterMsg::NewRequest(meta), bytes, TrafficClass::Admin);
        if let Some(q) = self.orch_qp.as_ref() {
            let _ = q.post(
                ClusterMsg::Bound { request: id, aw },
                crate::proto::HDR_BYTES,
                TrafficClass::Admin,
            );
        }
        let kind = if resubmit { EventKind::Migrated } else { EventKind::Admitted };
        self.events.record(kind, id, 0, aw);
        self.loads.note_submit(aw);
        self.loads.note_pages(aw, est_pages);
    }

    fn handle(&mut self, msg: ClusterMsg) {
        match msg {
            ClusterMsg::Token { request, index, token, worker } => {
                let mut inner = self.shared.inner.lock().unwrap();
                let gen = inner.generated.entry(request).or_default();
                if (index as usize) < gen.len() {
                    // Re-emitted during replay/restart: recomputation,
                    // not new output. Keep the original.
                } else {
                    gen.resize(index as usize, u32::MAX);
                    gen.push(token);
                    drop(inner);
                    self.events.record(EventKind::Token, request, index, worker);
                }
            }
            ClusterMsg::Finished { request, worker } => {
                let mut newly = false;
                if let Some(r) = self.reqs.get_mut(&request) {
                    if !r.finished {
                        r.finished = true;
                        newly = true;
                    }
                }
                if newly {
                    self.events.record(EventKind::Finished, request, 0, worker);
                    self.shared.inner.lock().unwrap().finished += 1;
                    self.loads.note_departure(worker);
                    // Let the checkpoint store reclaim the request's
                    // segment log (bounded memory).
                    if let Some(q) = self.store_qp.as_ref() {
                        let _ = q.post(
                            ClusterMsg::ReqFinished { request },
                            crate::proto::HDR_BYTES,
                            TrafficClass::Admin,
                        );
                    }
                }
            }
            ClusterMsg::Status(st) => {
                self.loads.update(st.aw, AwLoad::from_status(&st));
            }
            ClusterMsg::Rejected { request, worker, reason } => {
                // AW-side defense in depth: terminal, surfaced as an error.
                self.mark_rejected(request, worker, reason);
            }
            ClusterMsg::Preempted { aw, meta } => {
                // Informational: the orchestrator owns re-admission.
                self.events.record(EventKind::Preempted, meta.request, 0, aw);
                self.shared.inner.lock().unwrap().preempted += 1;
                self.loads.note_departure(aw);
            }
            ClusterMsg::AwSet { aws: new_aws } => {
                self.aws = new_aws;
            }
            ClusterMsg::Rebind { request, new_aw } => {
                // A restored request resumed elsewhere: a migration.
                self.events.record(EventKind::Migrated, request, 0, new_aw);
            }
            ClusterMsg::Resubmit { requests } => {
                // Lost before any checkpoint: restart from the prompt
                // (through the admission queue — backpressure applies).
                for id in requests {
                    self.enqueue(id, true);
                }
            }
            ClusterMsg::RestartNotice => {
                // Coarse restart: all in-flight work was lost.
                // Re-submit every unfinished request from scratch.
                let ids: Vec<u64> = self
                    .reqs
                    .iter()
                    .filter(|(_, r)| !r.finished && !r.rejected)
                    .map(|(&id, _)| id)
                    .collect();
                for id in ids {
                    self.enqueue(id, true);
                }
            }
            _ => {}
        }
    }
}
