//! Cluster gateway: admits requests to AWs (round-robin over the live
//! set), collects output tokens, and records the event log the experiment
//! harnesses analyze. Under coarse-grained restarts it re-submits
//! unfinished requests and de-duplicates re-emitted tokens, so the metrics
//! see recomputation as a token-stream *gap*, not as extra throughput.

use crate::metrics::{EventKind, EventLog};
use crate::proto::{ClusterMsg, RequestMeta};
use crate::transport::{link::TrafficClass, Fabric, Inbox, NodeId, Plane, Qp};
use crate::workload::Request;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct GatewayParams {
    /// Pre-registered inbox (the cluster registers the gateway node before
    /// spawning workers, which create QPs toward it at init).
    pub inbox: Inbox<ClusterMsg>,
    pub schedule: Vec<Request>,
    pub initial_aws: Vec<u32>,
    pub fabric: Arc<Fabric<ClusterMsg>>,
    pub events: Arc<EventLog>,
    pub shared: Arc<GatewayShared>,
    pub stop: Arc<AtomicBool>,
    /// Give up this long after the last scheduled arrival even if some
    /// requests never finish (worker failures in baseline runs).
    pub drain_timeout: Duration,
}

/// State shared with the harness (inspectable during/after the run).
#[derive(Default)]
pub struct GatewayShared {
    inner: Mutex<SharedInner>,
    pub done: AtomicBool,
}

#[derive(Default)]
struct SharedInner {
    /// request id -> generated token ids (deduped).
    generated: HashMap<u64, Vec<u32>>,
    finished: usize,
    submitted: usize,
}

impl GatewayShared {
    pub fn generated_of(&self, id: u64) -> Vec<u32> {
        self.inner.lock().unwrap().generated.get(&id).cloned().unwrap_or_default()
    }

    pub fn finished(&self) -> usize {
        self.inner.lock().unwrap().finished
    }

    pub fn submitted(&self) -> usize {
        self.inner.lock().unwrap().submitted
    }
}

struct GwReq {
    meta: RequestMeta,
    assigned: u32,
    finished: bool,
}

pub fn spawn(params: GatewayParams) -> std::thread::JoinHandle<()> {
    let clock = params.fabric.clock().clone();
    crate::util::clock::spawn_participant(&clock, "gateway", move || gateway_main(params))
        .expect("spawn gateway")
}

fn gateway_main(p: GatewayParams) {
    let clock = p.fabric.clock().clone();
    let inbox = &p.inbox;
    let mut qps: HashMap<u32, Qp<ClusterMsg>> = HashMap::new();
    let mut orch_qp = p.fabric.qp(NodeId::Gateway, NodeId::Orchestrator, Plane::Control).ok();
    let store_qp = p.fabric.qp(NodeId::Gateway, NodeId::Store, Plane::Control).ok();
    let mut aws = p.initial_aws.clone();
    let mut rr = 0usize;
    // Ordered: RestartNotice resubmission order must be deterministic.
    let mut reqs: BTreeMap<u64, GwReq> = BTreeMap::new();
    let start = clock.now();
    let mut next = 0usize;
    let last_arrival = p.schedule.last().map(|r| r.arrival_s).unwrap_or(0.0);

    loop {
        if p.stop.load(Ordering::Relaxed) {
            break;
        }
        let now = clock.now().saturating_sub(start).as_secs_f64();

        // 1. Submit due arrivals.
        while next < p.schedule.len() && p.schedule[next].arrival_s <= now {
            let r = &p.schedule[next];
            next += 1;
            if aws.is_empty() {
                continue; // total outage: drop (counted as unsubmitted)
            }
            let aw = aws[rr % aws.len()];
            rr += 1;
            let meta = RequestMeta {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new_tokens as u32,
            };
            submit(&p.fabric, &mut qps, aw, &meta);
            if let Some(q) = orch_qp.as_ref() {
                let _ = q.post(
                    ClusterMsg::Bound { request: r.id, aw },
                    crate::proto::HDR_BYTES,
                    TrafficClass::Admin,
                );
            }
            p.events.record(EventKind::Submitted, r.id, 0, aw);
            reqs.insert(r.id, GwReq { meta, assigned: aw, finished: false });
            p.shared.inner.lock().unwrap().submitted += 1;
        }

        // 2. Collect tokens / notices.
        match inbox.recv(Duration::from_millis(1)) {
            Ok(env) => match env.msg {
                ClusterMsg::Token { request, index, token, worker } => {
                    let mut inner = p.shared.inner.lock().unwrap();
                    let gen = inner.generated.entry(request).or_default();
                    if (index as usize) < gen.len() {
                        // Re-emitted during replay/restart: recomputation,
                        // not new output. Keep the original.
                    } else {
                        gen.resize(index as usize, u32::MAX);
                        gen.push(token);
                        drop(inner);
                        p.events.record(EventKind::Token, request, index, worker);
                    }
                }
                ClusterMsg::Finished { request, worker } => {
                    if let Some(r) = reqs.get_mut(&request) {
                        if !r.finished {
                            r.finished = true;
                            p.events.record(EventKind::Finished, request, 0, worker);
                            p.shared.inner.lock().unwrap().finished += 1;
                            // Let the checkpoint store reclaim the
                            // request's segment log (bounded memory).
                            if let Some(q) = store_qp.as_ref() {
                                let _ = q.post(
                                    ClusterMsg::ReqFinished { request },
                                    crate::proto::HDR_BYTES,
                                    TrafficClass::Admin,
                                );
                            }
                        }
                    }
                }
                ClusterMsg::AwSet { aws: new_aws } => {
                    aws = new_aws;
                    rr = 0;
                }
                ClusterMsg::Rebind { request, new_aw } => {
                    if let Some(r) = reqs.get_mut(&request) {
                        r.assigned = new_aw;
                    }
                }
                ClusterMsg::Resubmit { requests } => {
                    // Lost before any checkpoint: restart from the prompt.
                    for id in requests {
                        let Some(r) = reqs.get(&id) else { continue };
                        if r.finished || aws.is_empty() {
                            continue;
                        }
                        let aw = aws[rr % aws.len()];
                        rr += 1;
                        let meta = r.meta.clone();
                        submit(&p.fabric, &mut qps, aw, &meta);
                        if let Some(q) = orch_qp.as_ref() {
                            let _ = q.post(
                                ClusterMsg::Bound { request: id, aw },
                                crate::proto::HDR_BYTES,
                                TrafficClass::Admin,
                            );
                        }
                        reqs.get_mut(&id).unwrap().assigned = aw;
                        p.events.record(EventKind::Migrated, id, 0, aw);
                    }
                }
                ClusterMsg::RestartNotice => {
                    // Coarse restart: all in-flight work was lost.
                    // Re-submit every unfinished request from scratch.
                    let ids: Vec<u64> =
                        reqs.iter().filter(|(_, r)| !r.finished).map(|(&id, _)| id).collect();
                    for id in ids {
                        if aws.is_empty() {
                            break;
                        }
                        let aw = aws[rr % aws.len()];
                        rr += 1;
                        let meta = reqs[&id].meta.clone();
                        submit(&p.fabric, &mut qps, aw, &meta);
                        if let Some(q) = orch_qp.as_ref() {
                            let _ = q.post(
                                ClusterMsg::Bound { request: id, aw },
                                crate::proto::HDR_BYTES,
                                TrafficClass::Admin,
                            );
                        }
                        reqs.get_mut(&id).unwrap().assigned = aw;
                        p.events.record(EventKind::Migrated, id, 0, aw);
                    }
                }
                _ => {}
            },
            Err(crate::transport::QpError::Timeout) => {}
            Err(_) => break,
        }
        // Keep the orchestrator QP fresh if it was unavailable at start.
        if orch_qp.is_none() {
            orch_qp = p.fabric.qp(NodeId::Gateway, NodeId::Orchestrator, Plane::Control).ok();
        }

        // 3. Exit conditions: everything finished, or drain timeout.
        let all_submitted = next >= p.schedule.len();
        if all_submitted {
            let unfinished = reqs.values().filter(|r| !r.finished).count();
            let pending_subs = p.schedule.len() - reqs.len();
            if unfinished == 0 && pending_subs == 0 {
                break;
            }
            if now > last_arrival + p.drain_timeout.as_secs_f64() {
                break;
            }
        }
    }
    p.shared.done.store(true, Ordering::Release);
}

fn submit(
    fabric: &Arc<Fabric<ClusterMsg>>,
    qps: &mut HashMap<u32, Qp<ClusterMsg>>,
    aw: u32,
    meta: &RequestMeta,
) {
    let qp = qps.entry(aw).or_insert_with(|| {
        fabric.qp(NodeId::Gateway, NodeId::Aw(aw), Plane::Control).expect("gw qp")
    });
    let bytes = meta.wire_bytes();
    let _ = qp.post(ClusterMsg::NewRequest(meta.clone()), bytes, TrafficClass::Admin);
}
